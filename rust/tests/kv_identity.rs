//! KV-cached incremental scoring ≡ full-prefix recompute, byte for byte.
//!
//! The serving stack scores each session append incrementally (cached
//! sessions + the scheduler's coalesced `append_batch` submissions — the
//! same O(suffix) contract the device engine's cache pool implements).
//! [`ForceStateless`] hides a model's session support, so every scoring
//! call re-runs the full prefix: the full-recompute oracle. These tests
//! pin that the two are **bit-identical** for every coordinator `Method`
//! × `VerifyRule`, and that the equivalence survives exactly the paths
//! where a stale cache would show: speculative rollback, suspend/restore
//! from the swap tier, and mid-decode chain degradation.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use polyspec::sync::Mutex;
use std::time::Instant;

use polyspec::coordinator::api::{DecodeError, Method, Request, Response};
use polyspec::coordinator::batcher::QueueEntry;
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::router::pipeline_headroom;
use polyspec::coordinator::scheduler::{self, BatchEvent, SchedulerOpts};
use polyspec::spec::chaos::{ChaosModel, Fault};
use polyspec::spec::mock::MockModel;
use polyspec::spec::types::{ForceStateless, LanguageModel, VerifyRule};
use polyspec::workload::tasks::TaskKind;

/// The standard mock chain (target / intermediate / draft on shared
/// weights), either with its native cached sessions (the KV-cached path)
/// or wrapped in [`ForceStateless`] (the full-recompute oracle). Same
/// seeds both ways: identical weights, different execution strategy.
fn chain_with(stateless: bool, seed: u64) -> Vec<Arc<dyn LanguageModel>> {
    let mk = |name: &str, noise: f32| -> Arc<dyn LanguageModel> {
        let m = MockModel::new(name, 512, 24, seed, noise);
        if stateless {
            Arc::new(ForceStateless(m))
        } else {
            Arc::new(m)
        }
    };
    vec![mk("target", 0.0), mk("mid", 0.35), mk("draft", 0.8)]
}

/// Every coordinator `Method` × `VerifyRule`. The noisy drafters guarantee
/// rejections under every rule, so each request's decode rolls sessions
/// back many times — rollback correctness is load-bearing here, not
/// incidental.
fn mixed_workload() -> Vec<Request> {
    let methods = [
        Method::Polybasic { draft_k: 4, mu: 4 },
        Method::Dualistic { draft_k: 4 },
        Method::Autoregressive,
    ];
    let rules = [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }];
    let tasks = [TaskKind::Qa, TaskKind::Summarization, TaskKind::Math];
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for &method in &methods {
        for &rule in &rules {
            id += 1;
            let mut r = Request::new(id, vec![1, 2, 3], 20 + (id as usize % 3) * 8);
            r.method = method;
            r.rule = rule;
            r.task = Some(tasks[id as usize % 3]);
            r.sampling.seed = 500 + id;
            r.sampling.temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
            reqs.push(r);
        }
    }
    reqs
}

fn serve(
    chain: &[Arc<dyn LanguageModel>],
    reqs: &[Request],
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
) -> std::collections::BTreeMap<u64, Response> {
    let now = Instant::now();
    let batch: Vec<QueueEntry> =
        reqs.iter().map(|r| QueueEntry::fresh(r.clone(), now)).collect();
    let mut out: std::collections::BTreeMap<u64, Result<Response, DecodeError>> =
        Default::default();
    scheduler::run_batch_opts(
        chain,
        batch,
        None,
        reqs.len(),
        kv,
        metrics,
        SchedulerOpts { coalesce: true },
        |ev| {
            if let BatchEvent::Done { id, response } = ev {
                out.insert(id, response);
            }
        },
    );
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
    out.into_iter().map(|(id, r)| (id, r.expect("request failed"))).collect()
}

fn big_pool() -> Arc<Mutex<KvManager>> {
    Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 8,
        total_blocks: 512,
        bytes_per_token: 4,
        swap_blocks: 0,
    })))
}

/// THE property: a concurrent Method × VerifyRule workload served on the
/// KV-cached coalescing path is byte-identical to the same workload on
/// full-prefix recompute — and both match the uncontended one-shot decode.
/// The cached run must actually exercise the cache (coalesced engine
/// calls, suffix-only compute, nonzero recompute-avoided ratio); the
/// stateless oracle must never touch it.
#[test]
fn prop_cached_serving_identical_to_full_recompute() {
    let reqs = mixed_workload();
    let cached_chain = chain_with(false, 41);
    let stateless_chain = chain_with(true, 41);

    // Uncontended oracle on the stateless chain: pure full-prefix scoring.
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| scheduler::decode(&stateless_chain, r).unwrap().tokens).collect();

    let kv = big_pool();
    for r in &reqs {
        kv.lock().admit(r.id, 60).unwrap();
    }
    let m_cached = Arc::new(Metrics::default());
    let cached = serve(&cached_chain, &reqs, &kv, &m_cached);

    let kv = big_pool();
    for r in &reqs {
        kv.lock().admit(r.id, 60).unwrap();
    }
    let m_stateless = Arc::new(Metrics::default());
    let stateless = serve(&stateless_chain, &reqs, &kv, &m_stateless);

    for (r, want) in reqs.iter().zip(&expected) {
        assert_eq!(
            &cached[&r.id].tokens, want,
            "{:?} {:?} request {}: cached-incremental diverged from full recompute",
            r.method, r.rule, r.id
        );
        assert_eq!(
            &stateless[&r.id].tokens, want,
            "request {}: stateless serving diverged from one-shot decode",
            r.id
        );
    }

    // The cached run must have gone through the coalesced O(suffix) path.
    assert!(m_cached.batched_calls.load(Ordering::Relaxed) > 0, "coalescing must engage");
    let computed = m_cached.suffix_tokens_computed.load(Ordering::Relaxed);
    let avoided = m_cached.prefix_tokens_avoided.load(Ordering::Relaxed);
    assert!(computed > 0, "cached run must compute suffix rows");
    assert!(avoided > 0, "cached run must avoid prefix recompute");
    assert!(m_cached.recompute_avoided_ratio() > 0.0);
    // ForceStateless has no batch handle: the oracle never coalesces and
    // never records suffix work.
    assert_eq!(m_stateless.engine_calls.load(Ordering::Relaxed), 0);
    assert_eq!(m_stateless.suffix_tokens_computed.load(Ordering::Relaxed), 0);
}

/// Suspend/restore does not leak cache state: a pool small enough to force
/// preemptions, backed by a swap tier large enough that every victim
/// swaps out and restores its KV, still decodes byte-identically to the
/// full-recompute oracle — restored sessions pick up their caches exactly
/// where suspension left them.
#[test]
fn prop_cached_swap_restore_identical_to_full_recompute() {
    let reqs = mixed_workload();
    let cached_chain = chain_with(false, 33);
    let stateless_chain = chain_with(true, 33);
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| scheduler::decode(&stateless_chain, r).unwrap().tokens).collect();

    // Tiny pool (admissions fit, growth demand saturates) + a swap tier
    // that holds every victim in full.
    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 4,
        total_blocks: 26,
        bytes_per_token: 4,
        swap_blocks: 128,
    })));
    let metrics = Arc::new(Metrics::default());
    kv.lock().attach_metrics(metrics.clone());
    for r in &reqs {
        let need = r.prompt.len() + pipeline_headroom(&r.method, cached_chain.len());
        kv.lock().admit_fresh(r.id, need).unwrap();
    }
    let out = serve(&cached_chain, &reqs, &kv, &metrics);

    for (r, want) in reqs.iter().zip(&expected) {
        assert_eq!(
            &out[&r.id].tokens, want,
            "{:?} {:?} request {}: suspend/restore-from-swap broke cache identity",
            r.method, r.rule, r.id
        );
    }
    let ord = Ordering::Relaxed;
    assert!(metrics.preemptions.load(ord) >= 1, "scenario must saturate the pool");
    assert!(metrics.swapped_blocks.load(ord) > 0, "victims must take the swap path");
    assert_eq!(kv.lock().active_seqs(), 0);
}

/// Mid-decode degradation does not leak cache state: a drafter fault drops
/// it from the chain partway through a request, and under greedy (only the
/// target's argmax commits) the output stays byte-identical to the
/// fault-free full-recompute oracle — the target's session cache carries
/// across the chain reshape untouched.
#[test]
fn prop_cached_degradation_identical_to_full_recompute() {
    let mk_req = || {
        let mut r = Request::new(1, vec![2, 7, 1], 24);
        r.method = Method::Dualistic { draft_k: 2 };
        r.rule = VerifyRule::Greedy;
        r.sampling.temperature = 0.0;
        r
    };
    // Oracle: fault-free stateless pair (same weights, full recompute).
    let stateless_chain: Vec<Arc<dyn LanguageModel>> = vec![
        Arc::new(ForceStateless(MockModel::new("t", 512, 24, 13, 0.0))),
        Arc::new(ForceStateless(MockModel::new("d", 512, 24, 13, 0.4))),
    ];
    let expected = scheduler::decode(&stateless_chain, &mk_req()).unwrap().tokens;

    // Cached run with the drafter faulting on its third call: the task
    // degrades mid-decode and finishes target-only, on live caches.
    let chain: Vec<Arc<dyn LanguageModel>> = vec![
        Arc::new(MockModel::new("t", 512, 24, 13, 0.0)),
        Arc::new(
            ChaosModel::new(MockModel::new("d", 512, 24, 13, 0.4)).fault_at(2, Fault::Fail),
        ),
    ];
    let kv = big_pool();
    kv.lock().admit(1, 60).unwrap();
    let metrics = Arc::new(Metrics::default());
    let out = serve(&chain, &[mk_req()], &kv, &metrics);

    assert_eq!(
        out[&1].tokens, expected,
        "mid-decode degradation must be invisible in greedy output"
    );
    assert!(out[&1].degraded >= 1, "the drafter fault must actually degrade the chain");
}
