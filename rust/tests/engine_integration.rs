//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! These are the end-to-end correctness signal: HLO text produced by
//! python/compile/aot.py is loaded, compiled and executed by the rust
//! runtime, and the polybasic system decodes with the real chain.

use std::sync::Arc;

use polyspec::runtime::EngineHost;
use polyspec::spec::types::{LanguageModel, SamplingParams, VerifyRule};
use polyspec::spec::{autoregressive, polybasic, PolyConfig};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn loads_and_scores() {
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["target"]).unwrap();
    let target = host.model(0);
    let logits = target.forward(&[1, 2, 3, 4, 5]).unwrap();
    assert_eq!(logits.seq(), 5);
    assert_eq!(logits.vocab(), target.vocab());
    // Logits must be finite and non-degenerate.
    let row = logits.row(4);
    assert!(row.iter().all(|x| x.is_finite()));
    let spread = row.iter().cloned().fold(f32::MIN, f32::max)
        - row.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 0.1, "degenerate logits, spread {spread}");
}

#[test]
fn causal_rows_stable_under_suffix_changes() {
    // The padding contract: row t depends only on tokens[0..=t].
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["draft"]).unwrap();
    let m = host.model(0);
    let a = m.forward(&[5, 6, 7, 8]).unwrap();
    let b = m.forward(&[5, 6, 7, 200]).unwrap();
    for t in 0..3 {
        let (ra, rb) = (a.row(t), b.row(t));
        for (x, y) in ra.iter().zip(rb) {
            assert!((x - y).abs() < 1e-4, "row {t} changed: {x} vs {y}");
        }
    }
}

#[test]
fn deterministic_across_calls() {
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["intermediate"]).unwrap();
    let m = host.model(0);
    let a = m.forward(&[9, 1, 1, 3]).unwrap();
    let b = m.forward(&[9, 1, 1, 3]).unwrap();
    assert_eq!(a.row(3), b.row(3));
}

#[test]
fn polybasic_greedy_equals_target_greedy_on_real_chain() {
    // THE system-level lossless check on real artifacts.
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["target", "intermediate", "draft"]).unwrap();
    let chain = host.chain();
    let prompt: Vec<i32> = vec![10, 20, 30, 40];
    let max_new = 24;
    let mut cfg = PolyConfig::for_chain(3, 4, 4, max_new);
    cfg.rule = VerifyRule::Greedy;
    cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
    let poly = polybasic::generate(&chain, &prompt, &cfg).unwrap();
    let ar = autoregressive::generate(chain[0].as_ref(), &prompt, max_new, &cfg.sampling)
        .unwrap();
    assert_eq!(poly.tokens, ar.tokens, "polybasic greedy diverged from target greedy");
    assert!(
        poly.forward_passes[0] < ar.forward_passes[0],
        "no target-forward savings: {:?} vs {:?}",
        poly.forward_passes,
        ar.forward_passes
    );
}

#[test]
fn chain_members_are_genuinely_cheaper() {
    // T_draft < T_int < T_target — the premise of the whole system.
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["target", "intermediate", "draft"]).unwrap();
    let t_target = host.measure_cost_ms(0, 96, 5).unwrap();
    let t_int = host.measure_cost_ms(1, 96, 5).unwrap();
    let t_draft = host.measure_cost_ms(2, 96, 5).unwrap();
    assert!(t_draft < t_int, "draft {t_draft}ms !< int {t_int}ms");
    assert!(t_int < t_target, "int {t_int}ms !< target {t_target}ms");
}

#[test]
fn speculative_sampling_reproducible_on_real_chain() {
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["target", "intermediate", "draft"]).unwrap();
    let chain = host.chain();
    let mut cfg = PolyConfig::for_chain(3, 4, 4, 16);
    cfg.sampling.seed = 1234;
    let a = polybasic::generate(&chain, &[7, 7, 7], &cfg).unwrap();
    let b = polybasic::generate(&chain, &[7, 7, 7], &cfg).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert!(a.mean_accept() >= 1.0);
    let vocab = chain[0].vocab() as i32;
    assert!(a.tokens.iter().all(|&t| t >= 0 && t < vocab));
}

#[test]
fn remote_handles_work_from_other_threads() {
    let dir = require_artifacts!();
    let host = EngineHost::load(dir, "v7b", &["draft"]).unwrap();
    let m: Arc<polyspec::runtime::RemoteModel> = host.model(0);
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let m = m.clone();
            std::thread::spawn(move || {
                let toks = vec![i as i32 + 1, 2, 3];
                m.forward(&toks).unwrap().row(2).to_vec()
            })
        })
        .collect();
    for h in handles {
        let row = h.join().unwrap();
        assert!(row.iter().all(|x| x.is_finite()));
    }
}
