//! Loom model checks for the serving stack's concurrency protocols.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (the `loom` CI job); a
//! normal `cargo test` sees an empty crate. Each model exhaustively
//! explores thread interleavings of one small protocol over the
//! `crate::sync` facade, which routes `Mutex`/`Condvar`/atomics to loom's
//! checked implementations under this cfg.
//!
//! The four protocols modeled here (see `rust/docs/verification.md`):
//!
//! 1. **Suspend vs. fresh admission** — `KvManager::suspend` releases a
//!    sequence, records resume debt, and reserves swap in one lock scope;
//!    no interleaving may let `admit_fresh` steal the freed blocks.
//! 2. **Cache release vs. evict-on-demand** — `release_cached` registering
//!    blocks in the radix cache racing a fresh admission that evicts on
//!    demand must conserve blocks and always admit when capacity exists.
//! 3. **Breaker half-open probe** — after cooldown, exactly one of two
//!    racing callers wins the single probe token, and a failed probe
//!    re-arms the cooldown.
//! 4. **Worker park/unpark** — a request pushed (or re-queued via
//!    `push_front_resumed`) around `close()` is popped exactly once and
//!    every parked worker wakes up (no lost wakeup, no double-pop).
//!
//! Models stay within loom's default thread budget (max 4, including
//! main) and use a preemption bound where the state space is large.

#![cfg(loom)]

use std::time::Duration;

use polyspec::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::{Request, ResumeCarry};
use polyspec::spec::rng::Pcg32;
use polyspec::spec::task::{InflightState, ResumeState};
use polyspec::spec::types::{BreakerState, FaultKind, HealthConfig, HealthTracker};
use polyspec::sync::time::Instant;
use polyspec::sync::{thread, Arc, Mutex};

fn dummy_carry() -> ResumeCarry {
    ResumeCarry {
        state: ResumeState {
            committed: vec![],
            rng: Pcg32::seeded(0),
            accept_lengths: vec![],
            stage_accepts: vec![],
            wall: Duration::ZERO,
            forward_passes: vec![0],
            forward_time: vec![Duration::ZERO],
            inflight: InflightState::None,
            live_models: vec![0],
            degraded: 0,
            swap: None,
        },
        streamed: 0,
        ttft: None,
        queue_time: Duration::ZERO,
        service_time: Duration::ZERO,
        preemptions: 1,
    }
}

/// Protocol 1: `suspend` (release + resume debt + swap reserve) is atomic
/// against a racing `admit_fresh`. The pool is sized so the suspended
/// sequence's resume debt covers every freed block: whichever side runs
/// first, the fresh arrival must be refused — before the suspend the pool
/// is full, after it the debt earmarks the freed space for the resumer.
#[test]
fn suspend_never_leaks_freed_blocks_to_fresh_admissions() {
    loom::model(|| {
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
            block_size: 4,
            total_blocks: 4,
            bytes_per_token: 0,
            swap_blocks: 4,
        })));
        kv.lock().admit(1, 16).expect("pool sized for seq 1");

        let kv2 = Arc::clone(&kv);
        let suspender = thread::spawn(move || {
            kv2.lock().suspend(1, 16, 16).expect("seq 1 is live")
        });
        let fresh = kv.lock().admit_fresh(2, 4);

        let handle = suspender.join().expect("suspender panicked");
        assert!(fresh.is_err(), "fresh admission stole blocks owed to the resumer");
        assert!(handle.is_some(), "swap tier sized to hold the suspended seq");
        let g = kv.lock();
        assert_eq!(g.resume_debt(), 4, "debt covers the suspended footprint");
        assert_eq!(g.free_blocks(), 4, "suspend freed the whole pool");
    });
}

/// Protocol 2: `release_cached` (register content in the radix cache, then
/// release) racing a fresh admission that evicts cached blocks on demand.
/// In every interleaving the admission finds capacity (free or evictable),
/// and block conservation holds at the end.
#[test]
fn release_cached_races_evict_on_demand() {
    loom::model(|| {
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
            block_size: 2,
            total_blocks: 4,
            bytes_per_token: 0,
            swap_blocks: 0,
        })));
        {
            // Seed the radix cache: admit a prompt, then release it cached.
            let mut g = kv.lock();
            g.admit_fresh_prefixed(10, &[1, 2, 3, 4], 4).expect("empty pool");
            g.release_cached(10, &[1, 2, 3, 4]).expect("seq 10 is live");
            g.admit_fresh(11, 4).expect("two blocks are free");
        }

        let kv2 = Arc::clone(&kv);
        let releaser = thread::spawn(move || {
            kv2.lock().release_cached(11, &[9, 9, 8, 8]).expect("seq 11 is live");
        });
        // Needs 2 blocks; whichever order the race resolves, free +
        // evictable-cached >= 2, so this must succeed.
        kv.lock().admit_fresh(20, 4).expect("capacity exists in every interleaving");
        releaser.join().expect("releaser panicked");

        let mut g = kv.lock();
        assert_eq!(g.seq_blocks(20), Some(2));
        g.release(20).expect("seq 20 is live");
        assert_eq!(
            g.free_blocks() + g.cached_blocks(),
            4,
            "block conservation: free + cached == total after full release"
        );
    });
}

/// Protocol 3a: once the cooldown elapses, exactly one of two racing
/// callers wins the half-open probe token; the loser (and any later
/// caller at the same instant) is refused because the winning probe
/// re-arms the breaker window.
#[test]
fn breaker_half_open_admits_exactly_one_probe() {
    loom::model(|| {
        let t0 = Instant::now();
        let tracker = Arc::new(HealthTracker::new(HealthConfig {
            failure_threshold: 2,
            cooldown: Duration::from_secs(1),
        }));
        tracker.record_failure_at(FaultKind::Transient, t0);
        tracker.record_failure_at(FaultKind::Transient, t0);
        assert_eq!(tracker.breaker_state_at(t0), BreakerState::Open);

        let probe_at = t0 + Duration::from_secs(1);
        let t2 = Arc::clone(&tracker);
        let racer = thread::spawn(move || t2.healthy_at(probe_at));
        let a = tracker.healthy_at(probe_at);
        let b = racer.join().expect("racer panicked");

        assert!(a ^ b, "exactly one caller may win the probe token (got {a}, {b})");
        assert!(
            !tracker.healthy_at(probe_at),
            "the winning probe re-armed the window; no second probe at the same instant"
        );
    });
}

/// Protocol 3b: concurrent failure reports never lose a streak increment —
/// the consecutive-failure count that trips the breaker is exact.
#[test]
fn breaker_failure_race_keeps_streak() {
    loom::model(|| {
        let t0 = Instant::now();
        let tracker = Arc::new(HealthTracker::new(HealthConfig {
            failure_threshold: 2,
            cooldown: Duration::from_secs(1),
        }));
        let t2 = Arc::clone(&tracker);
        let racer = thread::spawn(move || {
            t2.record_failure_at(FaultKind::Timeout, t0);
        });
        tracker.record_failure_at(FaultKind::Timeout, t0);
        racer.join().expect("racer panicked");

        assert_eq!(tracker.consecutive_failures(), 2, "no lost increment");
        assert_eq!(tracker.errors(), 2);
        assert_eq!(tracker.breaker_state_at(t0), BreakerState::Open);
    });
}

fn instant_policy() -> BatchPolicy {
    // Zero windows: pop_batch never takes the wait_timeout path (which the
    // loom facade models as a plain wait), so dispatch is immediate once
    // work exists and parking happens only on an empty queue.
    BatchPolicy {
        max_batch: 1,
        max_wait: Duration::ZERO,
        starvation_wait: Duration::ZERO,
    }
}

/// Protocol 4a: a preempted request re-queued via `push_front_resumed`
/// around `close()` is never lost — the worker parked in `pop_batch`
/// observes it (wakeup delivered) and drains it before seeing the close.
#[test]
fn resumed_push_never_loses_wakeup() {
    let mut builder = loom::model::Builder::new();
    builder.preemption_bound = Some(3);
    builder.check(|| {
        let b = Arc::new(DynamicBatcher::new(instant_policy()));
        let b2 = Arc::clone(&b);
        let worker = thread::spawn(move || {
            let mut ids = Vec::new();
            while let Some(batch) = b2.pop_batch() {
                for entry in &batch {
                    ids.push(entry.req.id);
                    assert!(entry.resume.is_some(), "resume baggage survives the queue");
                }
            }
            ids
        });

        b.push_front_resumed(Request::new(7, vec![1], 4), dummy_carry());
        b.close();

        let got = worker.join().expect("worker panicked");
        assert_eq!(got, vec![7], "the resumed request is drained exactly once");
    });
}

/// Protocol 4b: two workers competing over one pushed request around
/// `close()` — the request is popped exactly once (no double-pop) and both
/// workers terminate (no lost wakeup leaves a worker parked forever).
#[test]
fn queued_request_popped_exactly_once_across_workers() {
    let mut builder = loom::model::Builder::new();
    builder.preemption_bound = Some(2);
    builder.check(|| {
        let b = Arc::new(DynamicBatcher::new(instant_policy()));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    let mut ids = Vec::new();
                    while let Some(batch) = b.pop_batch() {
                        for entry in &batch {
                            ids.push(entry.req.id);
                        }
                    }
                    ids
                })
            })
            .collect();

        b.push(Request::new(3, vec![1], 4));
        b.close();

        let mut all = Vec::new();
        for w in workers {
            all.extend(w.join().expect("worker panicked"));
        }
        assert_eq!(all, vec![3], "one worker pops the request, the other exits clean");
    });
}
