//! Fault-tolerant serving, end to end, under the deterministic chaos
//! harness ([`polyspec::spec::chaos`]).
//!
//! These tests pin the failure-semantics contract documented in
//! `coordinator`: drafter faults **degrade** the chain without touching
//! the output distribution (byte-identical under deterministic verify
//! rules), target faults **fail** the request with a typed
//! [`DecodeError`] and provably release KV, and request deadlines cancel
//! overdue work at step boundaries — never leaking pool space.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use polyspec::sync::Mutex;
use std::time::{Duration, Instant};

use polyspec::coordinator::api::{DecodeError, Method, Request, Response};
use polyspec::coordinator::batcher::QueueEntry;
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::scheduler::{decode, run_batch, BatchEvent};
use polyspec::spec::chaos::{ChaosModel, Fault};
use polyspec::spec::mock::{mock_chain, MockModel};
use polyspec::spec::types::{LanguageModel, VerifyRule};

/// The standard mock chain (same weights as [`mock_chain`]) with scripted
/// faults: each `(member, call_idx, fault)` wraps chain member `member`
/// in a [`ChaosModel`] injecting `fault` at its `call_idx`-th call.
/// Unscripted calls pass through bit-identically, so faulty and clean
/// chains are comparable token for token.
fn chaos_chain(seed: u64, faults: &[(usize, u64, Fault)]) -> Vec<Arc<dyn LanguageModel>> {
    let spec = [("mock-target", 0.0f32), ("mock-mid", 0.35), ("mock-draft", 0.8)];
    spec.iter()
        .enumerate()
        .map(|(i, &(name, noise))| {
            let inner = MockModel::new(name, 512, 24, seed, noise);
            let scripted: Vec<(u64, Fault)> =
                faults.iter().filter(|f| f.0 == i).map(|f| (f.1, f.2)).collect();
            if scripted.is_empty() {
                Arc::new(inner) as Arc<dyn LanguageModel>
            } else {
                let mut chaotic = ChaosModel::new(inner);
                for (at, fault) in scripted {
                    chaotic = chaotic.fault_at(at, fault);
                }
                Arc::new(chaotic) as Arc<dyn LanguageModel>
            }
        })
        .collect()
}

/// A greedy (deterministic-rule) request: every commit is the argmax of
/// the target's filtered row, so output must survive any drafter fault.
fn greedy_req(id: u64, method: Method, max_new: usize) -> Request {
    let mut r = Request::new(id, vec![3, 1, 4], max_new);
    r.method = method;
    r.rule = VerifyRule::Greedy;
    r.sampling.temperature = 0.0;
    r.sampling.seed = 100 + id;
    r
}

fn kv_pool() -> Arc<Mutex<KvManager>> {
    Arc::new(Mutex::new(KvManager::new(KvConfig::default())))
}

fn drive(
    chain: &[Arc<dyn LanguageModel>],
    batch: Vec<QueueEntry>,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
) -> Vec<Result<Response, DecodeError>> {
    let mut out = Vec::new();
    run_batch(chain, batch, None, 8, kv, metrics, |ev| {
        if let BatchEvent::Done { response, .. } = ev {
            out.push(response);
        }
    });
    out
}

const ALL_METHODS: [Method; 3] = [
    Method::Autoregressive,
    Method::Dualistic { draft_k: 4 },
    Method::Polybasic { draft_k: 4, mu: 4 },
];

/// THE degradation property, single-shot: a drafter failing mid-decode is
/// dropped from the chain and the decode completes with byte-identical
/// tokens to a fault-free run, for every Method under a deterministic
/// verify rule. Only the methods that use the faulted drafter degrade.
#[test]
fn prop_drafter_fault_is_byte_invisible_under_greedy() {
    for (m, method) in ALL_METHODS.iter().enumerate() {
        let req = greedy_req(m as u64 + 1, *method, 32);
        let clean = decode(&mock_chain(512, 24, 55), &req).unwrap();
        // The deepest drafter fails its third call; all other calls clean.
        let faulty_chain = chaos_chain(55, &[(2, 2, Fault::Fail)]);
        let faulty = decode(&faulty_chain, &req).unwrap();
        assert_eq!(
            faulty.tokens, clean.tokens,
            "{}: drafter fault must be invisible in greedy output",
            method.label()
        );
        match method {
            Method::Autoregressive => {
                assert_eq!(faulty.degraded, 0, "vanilla decode has no drafters to lose")
            }
            _ => assert_eq!(
                faulty.degraded, 1,
                "{}: the failed drafter must be counted as dropped",
                method.label()
            ),
        }
    }
}

/// Full shrink: both drafters' engines die, the polybasic chain degrades
/// member by member down to plain autoregressive decode on the target,
/// and the greedy output equals a vanilla decode of the target alone.
#[test]
fn all_drafters_lost_degrades_polybasic_to_autoregressive() {
    let poly = greedy_req(1, Method::Polybasic { draft_k: 4, mu: 4 }, 32);
    let vanilla = greedy_req(1, Method::Autoregressive, 32);
    let expected = decode(&mock_chain(512, 24, 71), &vanilla).unwrap();
    let chain = chaos_chain(71, &[(1, 0, Fault::Lost), (2, 0, Fault::Lost)]);
    let out = decode(&chain, &poly).unwrap();
    assert_eq!(out.tokens, expected.tokens, "fully degraded chain must match vanilla decode");
    assert_eq!(out.degraded, 2, "both drafters were lost");
}

/// A drafter fault under a stochastic verify rule still completes the
/// request (the committed-token *distribution* is preserved even though
/// the sampled path may differ from a fault-free run).
#[test]
fn stochastic_rule_completes_under_drafter_loss() {
    let mut req = greedy_req(1, Method::Polybasic { draft_k: 4, mu: 4 }, 32);
    req.rule = VerifyRule::Speculative;
    req.sampling.temperature = 1.0;
    let chain = chaos_chain(33, &[(2, 4, Fault::Lost)]);
    let out = decode(&chain, &req).unwrap();
    assert_eq!(out.tokens.len(), 32, "degraded decode must still fill the budget");
    assert!(out.degraded >= 1, "the lost drafter must be counted");
}

/// THE serving acceptance property: a drafter engine dies mid-decode
/// under a live batch. Every Method completes with tokens byte-identical
/// to an uncontended fault-free decode, responses report the degradation,
/// the server-wide counter accounts for it, and no KV leaks.
#[test]
fn prop_run_batch_survives_drafter_loss_byte_identically() {
    let reqs: Vec<Request> = ALL_METHODS
        .iter()
        .enumerate()
        .map(|(i, &m)| greedy_req(i as u64 + 1, m, 24 + 4 * i))
        .collect();
    let expected: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| decode(&mock_chain(512, 24, 91), r).unwrap().tokens)
        .collect();

    // The deepest drafter's engine dies at its sixth call — mid-decode for
    // the batch — and every later call against it fails too.
    let chain = chaos_chain(91, &[(2, 5, Fault::Lost)]);
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    let now = Instant::now();
    let batch: Vec<QueueEntry> = reqs
        .iter()
        .map(|r| {
            kv.lock().admit(r.id, 60).unwrap();
            QueueEntry::fresh(r.clone(), now)
        })
        .collect();

    let out = drive(&chain, batch, &kv, &metrics);

    assert_eq!(out.len(), reqs.len());
    let mut by_id: std::collections::BTreeMap<u64, Response> = Default::default();
    for r in out {
        let resp = r.expect("drafter loss must never fail a request");
        by_id.insert(resp.id, resp);
    }
    for (req, want) in reqs.iter().zip(&expected) {
        let resp = &by_id[&req.id];
        assert_eq!(
            &resp.tokens, want,
            "request {} ({}): degradation must be invisible in greedy output",
            req.id,
            req.method.label()
        );
        match req.method {
            Method::Autoregressive => assert_eq!(resp.degraded, 0),
            _ => assert!(
                resp.degraded >= 1,
                "request {} ({}) must report the dropped drafter",
                req.id,
                req.method.label()
            ),
        }
    }
    assert!(
        metrics.chains_degraded.load(Ordering::Relaxed) >= 2,
        "both speculative chains dropped the lost drafter"
    );
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 0);
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
    assert_eq!(metrics.inflight(), 0);
}

/// A *target* engine loss is fatal — degradation cannot help, because only
/// the target defines the output distribution. The request fails with the
/// typed [`DecodeError::EngineLost`] and its KV is released.
#[test]
fn target_loss_fails_with_engine_lost_and_releases_kv() {
    let chain = chaos_chain(17, &[(0, 2, Fault::Lost)]);
    let req = greedy_req(1, Method::Polybasic { draft_k: 4, mu: 4 }, 32);
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    kv.lock().admit(1, 60).unwrap();
    let out = drive(&chain, vec![QueueEntry::fresh(req, Instant::now())], &kv, &metrics);
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].as_ref().unwrap_err(), &DecodeError::EngineLost);
    assert_eq!(kv.lock().active_seqs(), 0, "failed request must release KV");
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.inflight(), 0);
}

/// A hung target call surfaces as a deadline timeout ([`FaultKind::Timeout`]
/// at the engine boundary), classified to [`DecodeError::Timeout`].
#[test]
fn hung_target_call_times_out_the_request() {
    let chain = chaos_chain(17, &[(0, 1, Fault::Hang(Duration::from_millis(2)))]);
    let req = greedy_req(1, Method::Dualistic { draft_k: 4 }, 32);
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    kv.lock().admit(1, 60).unwrap();
    let out = drive(&chain, vec![QueueEntry::fresh(req, Instant::now())], &kv, &metrics);
    assert_eq!(out[0].as_ref().unwrap_err(), &DecodeError::Timeout);
    assert_eq!(kv.lock().active_seqs(), 0, "failed request must release KV");
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
}

/// A request whose deadline expired while queued is refused at admission:
/// no session ever opens, no first token is recorded, and the router's KV
/// reservation is returned.
#[test]
fn deadline_expired_in_queue_is_refused_at_admission() {
    let chain = mock_chain(512, 24, 5);
    let mut req = greedy_req(1, Method::Autoregressive, 16);
    req.deadline = Some(Duration::from_millis(1));
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    kv.lock().admit(1, 40).unwrap();
    let entry = QueueEntry::fresh(req, Instant::now());
    std::thread::sleep(Duration::from_millis(5)); // let the deadline lapse in queue
    let out = drive(&chain, vec![entry], &kv, &metrics);
    assert_eq!(out[0].as_ref().unwrap_err(), &DecodeError::Timeout);
    assert_eq!(kv.lock().active_seqs(), 0, "reservation must be returned");
    assert_eq!(metrics.deadline_cancellations.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.ttft_latency.count(), 0, "no decode ever started");
}

/// A deadline exceeded *mid-decode* (here: one slow engine call pushes the
/// request past its budget) cancels the task at the next step boundary
/// with [`DecodeError::Timeout`], dropping its sessions and releasing KV.
#[test]
fn deadline_exceeded_mid_decode_cancels_and_releases_kv() {
    let chain = chaos_chain(5, &[(0, 0, Fault::Latency(Duration::from_millis(30)))]);
    let mut req = greedy_req(1, Method::Autoregressive, 64);
    req.deadline = Some(Duration::from_millis(8));
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    kv.lock().admit(1, 40).unwrap();
    let out = drive(&chain, vec![QueueEntry::fresh(req, Instant::now())], &kv, &metrics);
    assert_eq!(out[0].as_ref().unwrap_err(), &DecodeError::Timeout);
    assert_eq!(kv.lock().active_seqs(), 0, "cancellation must release KV");
    assert_eq!(metrics.deadline_cancellations.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.inflight(), 0);
}

/// A two-member chain (target + one drafter) sharing seed/noise so the
/// drafter is *perfect*: under greedy it full-accepts every block, which
/// keeps its session a strict prefix of the context and makes every
/// tick's drafter call a pure batched append.
fn pair_chain(fault: Option<(u64, Fault)>) -> Vec<Arc<dyn LanguageModel>> {
    let target = MockModel::new("t", 512, 24, 13, 0.0);
    let draft = MockModel::new("d", 512, 24, 13, 0.0);
    let draft: Arc<dyn LanguageModel> = match fault {
        Some((at, f)) => Arc::new(ChaosModel::new(draft).fault_at(at, f)),
        None => Arc::new(draft),
    };
    vec![Arc::new(target), draft]
}

/// Fault isolation inside a coalesced batch: when one session's entry in
/// a [`SessionAppendBatch`]-style batched call faults, only the task that
/// owns that entry degrades — its batch-mates absorb their rows and keep
/// their drafters — and under greedy both outputs stay byte-identical to
/// a fault-free run.
#[test]
fn batched_entry_fault_degrades_only_its_own_task() {
    let reqs: Vec<Request> =
        (1..=2).map(|id| greedy_req(id, Method::Dualistic { draft_k: 1 }, 24)).collect();
    let clean = pair_chain(None);
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| decode(&clean, r).unwrap().tokens).collect();

    // Two live same-chain requests: each tick the scheduler coalesces both
    // drafter appends into one batched call claiming two chaos indices in
    // batch order (draft_k = 1 and a perfect drafter keep every tick's
    // drafter call a pure batched append). Index 3 is therefore the second
    // entry of the second tick's batch: request 2's entry, mid-batch.
    let chain = pair_chain(Some((3, Fault::Fail)));
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    let now = Instant::now();
    let batch: Vec<QueueEntry> = reqs
        .iter()
        .map(|r| {
            kv.lock().admit(r.id, 60).unwrap();
            QueueEntry::fresh(r.clone(), now)
        })
        .collect();
    let out = drive(&chain, batch, &kv, &metrics);

    let mut by_id: std::collections::BTreeMap<u64, Response> = Default::default();
    for r in out {
        let resp = r.expect("a drafter fault must never fail a request");
        by_id.insert(resp.id, resp);
    }
    for (req, want) in reqs.iter().zip(&expected) {
        assert_eq!(
            &by_id[&req.id].tokens, want,
            "request {}: batched-entry fault must be invisible in greedy output",
            req.id
        );
    }
    assert_eq!(by_id[&1].degraded, 0, "the clean entry's task must keep its drafter");
    assert_eq!(by_id[&2].degraded, 1, "only the faulted entry's task degrades");
    assert_eq!(metrics.chains_degraded.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 0);
    assert!(
        metrics.batched_calls.load(Ordering::Relaxed) > 0,
        "coalescing must have engaged"
    );
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
}
