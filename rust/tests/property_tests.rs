//! Property-based tests over coordinator + algorithm invariants.
//!
//! The offline crate set has no proptest, so these are hand-rolled
//! properties: seeded random input generation (PCG32) with many iterations
//! per property and failure messages that include the seed for replay.

use std::sync::Arc;
use polyspec::sync::Mutex;
use std::time::Instant;

use polyspec::coordinator::api::{Method, Request};
use polyspec::coordinator::batcher::{BatchPolicy, DynamicBatcher, QueueEntry};
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::router::pipeline_headroom;
use polyspec::coordinator::scheduler;
use polyspec::runtime::json::Json;
use polyspec::spec::csdraft::{self, CsDraftConfig, CsDraftTask};
use polyspec::spec::mock::{mock_chain, MockModel};
use polyspec::spec::ngram::BigramModel;
use polyspec::spec::rng::Pcg32;
use polyspec::spec::task::DecodeTask;
use polyspec::spec::types::{
    reconcile, softmax, ForceStateless, LanguageModel, SamplingParams, ScoringSession, VerifyRule,
};
use polyspec::spec::verify::verify_block;
use polyspec::spec::{autoregressive, dualistic, polybasic, PolyConfig};
use polyspec::workload::tasks::{make_query, ALL_TASKS};

/// KV manager: under arbitrary admit/grow/release sequences the allocator
/// never oversubscribes, never loses blocks, and ends balanced.
#[test]
fn prop_kv_manager_conserves_blocks() {
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(seed);
        let total = 8 + rng.next_below(64) as usize;
        let block = 1 + rng.next_below(32) as usize;
        let mut mgr =
            KvManager::new(KvConfig {
                block_size: block,
                total_blocks: total,
                bytes_per_token: 4,
                swap_blocks: 0,
            });
        let mut live: Vec<(u64, usize)> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..200 {
            assert!(mgr.allocated_blocks() + mgr.free_blocks() == total, "seed {seed}: leak");
            match rng.next_below(3) {
                0 => {
                    let tokens = 1 + rng.next_below((block * 6) as u32) as usize;
                    next_id += 1;
                    if mgr.admit(next_id, tokens).is_ok() {
                        live.push((next_id, tokens));
                    } else {
                        assert!(
                            !mgr.can_admit(tokens),
                            "seed {seed}: admit failed though can_admit true"
                        );
                    }
                }
                1 => {
                    if let Some(i) = live.last().map(|_| live.len() - 1) {
                        let (id, old) = live[i];
                        let newlen = old + rng.next_below(block as u32 * 2) as usize;
                        if mgr.grow(id, newlen).is_ok() {
                            live[i] = (id, newlen);
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u32) as usize;
                        let (id, _) = live.remove(i);
                        mgr.release(id).unwrap();
                    }
                }
            }
        }
        for (id, _) in live {
            mgr.release(id).unwrap();
        }
        assert_eq!(mgr.allocated_blocks(), 0, "seed {seed}: blocks leaked at drain");
        assert_eq!(mgr.active_seqs(), 0);
    }
}

/// Paged-KV prefix sharing is invisible in output: for every coordinator
/// `Method` × `VerifyRule`, requests admitted through the radix-prefix
/// path — two prompts diverging after a shared full-block prefix, plus an
/// exact repeat of the first prompt — decode byte-identically to the same
/// requests decoded alone through `scheduler::decode`. Sharing is real,
/// not incidental: the pair holds strictly fewer than twice the blocks of
/// a lone admission, the shared blocks are the *same physical ids* across
/// all three sequences, and the refcounts prove it.
#[test]
fn prop_prefix_shared_decode_identical_to_uncontended() {
    let methods = [
        Method::Autoregressive,
        Method::Dualistic { draft_k: 4 },
        Method::Polybasic { draft_k: 4, mu: 4 },
    ];
    let mut rng = Pcg32::seeded(4096);
    for rule in [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }] {
        for &method in &methods {
            let chain = mock_chain(512, 24, 19);
            let headroom = pipeline_headroom(&method, chain.len());
            // A shared prefix spanning two full 8-token blocks; per-request
            // tails diverge inside the third block.
            let prefix: Vec<i32> = (0..16).map(|_| rng.next_below(24) as i32).collect();
            let mut mk = |id: u64| {
                let mut prompt = prefix.clone();
                for _ in 0..2 + rng.next_below(4) {
                    prompt.push(rng.next_below(24) as i32);
                }
                let mut r = Request::new(id, prompt, 12 + (id as usize % 3) * 4);
                r.method = method;
                r.rule = rule;
                r.sampling.seed = 7000 + id;
                r.sampling.temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
                r
            };
            let a = mk(1);
            let b = mk(2);
            let mut c = mk(3);
            c.prompt = a.prompt.clone(); // exact repeat: full cached-prefix hit
            let reqs = [a, b, c];
            let expected: Vec<Vec<i32>> =
                reqs.iter().map(|r| scheduler::decode(&chain, r).unwrap().tokens).collect();

            // Generous pool: no preemption, so any divergence is the
            // cache's fault alone.
            let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
                block_size: 8,
                total_blocks: 64,
                bytes_per_token: 4,
                swap_blocks: 0,
            })));
            let metrics = Arc::new(Metrics::default());
            let now = Instant::now();
            let mut allocated_after = [0usize; 3];
            let batch: Vec<QueueEntry> = reqs
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    let mut kvm = kv.lock();
                    kvm.admit_fresh_prefixed(r.id, &r.prompt, r.prompt.len() + headroom)
                        .unwrap();
                    allocated_after[i] = kvm.allocated_blocks();
                    drop(kvm);
                    QueueEntry::fresh(r.clone(), now)
                })
                .collect();
            {
                let kvm = kv.lock();
                // The sharing criterion: two admissions sharing a prefix
                // consume strictly fewer blocks than two lone admissions.
                assert!(
                    allocated_after[1] < 2 * allocated_after[0],
                    "{method:?} {rule:?}: pair holds {} blocks, one holds {}",
                    allocated_after[1],
                    allocated_after[0]
                );
                let ta = kvm.seq_block_ids(1).unwrap();
                let tb = kvm.seq_block_ids(2).unwrap();
                let tc = kvm.seq_block_ids(3).unwrap();
                assert_eq!(ta[..2], tb[..2], "prefix blocks must be physically shared");
                assert_eq!(ta[..2], tc[..2], "the repeat must map the same physical blocks");
                assert!(
                    kvm.block_refcount(ta[0]) >= 3,
                    "{method:?} {rule:?}: three sequences map the shared block, refcount {}",
                    kvm.block_refcount(ta[0])
                );
                assert!(
                    kvm.prefix_hit_tokens() >= 32,
                    "{method:?} {rule:?}: both followers must hit the 16-token prefix, got {}",
                    kvm.prefix_hit_tokens()
                );
            }

            let mut got: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
            scheduler::run_batch(&chain, batch, None, reqs.len(), &kv, &metrics, |ev| {
                if let scheduler::BatchEvent::Done { id, response } = ev {
                    let resp = response.expect("no failures under an uncontended pool");
                    got.insert(id, resp.tokens);
                }
            });
            for (r, want) in reqs.iter().zip(&expected) {
                assert_eq!(
                    &got[&r.id], want,
                    "{method:?} {rule:?} request {}: prefix sharing must be invisible in output",
                    r.id
                );
            }
            let kvm = kv.lock();
            assert_eq!(kvm.active_seqs(), 0, "{method:?} {rule:?}: KV leaked");
        }
    }
}

/// Cross-request batched verification is invisible in output: a
/// concurrent mixed-class workload covering every coordinator `Method` ×
/// `VerifyRule` decodes byte-identically with the scheduler's coalescing
/// on and off, and both match the uncontended one-shot decode. The
/// batched path must actually engage — the coalescing run records
/// batched calls with ≥ 2 sessions — and the unbatched run must never
/// submit one.
#[test]
fn prop_batched_verification_identical_to_unbatched() {
    let methods = [
        Method::Autoregressive,
        Method::Dualistic { draft_k: 4 },
        Method::Polybasic { draft_k: 4, mu: 4 },
    ];
    let rules = [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }];
    let chain = mock_chain(512, 24, 123);
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for &method in &methods {
        for &rule in &rules {
            id += 1;
            let mut r = Request::new(id, vec![2, 7, 1], 16 + (id as usize % 4) * 6);
            r.method = method;
            r.rule = rule;
            r.task = Some(ALL_TASKS[id as usize % ALL_TASKS.len()]);
            r.sampling.seed = 300 + id;
            r.sampling.temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
            reqs.push(r);
        }
    }
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| scheduler::decode(&chain, r).unwrap().tokens).collect();

    let run = |opts: scheduler::SchedulerOpts| {
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
            block_size: 8,
            total_blocks: 512,
            bytes_per_token: 4,
            swap_blocks: 0,
        })));
        let metrics = Arc::new(Metrics::default());
        let now = Instant::now();
        let batch: Vec<QueueEntry> = reqs
            .iter()
            .map(|r| {
                kv.lock().admit(r.id, 60).unwrap();
                QueueEntry::fresh(r.clone(), now)
            })
            .collect();
        let mut got: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
        scheduler::run_batch_opts(&chain, batch, None, reqs.len(), &kv, &metrics, opts, |ev| {
            if let scheduler::BatchEvent::Done { id, response } = ev {
                got.insert(id, response.expect("no faults in this workload").tokens);
            }
        });
        assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
        (got, metrics)
    };
    let (batched, m_on) = run(scheduler::SchedulerOpts { coalesce: true });
    let (unbatched, m_off) = run(scheduler::SchedulerOpts { coalesce: false });
    assert_eq!(batched, unbatched, "coalescing changed some request's committed tokens");
    for (r, want) in reqs.iter().zip(&expected) {
        assert_eq!(
            &batched[&r.id], want,
            "{:?} {:?} request {}: batched serving diverged from one-shot decode",
            r.method, r.rule, r.id
        );
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(m_on.batched_calls.load(ord) > 0, "the coalescing path must actually engage");
    assert!(
        m_on.batch_occupancy.max() >= 2,
        "same-member plans must coalesce into multi-session batches"
    );
    assert_eq!(m_off.engine_calls.load(ord), 0, "coalesce=false must never submit a batch");
}

/// Batcher: every pushed request is popped exactly once, regardless of
/// batch sizing, priorities, or close timing.
#[test]
fn prop_batcher_no_loss_no_dup() {
    for seed in 0..25u64 {
        let mut rng = Pcg32::seeded(seed);
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 1 + rng.next_below(5) as usize,
            max_wait: std::time::Duration::ZERO,
            ..Default::default()
        });
        let n = 1 + rng.next_below(40) as usize;
        let mut pushed = std::collections::BTreeSet::new();
        for id in 0..n as u64 {
            let mut r = Request::new(id, vec![1, 2], 4);
            r.task = Some(ALL_TASKS[rng.next_below(6) as usize]);
            r.method = Method::Autoregressive;
            b.push(r);
            pushed.insert(id);
        }
        b.close();
        let mut popped = std::collections::BTreeSet::new();
        while let Some(batch) = b.pop_batch() {
            for entry in batch {
                assert!(popped.insert(entry.req.id), "seed {seed}: duplicate {}", entry.req.id);
            }
        }
        assert_eq!(pushed, popped, "seed {seed}: lost requests");
    }
}

/// verify_block invariants for random distributions and rules.
#[test]
fn prop_verify_block_invariants() {
    let mut rng = Pcg32::seeded(99);
    for case in 0..300 {
        let vocab = 2 + rng.next_below(30) as usize;
        let len = 1 + rng.next_below(8) as usize;
        let mk_dist = |rng: &mut Pcg32| {
            let logits: Vec<f32> = (0..vocab).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
            softmax(&logits, 1.0)
        };
        let p: Vec<Vec<f32>> = (0..len).map(|_| mk_dist(&mut rng)).collect();
        let q: Vec<Vec<f32>> = (0..len).map(|_| mk_dist(&mut rng)).collect();
        let toks: Vec<i32> = (0..len).map(|_| rng.next_below(vocab as u32) as i32).collect();
        for rule in
            [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.3 }]
        {
            let v = verify_block(&toks, &p, &q, rule, &mut rng);
            assert!(v.accepted <= len, "case {case}");
            assert_eq!(v.replacement.is_none(), v.accepted == len, "case {case}");
            if let Some(r) = v.replacement {
                assert!((r as usize) < vocab, "case {case}: replacement out of vocab");
            }
        }
    }
}

/// Polybasic decode: for random chain configurations the output always has
/// the exact requested length, stays in-vocab, and under greedy equals the
/// target's greedy decode (lossless cascade).
#[test]
fn prop_polybasic_greedy_lossless_random_configs() {
    for seed in 0..15u64 {
        let mut rng = Pcg32::seeded(seed * 31 + 7);
        let vocab = 8 + rng.next_below(24) as usize;
        let n_models = 2 + rng.next_below(3) as usize; // 2..4
        let mut chain: Vec<Arc<dyn LanguageModel>> = vec![Arc::new(MockModel::new(
            "t", 512, vocab, seed, 0.0,
        ))];
        for j in 1..n_models {
            chain.push(Arc::new(MockModel::new(
                &format!("d{j}"),
                512,
                vocab,
                seed,
                0.2 + 0.4 * j as f32,
            )));
        }
        let draft_k = 2 + rng.next_below(5) as usize;
        let mu = 1 + rng.next_below(8) as usize;
        let max_new = 8 + rng.next_below(32) as usize;
        let mut cfg = PolyConfig::for_chain(n_models, draft_k, mu, max_new);
        cfg.rule = VerifyRule::Greedy;
        cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
        let prompt: Vec<i32> =
            (0..3 + rng.next_below(6) as usize).map(|_| rng.next_below(vocab as u32) as i32).collect();

        let out = polybasic::generate(&chain, &prompt, &cfg)
            .unwrap_or_else(|e| panic!("seed {seed} cfg {cfg:?}: {e}"));
        assert_eq!(out.tokens.len(), max_new, "seed {seed}");
        assert!(out.tokens.iter().all(|&t| (t as usize) < vocab), "seed {seed}");

        let ar = autoregressive::generate(chain[0].as_ref(), &prompt, max_new, &cfg.sampling)
            .unwrap();
        assert_eq!(
            out.tokens, ar.tokens,
            "seed {seed} k={draft_k} mu={mu} n={n_models}: greedy output diverged"
        );
    }
}

/// Forward-pass accounting: target forwards + acceptance must be consistent
/// (sum of per-forward committed tokens equals the output length).
#[test]
fn prop_accept_lengths_account_for_output() {
    for seed in 0..10u64 {
        let chain = mock_chain(512, 24, seed);
        let mut cfg = PolyConfig::for_chain(3, 4, 5, 40);
        cfg.sampling.seed = seed;
        let out = polybasic::generate(&chain, &[1, 2, 3], &cfg).unwrap();
        let committed: u32 = out.accept_lengths.iter().sum();
        assert!(
            committed as usize >= out.tokens.len(),
            "seed {seed}: accepted {committed} < emitted {}",
            out.tokens.len()
        );
        assert_eq!(out.accept_lengths.len() as u64, out.forward_passes[0], "seed {seed}");
    }
}

/// Session-based decode must be token-identical to the stateless fallback
/// (ForceStateless hides the mock's cached sessions, so every scoring call
/// re-runs the full prefix — the pre-session behaviour) for every
/// verification rule, across random chain configurations.
#[test]
fn prop_session_decode_identical_to_stateless() {
    for rule in [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }] {
        for seed in 0..6u64 {
            let mut rng = Pcg32::seeded(seed * 131 + 17);
            let vocab = 8 + rng.next_below(24) as usize;
            let n_models = 2 + rng.next_below(2) as usize; // 2..3
            let mk = |stateless: bool| -> Vec<Arc<dyn LanguageModel>> {
                (0..n_models)
                    .map(|j| -> Arc<dyn LanguageModel> {
                        let noise = 0.4 * j as f32;
                        let m = MockModel::new(&format!("m{j}"), 512, vocab, seed, noise);
                        if stateless {
                            Arc::new(ForceStateless(m))
                        } else {
                            Arc::new(m)
                        }
                    })
                    .collect()
            };
            let draft_k = 2 + rng.next_below(5) as usize;
            let mu = 1 + rng.next_below(6) as usize;
            let max_new = 8 + rng.next_below(24) as usize;
            let mut cfg = PolyConfig::for_chain(n_models, draft_k, mu, max_new);
            cfg.rule = rule;
            let temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
            cfg.sampling = SamplingParams { temperature, seed, ..Default::default() };
            let prompt: Vec<i32> = (0..2 + rng.next_below(5) as usize)
                .map(|_| rng.next_below(vocab as u32) as i32)
                .collect();

            let cached = polybasic::generate(&mk(false), &prompt, &cfg)
                .unwrap_or_else(|e| panic!("seed {seed} {rule:?}: {e}"));
            let stateless = polybasic::generate(&mk(true), &prompt, &cfg).unwrap();
            assert_eq!(cached.tokens, stateless.tokens, "seed {seed} rule {rule:?}");
            assert_eq!(
                cached.forward_passes, stateless.forward_passes,
                "seed {seed} rule {rule:?}: call accounting diverged"
            );
            assert_eq!(cached.accept_lengths, stateless.accept_lengths, "seed {seed} {rule:?}");

            // Dualistic gets the same guarantee.
            let dcfg = dualistic::DualisticConfig {
                draft_k,
                rule,
                sampling: cfg.sampling,
                max_new,
            };
            let c = mk(false);
            let s = mk(true);
            let dc = dualistic::generate(c[0].as_ref(), c[n_models - 1].as_ref(), &prompt, &dcfg)
                .unwrap();
            let ds = dualistic::generate(s[0].as_ref(), s[n_models - 1].as_ref(), &prompt, &dcfg)
                .unwrap();
            assert_eq!(dc.tokens, ds.tokens, "dualistic seed {seed} rule {rule:?}");
        }
    }
}

/// Stepped decode tasks must be token-identical to one-shot `generate` for
/// every coordinator `Method` × `VerifyRule`, with matching forward-pass
/// and acceptance accounting, and the per-step committed deltas must
/// concatenate to exactly the final output (the stream a server delivers).
#[test]
fn prop_stepped_task_identical_to_generate_all_methods_rules() {
    let methods = [
        Method::Autoregressive,
        Method::Dualistic { draft_k: 4 },
        Method::Polybasic { draft_k: 4, mu: 5 },
    ];
    for rule in [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }] {
        for &method in &methods {
            for seed in 0..4u64 {
                let chain = mock_chain(512, 24, seed + 50);
                let mut req = Request::new(seed + 1, vec![3, 1, 4], 8 + seed as usize * 9);
                req.method = method;
                req.rule = rule;
                req.sampling = SamplingParams {
                    temperature: if rule == VerifyRule::Greedy { 0.0 } else { 1.0 },
                    seed,
                    ..Default::default()
                };
                let whole = scheduler::decode(&chain, &req)
                    .unwrap_or_else(|e| panic!("{method:?} {rule:?} seed {seed}: {e}"));
                for m in &chain {
                    m.reset_counters();
                }
                let mut task = scheduler::open_task(&chain, &req).unwrap();
                let mut streamed = Vec::new();
                let mut steps = 0;
                while !task.finished() {
                    let before = task.committed().len();
                    let outcome = task.step().unwrap();
                    let after = task.committed().len();
                    assert_eq!(
                        outcome.new_tokens(),
                        after - before,
                        "{method:?} {rule:?} seed {seed}: outcome disagrees with committed()"
                    );
                    streamed.extend_from_slice(&task.committed()[before..]);
                    steps += 1;
                    assert!(steps < 10_000, "{method:?} {rule:?} seed {seed}: runaway task");
                }
                assert_eq!(
                    streamed, whole.tokens,
                    "{method:?} {rule:?} seed {seed}: streamed deltas diverged"
                );
                let out = task.finish();
                assert_eq!(out.tokens, whole.tokens, "{method:?} {rule:?} seed {seed}");
                assert_eq!(
                    out.forward_passes, whole.forward_passes,
                    "{method:?} {rule:?} seed {seed}: call accounting diverged"
                );
                assert_eq!(
                    out.accept_lengths, whole.accept_lengths,
                    "{method:?} {rule:?} seed {seed}"
                );
            }
        }
    }
}

/// CS-Drafting is not a coordinator `Method` (it is bench-only), so its
/// stepped task is covered directly: stepped == one-shot for every rule.
#[test]
fn prop_stepped_csdraft_identical_to_generate() {
    for rule in [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }] {
        let models: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(MockModel::new("t", 512, 24, 5, 0.0)),
            Arc::new(MockModel::new("d1", 512, 24, 5, 0.4)),
            Arc::new(BigramModel::new(512, 24)),
        ];
        let cfg = CsDraftConfig {
            lens: vec![3, 2],
            rule,
            sampling: SamplingParams {
                temperature: if rule == VerifyRule::Greedy { 0.0 } else { 1.0 },
                seed: 7,
                ..Default::default()
            },
            max_new: 25,
        };
        let whole = csdraft::generate(&models, &[4, 2], &cfg).unwrap();
        for m in &models {
            m.reset_counters();
        }
        let mut task = CsDraftTask::new(&models, &[4, 2], cfg).unwrap();
        let mut streamed = Vec::new();
        while !task.finished() {
            let before = task.committed().len();
            task.step().unwrap();
            streamed.extend_from_slice(&task.committed()[before..]);
        }
        assert_eq!(streamed, whole.tokens, "csdraft {rule:?}: streamed deltas diverged");
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, whole.tokens, "csdraft {rule:?}");
        assert_eq!(out.forward_passes, whole.forward_passes, "csdraft {rule:?}");
        assert_eq!(out.stage_accept_lengths, whole.stage_accept_lengths, "csdraft {rule:?}");
    }
}

/// Session invariants under random append / rollback / reconcile walks:
/// rows depend only on the prefix, rollback restores bit-identical rows,
/// and the session always agrees with a from-scratch `forward`.
#[test]
fn prop_session_rollback_bit_identical() {
    for seed in 0..12u64 {
        let mut rng = Pcg32::seeded(seed * 7 + 3);
        let vocab = 4 + rng.next_below(28) as usize;
        let model = MockModel::new("m", 256, vocab, seed, 0.6);
        let mut sess = model.open_session().unwrap();
        let mut shadow: Vec<i32> = Vec::new();
        for _step in 0..60 {
            match rng.next_below(3) {
                0 => {
                    // Append a random chunk (bounded by seq_len).
                    let room = 256 - shadow.len();
                    if room > 0 {
                        let k = 1 + rng.next_below(room.min(7) as u32) as usize;
                        let chunk: Vec<i32> =
                            (0..k).map(|_| rng.next_below(vocab as u32) as i32).collect();
                        shadow.extend_from_slice(&chunk);
                        sess.append(&chunk).unwrap();
                    }
                }
                1 => {
                    // Roll back to a random earlier length.
                    let to = rng.next_below(shadow.len() as u32 + 1) as usize;
                    shadow.truncate(to);
                    sess.rollback(to).unwrap();
                }
                _ => {
                    // Reconcile against a mutated copy (diverge + extend).
                    let mut target = shadow.clone();
                    if !target.is_empty() {
                        let at = rng.next_below(target.len() as u32) as usize;
                        target.truncate(at);
                    }
                    target.push(rng.next_below(vocab as u32) as i32);
                    reconcile(&mut *sess, &target).unwrap();
                    shadow = target;
                }
            }
            assert_eq!(sess.tokens(), &shadow[..], "seed {seed}: prefix diverged");
            assert_eq!(sess.len(), shadow.len(), "seed {seed}");
            if !shadow.is_empty() {
                // Spot-check a random cached row against a fresh forward:
                // bit-identical, not approximately equal.
                let t = rng.next_below(shadow.len() as u32) as usize;
                let fresh = model.forward(&shadow).unwrap();
                assert_eq!(sess.row(t), fresh.row(t), "seed {seed} pos {t}");
            }
        }
    }
}

/// The session API on the trait-object / default path: StatelessSession
/// must satisfy the same invariants as the cached mock session.
#[test]
fn prop_stateless_session_matches_cached() {
    for seed in 0..6u64 {
        let mut rng = Pcg32::seeded(seed + 900);
        let vocab = 6 + rng.next_below(10) as usize;
        let cached_model = MockModel::new("m", 128, vocab, seed, 0.3);
        let stateless_model = ForceStateless(MockModel::new("m", 128, vocab, seed, 0.3));
        let mut cached = cached_model.open_session().unwrap();
        let mut stateless = stateless_model.open_session().unwrap();
        let mut shadow: Vec<i32> = Vec::new();
        for _ in 0..25 {
            if shadow.is_empty() || rng.next_f32() < 0.7 {
                let k = 1 + rng.next_below(5) as usize;
                let chunk: Vec<i32> =
                    (0..k).map(|_| rng.next_below(vocab as u32) as i32).collect();
                shadow.extend_from_slice(&chunk);
                cached.append(&chunk).unwrap();
                stateless.append(&chunk).unwrap();
            } else {
                let to = rng.next_below(shadow.len() as u32 + 1) as usize;
                shadow.truncate(to);
                cached.rollback(to).unwrap();
                stateless.rollback(to).unwrap();
            }
            assert_eq!(cached.len(), stateless.len());
            for t in 0..shadow.len() {
                assert_eq!(cached.row(t), stateless.row(t), "seed {seed} pos {t}");
            }
        }
    }
}

/// JSON writer/parser round-trip over random JSON trees.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f32() < 0.5),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round()),
            3 => Json::Str(format!("s{}-\"x\"\n", rng.next_u32())),
            4 => Json::Arr((0..rng.next_below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Pcg32::seeded(5);
    for case in 0..200 {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(parsed, v, "case {case}");
    }
}

/// Workload generator: queries always fit the v7b admission budget.
#[test]
fn prop_queries_fit_context_budget() {
    let headroom = PolyConfig::for_chain(3, 6, 8, 1).headroom();
    for task in ALL_TASKS {
        for i in 0..50 {
            let q = make_query(task, i, 256);
            assert!(
                q.prompt.len() + q.max_new + headroom <= 160,
                "{task:?} query {i}: {} + {} + {headroom} > 160",
                q.prompt.len(),
                q.max_new
            );
        }
    }
}
