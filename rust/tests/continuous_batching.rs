//! Continuous-batching coordinator tests over mock chains (no artifacts):
//! step-level round-robin, mid-flight admission, streaming, starvation
//! guard, and the no-head-of-line-blocking guarantee.

use std::sync::Arc;
use polyspec::sync::Mutex;
use std::time::{Duration, Instant};

use polyspec::coordinator::api::{DecodeError, Method, Request, Response};
use polyspec::coordinator::batcher::{BatchPolicy, DynamicBatcher, QueueEntry};
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::scheduler::{run_batch, BatchEvent};
use polyspec::spec::mock::mock_chain;
use polyspec::workload::tasks::TaskKind;

const POLY: Method = Method::Polybasic { draft_k: 4, mu: 4 };

fn mk_req(id: u64, max_new: usize, task: TaskKind) -> Request {
    let mut r = Request::new(id, vec![1, 2, 3], max_new);
    r.method = POLY;
    r.task = Some(task);
    r.sampling.seed = id;
    r
}

fn kv_pool() -> Arc<Mutex<KvManager>> {
    Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 16,
        total_blocks: 256,
        bytes_per_token: 4,
        swap_blocks: 0,
    })))
}

/// Replayable record of scheduler events.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Ev {
    Delta { id: u64, n: usize },
    Done { id: u64, ok: bool },
}

fn record(
    log: &mut Vec<Ev>,
    resps: &mut Vec<Result<Response, DecodeError>>,
    ev: BatchEvent<'_>,
) {
    match ev {
        BatchEvent::Delta { id, tokens } => log.push(Ev::Delta { id, n: tokens.len() }),
        BatchEvent::Done { id, response } => {
            log.push(Ev::Done { id, ok: response.is_ok() });
            resps.push(response);
        }
    }
}

/// The tentpole guarantee: a short interactive request admitted from the
/// queue *after* a long batch request started decoding still finishes
/// first — steps interleave instead of whole requests serializing.
#[test]
fn interactive_request_overtakes_long_batch_request() {
    let chain = mock_chain(512, 24, 3);
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    let long = mk_req(1, 200, TaskKind::Summarization);
    let short = mk_req(2, 8, TaskKind::Qa);
    kv.lock().admit(1, 20).unwrap();
    kv.lock().admit(2, 20).unwrap();

    // The long request is already dispatched; the short one is only in the
    // admission queue and must join mid-flight.
    let batcher = DynamicBatcher::new(BatchPolicy::default());
    batcher.push(short);
    let mut log: Vec<Ev> = Vec::new();
    let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
    run_batch(
        &chain,
        vec![QueueEntry::fresh(long, Instant::now())],
        Some(&batcher),
        4,
        &kv,
        &metrics,
        |ev| record(&mut log, &mut out, ev),
    );

    assert_eq!(out.len(), 2);
    let first = out[0].as_ref().unwrap();
    assert_eq!(first.id, 2, "short interactive request must complete first");
    assert_eq!(first.tokens.len(), 8);
    let second = out[1].as_ref().unwrap();
    assert_eq!(second.id, 1);
    assert_eq!(second.tokens.len(), 200);

    // The long request kept decoding after the short one finished.
    let done_short = log
        .iter()
        .position(|e| matches!(e, Ev::Done { id: 2, .. }))
        .expect("short request completion event");
    assert!(
        log[done_short + 1..]
            .iter()
            .any(|e| matches!(e, Ev::Delta { id: 1, .. })),
        "long request should still be mid-decode when the short one finishes"
    );
    // Every event succeeded and the short request's deltas sum to its
    // budget.
    assert!(log.iter().all(|e| !matches!(e, Ev::Done { ok: false, .. })));
    let short_streamed: usize = log
        .iter()
        .filter_map(|e| match e {
            Ev::Delta { id: 2, n } => Some(*n),
            _ => None,
        })
        .sum();
    assert_eq!(short_streamed, 8);
    // Both requests were live at once, and TTFT was recorded for both.
    assert!(metrics.inflight_peak() >= 2, "peak {}", metrics.inflight_peak());
    assert_eq!(metrics.inflight(), 0);
    assert_eq!(metrics.ttft_latency.count(), 2);
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
}

/// Streamed deltas concatenate to exactly the final response tokens, and
/// serving measurements are coherent.
#[test]
fn deltas_concatenate_to_response() {
    let chain = mock_chain(512, 24, 7);
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    let req = mk_req(5, 40, TaskKind::Qa);
    kv.lock().admit(5, 20).unwrap();
    let mut streamed: Vec<i32> = Vec::new();
    let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
    let batch = vec![QueueEntry::fresh(req, Instant::now())];
    run_batch(&chain, batch, None, 1, &kv, &metrics, |ev| match ev {
        BatchEvent::Delta { tokens, .. } => streamed.extend_from_slice(tokens),
        BatchEvent::Done { response, .. } => out.push(response),
    });
    let resp = out[0].as_ref().unwrap();
    assert_eq!(streamed, resp.tokens, "deltas must reassemble the response");
    assert_eq!(resp.tokens.len(), 40);
    assert!(resp.ttft.expect("first token committed") <= resp.queue_time + resp.service_time);
    // KV tracked the live length and grew past the admitted reservation.
    assert!(kv.lock().peak_blocks() > 2, "live-length growth not tracked");
}

/// Starvation guard: under sustained interactive arrivals, a batch-class
/// request older than `starvation_wait` is admitted ahead of them.
#[test]
fn starved_batch_request_admitted_under_interactive_load() {
    let chain = mock_chain(512, 24, 11);
    let kv = kv_pool();
    let metrics = Arc::new(Metrics::default());
    let batcher = DynamicBatcher::new(BatchPolicy {
        max_batch: 1,
        max_wait: Duration::ZERO,
        starvation_wait: Duration::from_millis(10),
    });
    for id in 1..=4u64 {
        kv.lock().admit(id, 20).unwrap();
    }
    batcher.push(mk_req(1, 12, TaskKind::Summarization)); // batch class
    std::thread::sleep(Duration::from_millis(15)); // starve it
    for id in 2..=4 {
        batcher.push(mk_req(id, 12, TaskKind::Qa)); // interactive wave
    }
    // max_live = 1 serializes admission, so completion order == admission
    // order; the starved batch request must come first.
    let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
    run_batch(&chain, Vec::new(), Some(&batcher), 1, &kv, &metrics, |ev| {
        if let BatchEvent::Done { response, .. } = ev {
            out.push(response);
        }
    });
    assert_eq!(out.len(), 4);
    let ids: Vec<u64> = out.iter().map(|r| r.as_ref().unwrap().id).collect();
    assert_eq!(ids[0], 1, "starved batch request must be admitted first, got {ids:?}");
    assert_eq!(kv.lock().active_seqs(), 0);
}

/// A pool smaller than one lone request's live footprint is genuine
/// capacity overflow: no preemption can help (there is nothing to evict
/// and the footprint exceeds the whole pool), so the request fails cleanly
/// and releases its allocation. Pool pressure with *other* work to evict
/// preempts instead — see `tests/preemption.rs`.
#[test]
fn kv_pool_smaller_than_one_request_fails_cleanly() {
    let chain = mock_chain(512, 24, 13);
    // Tiny pool: 2 blocks of 16 = 32 tokens.
    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 16,
        total_blocks: 2,
        bytes_per_token: 4,
        swap_blocks: 0,
    })));
    let metrics = Arc::new(Metrics::default());
    // Needs 3 + 100 + headroom tokens live by the end — far over the pool.
    let req = mk_req(9, 100, TaskKind::Qa);
    kv.lock().admit(9, 20).unwrap();
    let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
    let batch = vec![QueueEntry::fresh(req, Instant::now())];
    run_batch(&chain, batch, None, 1, &kv, &metrics, |ev| {
        if let BatchEvent::Done { response, .. } = ev {
            out.push(response);
        }
    });
    assert_eq!(out.len(), 1);
    assert!(out[0].is_err(), "overgrown request must fail, not overcommit");
    assert_eq!(kv.lock().active_seqs(), 0, "failed request must release KV");
    assert_eq!(metrics.inflight(), 0);
    assert_eq!(
        metrics.requests_failed.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "the failure must be counted"
    );
    assert_eq!(
        metrics.preemptions.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "nothing to evict: this is capacity overflow, not pool pressure"
    );
}
