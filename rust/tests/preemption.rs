//! Preempt-and-resume under KV saturation.
//!
//! The live-length admission policy deliberately overcommits the KV pool,
//! so mid-decode `grow` calls hit a saturated pool under load. These tests
//! pin the contract that replaced fail-on-grow: pool pressure suspends and
//! later resumes decode tasks, and the preemption is **invisible in
//! output** — every response's tokens are byte-identical to the same
//! request decoded uncontended, nothing fails, and the metrics account for
//! every suspension. With a swap tier configured, victims suspend to swap
//! and restore without re-scoring — same byte-identity, strictly less
//! wasted recompute than the discard path.

use std::sync::Arc;
use polyspec::sync::Mutex;
use std::time::Instant;

use polyspec::coordinator::api::{DecodeError, Method, Request, Response};
use polyspec::coordinator::batcher::{BatchPolicy, DynamicBatcher, QueueEntry};
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::router::pipeline_headroom;
use polyspec::coordinator::scheduler::{decode, run_batch, select_victim, BatchEvent, VictimInfo};
use polyspec::spec::mock::mock_chain;
use polyspec::spec::types::{LanguageModel, VerifyRule};
use polyspec::workload::tasks::TaskKind;

/// Every coordinator Method crossed with every VerifyRule, with varied
/// budgets, seeds, and scheduling classes.
fn mixed_workload() -> Vec<Request> {
    let methods = [
        Method::Polybasic { draft_k: 4, mu: 4 },
        Method::Dualistic { draft_k: 4 },
        Method::Autoregressive,
    ];
    let rules = [VerifyRule::Greedy, VerifyRule::Speculative, VerifyRule::Typical { eps: 0.25 }];
    let tasks = [TaskKind::Qa, TaskKind::Summarization, TaskKind::Math];
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for &method in &methods {
        for &rule in &rules {
            id += 1;
            let mut r = Request::new(id, vec![1, 2, 3], 24 + (id as usize % 3) * 8);
            r.method = method;
            r.rule = rule;
            r.task = Some(tasks[id as usize % 3]);
            r.sampling.seed = 1000 + id;
            r.sampling.temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
            reqs.push(r);
        }
    }
    reqs
}

/// Admit a request the way the router does: prompt + speculative headroom,
/// through the fresh-arrival path that honors resume debt.
fn router_admit(kv: &Arc<Mutex<KvManager>>, chain_len: usize, req: &Request) {
    let need = req.prompt.len() + pipeline_headroom(&req.method, chain_len);
    kv.lock().admit_fresh(req.id, need).unwrap();
}

/// Per-request concatenation of streamed deltas.
type Streams = std::collections::BTreeMap<u64, Vec<i32>>;

fn drive(
    chain: &[Arc<dyn LanguageModel>],
    batch: Vec<QueueEntry>,
    admit: Option<&DynamicBatcher>,
    max_live: usize,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
) -> (Vec<Result<Response, DecodeError>>, Streams) {
    let mut out = Vec::new();
    let mut streams: Streams = Default::default();
    run_batch(chain, batch, admit, max_live, kv, metrics, |ev| match ev {
        BatchEvent::Delta { id, tokens } => {
            streams.entry(id).or_default().extend_from_slice(tokens)
        }
        BatchEvent::Done { response, .. } => out.push(response),
    });
    (out, streams)
}

/// THE acceptance property: a workload that exhausts the KV pool
/// mid-decode (previously `Err("KV pool exhausted growing seq …")`) now
/// completes **all** requests with byte-identical tokens to an uncontended
/// run, with at least one preemption and zero request failures.
#[test]
fn prop_saturated_pool_preempts_and_completes_byte_identically() {
    let chain = mock_chain(512, 24, 77);
    let reqs = mixed_workload();

    // Uncontended reference: each request decoded alone through the same
    // Method dispatch the scheduler uses.
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| decode(&chain, r).unwrap().tokens).collect();

    // Deliberately tiny pool: all nine live-length admissions fit (the
    // router's overcommit), but their growth demand is several times the
    // pool — growth MUST saturate, and no single request exceeds the pool,
    // so every saturation is resolvable by eviction.
    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 4,
        total_blocks: 26,
        bytes_per_token: 4,
        swap_blocks: 0,
    })));
    let metrics = Arc::new(Metrics::default());
    let now = Instant::now();
    let batch: Vec<QueueEntry> = reqs
        .iter()
        .map(|r| {
            router_admit(&kv, chain.len(), r);
            QueueEntry::fresh(r.clone(), now)
        })
        .collect();

    let (out, streams) = drive(&chain, batch, None, reqs.len(), &kv, &metrics);

    assert_eq!(out.len(), reqs.len());
    let mut by_id: std::collections::BTreeMap<u64, Response> = Default::default();
    for r in out {
        let resp = r.expect("pool pressure must never fail a request");
        by_id.insert(resp.id, resp);
    }
    for (req, want) in reqs.iter().zip(&expected) {
        let resp = &by_id[&req.id];
        assert_eq!(
            &resp.tokens, want,
            "request {} ({:?} {:?}): preemption must be invisible in output",
            req.id, req.method, req.rule
        );
        assert_eq!(
            &streams[&req.id], want,
            "request {}: streamed deltas must reassemble exactly once",
            req.id
        );
    }

    let preemptions = metrics.preemptions.load(std::sync::atomic::Ordering::Relaxed);
    let resumes = metrics.resumes.load(std::sync::atomic::Ordering::Relaxed);
    assert!(preemptions >= 1, "the pool must have saturated at least once");
    assert_eq!(resumes, preemptions, "every preempted request must resume exactly once");
    let per_request: u64 = by_id.values().map(|r| r.preemptions as u64).sum();
    assert_eq!(
        per_request, preemptions,
        "per-response preemption counts must account for every eviction"
    );
    assert_eq!(kv.lock().resume_debt(), 0, "all resume debt must settle");
    assert!(
        metrics.wasted_recompute_tokens.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "resumes re-score their prefix; the gauge must show it"
    );
    assert_eq!(metrics.requests_failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert_eq!(
        metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        reqs.len() as u64
    );
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
    assert_eq!(metrics.inflight(), 0);
}

/// Same property through the shared admission queue: victims re-enter via
/// `DynamicBatcher::push_front_resumed` and are re-admitted between steps,
/// with queued (not-yet-live) requests' reservations adding pressure.
#[test]
fn preemption_via_batcher_resumed_lane_completes_all() {
    let chain = mock_chain(512, 24, 91);
    let reqs: Vec<Request> = mixed_workload().into_iter().take(6).collect();
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| decode(&chain, r).unwrap().tokens).collect();

    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 4,
        total_blocks: 24,
        bytes_per_token: 4,
        swap_blocks: 0,
    })));
    let metrics = Arc::new(Metrics::default());
    let batcher = DynamicBatcher::new(BatchPolicy {
        max_batch: 3,
        max_wait: std::time::Duration::ZERO,
        ..Default::default()
    });
    for r in &reqs {
        router_admit(&kv, chain.len(), r);
        batcher.push(r.clone());
    }

    // One worker, three live slots: live tasks grow while queued requests
    // hold reservations, so saturation resolves by preempting live work.
    let (out, streams) = drive(&chain, Vec::new(), Some(&batcher), 3, &kv, &metrics);

    assert_eq!(out.len(), reqs.len());
    let mut by_id: std::collections::BTreeMap<u64, Response> = Default::default();
    for r in out {
        let resp = r.expect("pool pressure must never fail a request");
        by_id.insert(resp.id, resp);
    }
    for (req, want) in reqs.iter().zip(&expected) {
        assert_eq!(&by_id[&req.id].tokens, want, "request {} diverged", req.id);
        assert_eq!(&streams[&req.id], want, "request {} stream diverged", req.id);
    }
    assert!(
        metrics.preemptions.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "the pool must have saturated at least once"
    );
    assert_eq!(metrics.requests_failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert!(batcher.is_empty(), "resumed lane must drain");
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
    assert_eq!(kv.lock().resume_debt(), 0, "all resume debt must settle");
}

/// Suspend-to-swap vs discard, on the same scripted saturating workload:
/// run it once with swap disabled (the discard path — every resume
/// re-scores its prefix) and once with a swap tier large enough for every
/// victim. Both runs complete all requests byte-identically to the
/// uncontended decode; the swap run restores every victim's KV from the
/// tier, so its wasted-recompute gauge reads exactly zero — strictly fewer
/// wasted tokens than the discard run on the same scenario.
#[test]
fn swap_tier_eliminates_resume_recompute_byte_identically() {
    let chain = mock_chain(512, 24, 33);
    let reqs = mixed_workload();
    let expected: Vec<Vec<i32>> =
        reqs.iter().map(|r| decode(&chain, r).unwrap().tokens).collect();

    let run = |swap_blocks: usize| {
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
            block_size: 4,
            total_blocks: 26,
            bytes_per_token: 4,
            swap_blocks,
        })));
        let metrics = Arc::new(Metrics::default());
        kv.lock().attach_metrics(metrics.clone());
        let now = Instant::now();
        let batch: Vec<QueueEntry> = reqs
            .iter()
            .map(|r| {
                router_admit(&kv, chain.len(), r);
                QueueEntry::fresh(r.clone(), now)
            })
            .collect();
        let (out, _) = drive(&chain, batch, None, reqs.len(), &kv, &metrics);
        (out, kv, metrics)
    };

    let (discard_out, _, discard_metrics) = run(0);
    // 128 swap blocks: even all victims suspended at once (each holding
    // prompt + committed + in-flight draft, ~11 blocks of 4) fit, so every
    // preemption in this run must take the swap path.
    let (swap_out, swap_kv, swap_metrics) = run(128);

    for (label, out) in [("discard", discard_out), ("swap", swap_out)] {
        let mut by_id: std::collections::BTreeMap<u64, Response> = Default::default();
        for r in out {
            let resp = r.expect("pool pressure must never fail a request");
            by_id.insert(resp.id, resp);
        }
        for (req, want) in reqs.iter().zip(&expected) {
            assert_eq!(
                &by_id[&req.id].tokens, want,
                "{label} run, request {}: swap state must be invisible in output",
                req.id
            );
        }
    }

    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(discard_metrics.preemptions.load(ord) >= 1, "scenario must saturate");
    assert!(swap_metrics.preemptions.load(ord) >= 1, "scenario must saturate with swap too");
    let wasted_discard = discard_metrics.wasted_recompute_tokens.load(ord);
    let wasted_swap = swap_metrics.wasted_recompute_tokens.load(ord);
    assert!(wasted_discard > 0, "discard resumes re-score their prefix");
    assert_eq!(wasted_swap, 0, "a big-enough swap tier restores every victim's KV in full");
    assert!(wasted_swap < wasted_discard, "swap must beat discard on wasted recompute");
    assert!(swap_metrics.swapped_blocks.load(ord) > 0, "victims must actually swap out");
    assert!(
        swap_metrics.restore_tokens_saved.load(ord) > 0,
        "restores must credit the recompute they avoided"
    );
    assert_eq!(
        discard_metrics.swapped_blocks.load(ord),
        0,
        "a zero-block tier must never accept a victim"
    );
    let kvm = swap_kv.lock();
    assert_eq!(kvm.swapped_blocks(), 0, "the swap tier must drain by completion");
    assert_eq!(kvm.resume_debt(), 0, "all resume debt must settle");
    assert_eq!(kvm.active_seqs(), 0, "KV leaked");
    assert!(kvm.restore_tokens_saved() > 0, "manager-level counter mirrors the metric");
}

/// The victim policy, end to end at the data level: batch-class before
/// interactive, then the largest KV holding, never the empty set.
#[test]
fn victim_selection_class_then_cost() {
    // Mixed classes: the batch-class task loses even when interactive
    // tasks hold more KV.
    let picked = select_victim([
        VictimInfo { index: 0, interactive: true, kv_blocks: 40 },
        VictimInfo { index: 1, interactive: false, kv_blocks: 1 },
        VictimInfo { index: 2, interactive: true, kv_blocks: 90 },
    ]);
    assert_eq!(picked, Some(1), "batch class must be evicted before interactive");
    // Homogeneous class: largest holding first.
    let picked = select_victim([
        VictimInfo { index: 0, interactive: false, kv_blocks: 4 },
        VictimInfo { index: 1, interactive: false, kv_blocks: 12 },
        VictimInfo { index: 2, interactive: false, kv_blocks: 8 },
    ]);
    assert_eq!(picked, Some(1), "largest holding frees the most pool");
    assert_eq!(select_victim(Vec::<VictimInfo>::new()), None);
}

/// Zero-commit requests under the same harness: no TTFT is recorded and
/// the response reports `None` rather than a fabricated latency.
#[test]
fn zero_token_request_has_no_ttft_even_under_pressure() {
    let chain = mock_chain(512, 24, 11);
    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 4,
        total_blocks: 32,
        bytes_per_token: 4,
        swap_blocks: 0,
    })));
    let metrics = Arc::new(Metrics::default());
    let mut zero = Request::new(1, vec![1, 2, 3], 0);
    zero.method = Method::Autoregressive;
    zero.task = Some(TaskKind::Qa);
    let mut busy = Request::new(2, vec![1, 2, 3], 32);
    busy.method = Method::Polybasic { draft_k: 4, mu: 4 };
    busy.task = Some(TaskKind::Qa);
    busy.sampling.seed = 5;
    router_admit(&kv, chain.len(), &zero);
    router_admit(&kv, chain.len(), &busy);
    let now = Instant::now();
    let batch = vec![QueueEntry::fresh(zero, now), QueueEntry::fresh(busy, now)];
    let (out, _) = drive(&chain, batch, None, 2, &kv, &metrics);
    let mut ttfts: std::collections::BTreeMap<u64, Option<std::time::Duration>> =
        Default::default();
    for r in out {
        let resp = r.unwrap();
        ttfts.insert(resp.id, resp.ttft);
    }
    assert_eq!(ttfts[&1], None, "zero-commit request must report no TTFT");
    assert!(ttfts[&2].is_some(), "the committing request still gets one");
    assert_eq!(metrics.ttft_latency.count(), 1, "only real first tokens enter the histogram");
}
