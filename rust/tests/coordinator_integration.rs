//! End-to-end coordinator tests over the real artifacts: the full
//! router -> batcher -> worker -> engine path.

use polyspec::coordinator::{Method, Server, ServerConfig, StreamItem};
use polyspec::workload::tasks::{make_query, TaskKind};

fn artifacts_ready() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn server() -> Server {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Server::start(ServerConfig::new(dir, "v7b")).expect("server start")
}

#[test]
fn serves_all_methods_end_to_end() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let server = server();
    let mut rxs = Vec::new();
    for (i, method) in [
        Method::Polybasic { draft_k: 6, mu: 8 },
        Method::Dualistic { draft_k: 4 },
        Method::Autoregressive,
    ]
    .into_iter()
    .enumerate()
    {
        let q = make_query(TaskKind::Qa, i as u64, 256);
        let rx = server
            .submit(q.prompt, 16, method, Some(TaskKind::Qa))
            .expect("submit");
        rxs.push((method, rx));
    }
    for (method, rx) in rxs {
        let resp = rx
            .recv_timeout(std::time::Duration::from_secs(300))
            .expect("response")
            .expect("decode");
        assert_eq!(resp.tokens.len(), 16, "{method:?}");
        assert!(resp.tokens.iter().all(|&t| (0..256).contains(&t)));
        assert!(resp.service_time.as_millis() > 0);
    }
    assert!(server.quiesce(std::time::Duration::from_secs(10)));
    // All KV released once the queue is drained.
    assert_eq!(server.kv_utilization(), 0.0);
    let metrics = server.shutdown();
    assert_eq!(
        metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        3
    );
    let snap = metrics.snapshot().to_string();
    assert!(snap.contains("tokens_generated"));
}

#[test]
fn streamed_deltas_reassemble_the_final_response() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let server = server();
    let q = make_query(TaskKind::Qa, 3, 256);
    let rx = server
        .submit_stream(q.prompt, 12, Method::Polybasic { draft_k: 6, mu: 8 }, Some(TaskKind::Qa))
        .expect("submit_stream");
    let mut streamed = Vec::new();
    let mut done = None;
    while let Ok(item) = rx.recv_timeout(std::time::Duration::from_secs(300)) {
        match item {
            StreamItem::Delta(tokens) => {
                assert!(!tokens.is_empty(), "empty delta");
                streamed.extend(tokens);
            }
            StreamItem::Done(resp) => {
                done = Some(resp);
                break;
            }
            StreamItem::Failed(e) => panic!("decode failed: {e}"),
        }
    }
    let resp = done.expect("stream must end with Done");
    assert_eq!(streamed, resp.tokens, "deltas must reassemble the response");
    assert_eq!(resp.tokens.len(), 12);
    assert!(resp.ttft.expect("first token") <= resp.queue_time + resp.service_time);
    assert!(server.quiesce(std::time::Duration::from_secs(10)));
    let metrics = server.shutdown();
    assert_eq!(metrics.ttft_latency.count(), 1);
}

#[test]
fn rejects_oversized_and_counts_it() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let server = server();
    let err = server
        .submit(vec![1; 150], 100, Method::Polybasic { draft_k: 6, mu: 8 }, None)
        .expect_err("should reject");
    let msg = format!("{err}");
    assert!(msg.contains("context overflow"), "{msg}");
    let metrics = server.shutdown();
    assert_eq!(
        metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
}

#[test]
fn concurrent_submissions_all_complete() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let server = server();
    let n = 6;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let task = polyspec::workload::ALL_TASKS[i % 6];
            let q = make_query(task, i as u64, 256);
            server
                .submit(q.prompt, 12, Method::Polybasic { draft_k: 6, mu: 8 }, Some(task))
                .expect("submit")
        })
        .collect();
    for rx in rxs {
        let resp = rx
            .recv_timeout(std::time::Duration::from_secs(600))
            .expect("response")
            .expect("decode");
        assert_eq!(resp.tokens.len(), 12);
    }
    let metrics = server.shutdown();
    assert_eq!(
        metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
}
