//! Synchronization facade: `std::sync` in production, [`loom`] under
//! `--cfg loom`.
//!
//! Every module in the concurrency core (`coordinator::scheduler`,
//! `coordinator::kv` + `coordinator::paged`, `DynamicBatcher`,
//! `spec::types::HealthTracker`, `runtime::host`) imports its primitives
//! from here instead of `std::sync`/`std::time`/`std::thread` directly
//! (`cargo xtask check` enforces this). In a normal build everything below
//! is a pure re-export or a `#[repr(transparent)]`-equivalent newtype over
//! the `std` primitive, so the facade has **zero runtime cost** and the
//! byte-identity suites see exactly the code they always saw. Under
//! `RUSTFLAGS="--cfg loom"` the same names resolve to [`loom`]'s
//! model-checked primitives, and `rust/tests/loom_models.rs` explores the
//! bounded interleavings of the delicate protocols.
//!
//! Deviations from a 1:1 re-export, and why:
//!
//! * [`Mutex::lock`] / [`Condvar::wait`] return the guard directly
//!   (parking_lot style), recovering from poisoning via
//!   [`PoisonError::into_inner`](std::sync::PoisonError::into_inner). The
//!   serving stack treats a panicking peer as a failed component (typed
//!   faults, breakers), never as a reason to cascade panics through every
//!   lock site — and the panic-free lint bans the `.lock().unwrap()`
//!   idiom anyway.
//! * [`Arc`] is always `std::sync::Arc`, even under loom: loom's `Arc`
//!   cannot coerce to `Arc<dyn Trait>` (unsized coercion is not
//!   implementable outside `std`), and the codebase shares
//!   `Arc<dyn LanguageModel>` pervasively. `Arc` is pure memory
//!   management here; the protocols under test live in the mutexes,
//!   condvars and atomics, which are loom's.
//! * Under loom there is no time: [`time::Instant`] is a logical stub
//!   whose `now()` is always zero, [`thread::sleep`] is a yield, and
//!   [`Condvar::wait_timeout`] never times out (a schedule that depends on
//!   a timeout firing must be modeled explicitly). Deadline- and
//!   cooldown-dependent code paths take an explicit `now: Instant`
//!   parameter (`HealthTracker::healthy_at` and friends) so models can
//!   drive the clock.

use std::time::Duration;

pub use std::sync::Arc;

#[cfg(not(loom))]
use std::sync as imp;

#[cfg(loom)]
use loom::sync as imp;

/// Atomic integers and [`Ordering`](std::sync::atomic::Ordering).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

/// Guard type of [`Mutex::lock`]: the backend's own guard, so condvar
/// waits can consume and return it.
pub type MutexGuard<'a, T> = imp::MutexGuard<'a, T>;

/// Mutual exclusion with a non-poisoning, guard-returning [`lock`]
/// (parking_lot-style API over the `std`/`loom` mutex).
///
/// [`lock`]: Mutex::lock
pub struct Mutex<T>(imp::Mutex<T>);

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Self(imp::Mutex::new(value))
    }

    /// Acquire the lock, recovering the data if a previous holder
    /// panicked. The panicking thread's own failure is surfaced through
    /// the fault/breaker layer, not by poisoning every peer.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.0.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

/// Result of [`Condvar::wait_timeout`]. Own type (not `std`'s) so the
/// loom backend, which has no time, can report "did not time out".
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Condition variable paired with [`Mutex`]; waits recover from
/// poisoning the same way [`Mutex::lock`] does.
pub struct Condvar(imp::Condvar);

impl Condvar {
    pub fn new() -> Self {
        Self(imp::Condvar::new())
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.0.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Wait with a timeout. Under loom the timeout never fires (loom has
    /// no clock): a protocol whose liveness depends on the timeout firing
    /// deadlocks in the model — which is exactly the signal that it needs
    /// an explicit wakeup instead.
    #[cfg(not(loom))]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        let (guard, res) =
            self.0.wait_timeout(guard, dur).unwrap_or_else(std::sync::PoisonError::into_inner);
        (guard, WaitTimeoutResult { timed_out: res.timed_out() })
    }

    #[cfg(loom)]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        (self.wait(guard), WaitTimeoutResult { timed_out: false })
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Condvar")
    }
}

/// Threads: `std::thread` in production, loom's model threads under
/// `--cfg loom` (where `sleep` degenerates to a yield).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{sleep, spawn, yield_now, Builder, JoinHandle};

    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};

    /// Loom has no clock: sleeping is just an invitation to reschedule.
    #[cfg(loom)]
    pub fn sleep(_dur: std::time::Duration) {
        loom::thread::yield_now();
    }

    /// Minimal stand-in for `std::thread::Builder` (loom spawns have no
    /// builder); the name is accepted and dropped.
    #[cfg(loom)]
    #[derive(Debug, Default)]
    pub struct Builder {
        name: Option<String>,
    }

    #[cfg(loom)]
    impl Builder {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn name(mut self, name: String) -> Self {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            Ok(spawn(f))
        }
    }
}

/// Monotonic time. In production this is `std::time::Instant`; under loom
/// it is a logical clock whose `now()` is always zero — code that must
/// behave differently across time takes an explicit `now` parameter so
/// models can fabricate instants (`Instant::now() + cooldown`).
pub mod time {
    pub use std::time::Duration;

    #[cfg(not(loom))]
    pub use std::time::Instant;

    #[cfg(loom)]
    pub use stub::Instant;

    #[cfg(loom)]
    mod stub {
        use std::ops::{Add, AddAssign, Sub};
        use std::time::Duration;

        /// Logical instant for loom builds: a nanosecond counter with no
        /// connection to wall time. `now()` is the epoch; models advance
        /// the clock by adding `Duration`s.
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
        pub struct Instant {
            nanos: u128,
        }

        impl Instant {
            pub fn now() -> Self {
                Self { nanos: 0 }
            }

            pub fn elapsed(&self) -> Duration {
                Self::now().saturating_duration_since(*self)
            }

            pub fn duration_since(&self, earlier: Instant) -> Duration {
                self.saturating_duration_since(earlier)
            }

            pub fn saturating_duration_since(&self, earlier: Instant) -> Duration {
                let nanos = self.nanos.saturating_sub(earlier.nanos);
                Duration::from_secs((nanos / 1_000_000_000) as u64)
                    + Duration::from_nanos((nanos % 1_000_000_000) as u64)
            }

            pub fn checked_add(&self, dur: Duration) -> Option<Instant> {
                self.nanos.checked_add(dur.as_nanos()).map(|nanos| Instant { nanos })
            }

            pub fn checked_sub(&self, dur: Duration) -> Option<Instant> {
                self.nanos.checked_sub(dur.as_nanos()).map(|nanos| Instant { nanos })
            }
        }

        impl Add<Duration> for Instant {
            type Output = Instant;
            fn add(self, dur: Duration) -> Instant {
                Instant { nanos: self.nanos.saturating_add(dur.as_nanos()) }
            }
        }

        impl AddAssign<Duration> for Instant {
            fn add_assign(&mut self, dur: Duration) {
                *self = *self + dur;
            }
        }

        impl Sub<Duration> for Instant {
            type Output = Instant;
            fn sub(self, dur: Duration) -> Instant {
                Instant { nanos: self.nanos.saturating_sub(dur.as_nanos()) }
            }
        }

        impl Sub<Instant> for Instant {
            type Output = Duration;
            fn sub(self, earlier: Instant) -> Duration {
                self.saturating_duration_since(earlier)
            }
        }
    }
}

/// Multi-producer single-consumer channels. In production this is
/// `std::sync::mpsc` verbatim. Under loom it is a small shim over the
/// facade's own `Mutex`/`Condvar` (loom has no `recv_timeout`):
/// `recv_timeout` blocks like `recv` and can only return `Disconnected`,
/// never `Timeout`.
#[cfg(not(loom))]
pub mod mpsc {
    pub use std::sync::mpsc::{
        channel, Receiver, RecvError, RecvTimeoutError, SendError, Sender, TryRecvError,
    };
}

#[cfg(loom)]
pub mod mpsc {
    use std::collections::VecDeque;
    use std::sync::Arc;
    use std::time::Duration;

    use super::{Condvar, Mutex};

    #[derive(Debug)]
    pub struct SendError<T>(pub T);

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct RecvError;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum RecvTimeoutError {
        Timeout,
        Disconnected,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum TryRecvError {
        Empty,
        Disconnected,
    }

    struct State<T> {
        queue: VecDeque<T>,
        senders: usize,
        receiver_alive: bool,
    }

    struct Chan<T> {
        state: Mutex<State<T>>,
        cv: Condvar,
    }

    pub struct Sender<T>(Arc<Chan<T>>);

    pub struct Receiver<T>(Arc<Chan<T>>);

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let chan = Arc::new(Chan {
            state: Mutex::new(State { queue: VecDeque::new(), senders: 1, receiver_alive: true }),
            cv: Condvar::new(),
        });
        (Sender(chan.clone()), Receiver(chan))
    }

    impl<T> Sender<T> {
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            let mut st = self.0.state.lock();
            if !st.receiver_alive {
                return Err(SendError(value));
            }
            st.queue.push_back(value);
            self.0.cv.notify_one();
            Ok(())
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            self.0.state.lock().senders += 1;
            Sender(self.0.clone())
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut st = self.0.state.lock();
            st.senders -= 1;
            if st.senders == 0 {
                self.0.cv.notify_all();
            }
        }
    }

    impl<T> std::fmt::Debug for Sender<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("Sender")
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut st = self.0.state.lock();
            loop {
                if let Some(v) = st.queue.pop_front() {
                    return Ok(v);
                }
                if st.senders == 0 {
                    return Err(RecvError);
                }
                st = self.0.cv.wait(st);
            }
        }

        /// Blocks like [`recv`](Self::recv): loom has no clock, so the
        /// timeout can never fire inside a model.
        pub fn recv_timeout(&self, _dur: Duration) -> Result<T, RecvTimeoutError> {
            self.recv().map_err(|RecvError| RecvTimeoutError::Disconnected)
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            let mut st = self.0.state.lock();
            match st.queue.pop_front() {
                Some(v) => Ok(v),
                None if st.senders == 0 => Err(TryRecvError::Disconnected),
                None => Err(TryRecvError::Empty),
            }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            self.0.state.lock().receiver_alive = false;
        }
    }

    impl<T> std::fmt::Debug for Receiver<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("Receiver")
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn mutex_lock_returns_guard_directly() {
        let m = Mutex::new(41);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 42);
    }

    #[test]
    fn mutex_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*m.lock(), 7, "data survives a panicking holder");
    }

    #[test]
    fn condvar_wait_timeout_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let guard = m.lock();
        let (_guard, res) = cv.wait_timeout(guard, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
