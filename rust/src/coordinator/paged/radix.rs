//! Block-granular radix prefix cache (mistralrs `PrefixCacheManager` /
//! vLLM prefix-caching shape, adapted to block tables).
//!
//! The trie is keyed on **full block chunks** of token content: each node
//! owns one `block_size`-token chunk and the [`BlockId`] whose (simulated)
//! KV rows score exactly that chunk given the path above it. The cache
//! holds one pool reference per node, so a cached block outlives the
//! sequence that wrote it and later requests sharing the prompt prefix map
//! it instead of re-allocating (and, in a real engine, re-scoring) it.
//!
//! [`lookup`](RadixCache::lookup) walks exact full-chunk matches and then
//! tries one *partial* match inside the next chunk — the caller shares that
//! tail block copy-on-write. [`register`](RadixCache::register) inserts a
//! finished (or admitted) sequence's full blocks, but only blocks the
//! sequence owns exclusively: a still-shared tail block may have been
//! logically overwritten past the shared prefix, so attributing its cached
//! content to a new chunk key would lie about what the rows score.
//!
//! Eviction is LRU at node granularity: only nodes whose block has no
//! sequence mapping it (pool refcount 1 — the cache's own reference) are
//! evictable, and evicting a node removes its whole subtree (a child's
//! rows are meaningless without the prefix above them). The `KvManager`
//! counts evictable nodes as available capacity and evicts on demand, so
//! caching never rejects an admission the uncached allocator would accept.

use std::collections::BTreeMap;

use crate::spec::types::Token;

use super::block::{BlockId, BlockPool};

#[derive(Debug)]
struct RadixNode {
    /// This node's `block_size`-token content chunk (the map key, kept here
    /// too so subtree removal can detach from the parent).
    chunk: Vec<Token>,
    block: BlockId,
    parent: Option<usize>,
    children: BTreeMap<Vec<Token>, usize>,
    /// Logical LRU clock value of the last lookup/register touching this
    /// node.
    last_used: u64,
}

/// Result of a prefix lookup: the longest cached prefix and the blocks
/// covering it (`tokens.div_ceil(block_size)` of them; the last one is a
/// partial match when `tokens % block_size != 0`).
#[derive(Debug)]
pub struct PrefixMatch {
    pub tokens: usize,
    pub blocks: Vec<BlockId>,
}

/// Trie of cached token prefixes at block granularity.
#[derive(Debug)]
pub struct RadixCache {
    block_size: usize,
    /// Node arena; `None` slots are free for reuse.
    nodes: Vec<Option<RadixNode>>,
    free_slots: Vec<usize>,
    root_children: BTreeMap<Vec<Token>, usize>,
    clock: u64,
    len: usize,
}

impl RadixCache {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        Self {
            block_size,
            nodes: Vec::new(),
            free_slots: Vec::new(),
            root_children: BTreeMap::new(),
            clock: 0,
            len: 0,
        }
    }

    /// Cached nodes (== cached blocks: node ↔ block is one-to-one).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn node(&self, idx: usize) -> &RadixNode {
        // xtask:allow(panic): indices come from the tree's own links; slots
        // are only vacated by remove_subtree, which unlinks them first.
        self.nodes[idx].as_ref().expect("live radix node")
    }

    fn node_mut(&mut self, idx: usize) -> &mut RadixNode {
        // xtask:allow(panic): same arena invariant as `node` above.
        self.nodes[idx].as_mut().expect("live radix node")
    }

    fn children_of(&self, cur: Option<usize>) -> &BTreeMap<Vec<Token>, usize> {
        match cur {
            None => &self.root_children,
            Some(ix) => &self.node(ix).children,
        }
    }

    /// Longest cached prefix of `tokens`: exact full-chunk descent, then at
    /// most one partial match inside the next chunk. Touches the matched
    /// path's LRU clocks. Does **not** change refcounts — the caller
    /// increfs the returned blocks if it decides to share them.
    pub fn lookup(&mut self, tokens: &[Token]) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        let b = self.block_size;
        let mut blocks = Vec::new();
        let mut i = 0usize;
        let mut cur: Option<usize> = None;
        loop {
            // Exact full-chunk child?
            let exact = if i + b <= tokens.len() {
                self.children_of(cur).get(&tokens[i..i + b]).copied()
            } else {
                None
            };
            if let Some(child) = exact {
                let node = self.node_mut(child);
                node.last_used = clock;
                blocks.push(node.block);
                i += b;
                cur = Some(child);
                continue;
            }
            // Best partial match inside the next chunk (shared CoW tail).
            let rest = &tokens[i..];
            let mut best: Option<(usize, usize)> = None; // (common prefix len, node)
            if !rest.is_empty() {
                for (key, &child) in self.children_of(cur) {
                    let cpl = key.iter().zip(rest).take_while(|(a, c)| a == c).count();
                    // cpl == b would have matched exactly above (rest shorter
                    // than b caps cpl below b here).
                    if cpl > 0 && best.is_none_or(|(bc, _)| cpl > bc) {
                        best = Some((cpl, child));
                    }
                }
            }
            if let Some((cpl, child)) = best {
                let node = self.node_mut(child);
                node.last_used = clock;
                blocks.push(node.block);
                i += cpl;
            }
            return PrefixMatch { tokens: i, blocks };
        }
    }

    /// Insert `tokens`' full-block chunks, mapping chunk `j` to `table[j]`.
    /// Existing nodes are reused (LRU-touched, no extra refs); a new node is
    /// inserted only while the sequence owns `table[j]` exclusively
    /// (refcount 1), and takes one cache reference on it. Stops at the
    /// first chunk that neither matches nor is exclusively owned: a shared,
    /// never-split tail block may hold rows for *different* content than
    /// this sequence committed, and everything deeper depends on it.
    pub fn register(&mut self, tokens: &[Token], table: &[BlockId], pool: &mut BlockPool) {
        self.clock += 1;
        let clock = self.clock;
        let b = self.block_size;
        let n_full = (tokens.len() / b).min(table.len());
        let mut cur: Option<usize> = None;
        for j in 0..n_full {
            let chunk = &tokens[j * b..(j + 1) * b];
            if let Some(&child) = self.children_of(cur).get(chunk) {
                self.node_mut(child).last_used = clock;
                cur = Some(child);
                continue;
            }
            let blk = table[j];
            if pool.refcount(blk) != 1 {
                return;
            }
            pool.incref(blk);
            let node = RadixNode {
                chunk: chunk.to_vec(),
                block: blk,
                parent: cur,
                children: BTreeMap::new(),
                last_used: clock,
            };
            let idx = match self.free_slots.pop() {
                Some(slot) => {
                    self.nodes[slot] = Some(node);
                    slot
                }
                None => {
                    self.nodes.push(Some(node));
                    self.nodes.len() - 1
                }
            };
            match cur {
                None => self.root_children.insert(chunk.to_vec(), idx),
                Some(p) => self.node_mut(p).children.insert(chunk.to_vec(), idx),
            };
            self.len += 1;
            cur = Some(idx);
        }
    }

    /// Cached nodes no live sequence maps (pool refcount 1): blocks the
    /// `KvManager` may reclaim on demand, counted into its `available()`.
    pub fn evictable(&self, pool: &BlockPool) -> usize {
        self.nodes
            .iter()
            .flatten()
            .filter(|n| pool.refcount(n.block) == 1)
            .count()
    }

    /// Evict the least-recently-used reclaimable node (and its subtree).
    /// Returns the number of blocks actually freed — at least one when any
    /// node was evictable, zero when nothing is reclaimable.
    pub fn evict_lru(&mut self, pool: &mut BlockPool) -> usize {
        let mut victim: Option<(u64, usize)> = None;
        for (idx, n) in self.nodes.iter().enumerate() {
            if let Some(n) = n {
                if pool.refcount(n.block) == 1
                    && victim.is_none_or(|(lu, _)| n.last_used < lu)
                {
                    victim = Some((n.last_used, idx));
                }
            }
        }
        let Some((_, idx)) = victim else { return 0 };
        self.remove_subtree(idx, pool)
    }

    fn remove_subtree(&mut self, idx: usize, pool: &mut BlockPool) -> usize {
        // Detach from the parent's child map first.
        let (parent, chunk) = {
            let n = self.node(idx);
            (n.parent, n.chunk.clone())
        };
        match parent {
            None => self.root_children.remove(&chunk),
            Some(p) => self.node_mut(p).children.remove(&chunk),
        };
        let mut freed = 0usize;
        let mut stack = vec![idx];
        while let Some(ix) = stack.pop() {
            // xtask:allow(panic): subtree indices are live until taken here.
            let node = self.nodes[ix].take().expect("live radix node");
            self.free_slots.push(ix);
            self.len -= 1;
            stack.extend(node.children.values().copied());
            if pool.decref(node.block) {
                freed += 1;
            }
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Allocate `n` blocks as a sequence's table.
    fn table(pool: &mut BlockPool, n: usize) -> Vec<BlockId> {
        (0..n).map(|_| pool.alloc().unwrap()).collect()
    }

    #[test]
    fn lookup_walks_full_chunks_then_partial() {
        let mut pool = BlockPool::new(16);
        let mut cache = RadixCache::new(4);
        let toks: Vec<Token> = (0..12).collect();
        let t = table(&mut pool, 3);
        cache.register(&toks, &t, &mut pool);
        assert_eq!(cache.len(), 3);
        // Exact full prefix.
        let m = cache.lookup(&toks);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.blocks, t);
        // Shorter query with a partial tail: 4 full + 2 into the next chunk.
        let m = cache.lookup(&toks[..6]);
        assert_eq!(m.tokens, 6);
        assert_eq!(m.blocks, &t[..2]);
        // Divergent content after one chunk: partial match stops at the
        // divergence point.
        let mut div = toks.clone();
        div[5] = 99;
        let m = cache.lookup(&div);
        assert_eq!(m.tokens, 5, "4 exact + 1 common into the second chunk");
        assert_eq!(m.blocks.len(), 2);
    }

    #[test]
    fn register_skips_shared_blocks_and_reuses_nodes() {
        let mut pool = BlockPool::new(16);
        let mut cache = RadixCache::new(4);
        let toks: Vec<Token> = (0..8).collect();
        let t = table(&mut pool, 2);
        cache.register(&toks, &t, &mut pool);
        assert_eq!(pool.refcount(t[0]), 2, "cache holds a ref");
        // Re-registering the same content must not double-insert or re-ref.
        cache.register(&toks, &t, &mut pool);
        assert_eq!(cache.len(), 2);
        assert_eq!(pool.refcount(t[0]), 2);
        // A different sequence whose tail block is shared (refcount > 1)
        // registers nothing past the shared point.
        let shared = t[1];
        pool.incref(shared); // simulate another sequence mapping it
        let mut toks2 = toks.clone();
        toks2[4] = 77; // diverges in chunk 1
        let t2 = vec![t[0], shared];
        cache.register(&toks2, &t2, &mut pool);
        assert_eq!(cache.len(), 2, "divergent shared tail must not be cached");
    }

    #[test]
    fn evict_lru_frees_cache_only_blocks_subtree_and_all() {
        let mut pool = BlockPool::new(16);
        let mut cache = RadixCache::new(2);
        let a: Vec<Token> = vec![1, 2, 3, 4];
        let b: Vec<Token> = vec![9, 9];
        let ta = table(&mut pool, 2);
        let tb = table(&mut pool, 1);
        cache.register(&a, &ta, &mut pool);
        cache.register(&b, &tb, &mut pool);
        assert_eq!(cache.len(), 3);
        // Sequences release: only the cache holds the blocks now.
        for &blk in ta.iter().chain(&tb) {
            pool.decref(blk);
        }
        assert_eq!(cache.evictable(&pool), 3);
        // Touch `b` so `a`'s chain is the LRU victim; evicting the chain
        // head removes the whole 2-node subtree.
        cache.lookup(&b);
        let freed = cache.evict_lru(&mut pool);
        assert_eq!(freed, 2, "subtree eviction frees both of a's blocks");
        assert_eq!(cache.len(), 1);
        let m = cache.lookup(&a);
        assert_eq!(m.tokens, 0, "evicted prefix no longer matches");
        assert_eq!(cache.lookup(&b).tokens, 2);
        // A block still mapped by a sequence is not evictable.
        let tc = table(&mut pool, 1);
        cache.register(&[5, 5], &tc, &mut pool);
        assert_eq!(cache.evictable(&pool), 1, "seq-mapped block is pinned");
        assert_eq!(
            {
                let f = cache.evict_lru(&mut pool);
                cache.evict_lru(&mut pool) + f
            },
            1,
            "only the unreferenced node frees a block"
        );
    }
}
