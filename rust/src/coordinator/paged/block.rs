//! Refcounted free-list block allocator — the physical layer of the paged
//! KV subsystem.
//!
//! A [`BlockPool`] owns a fixed set of [`BlockId`]s. Sequences hold
//! references to blocks through their block tables; the radix prefix cache
//! ([`super::radix`]) holds one extra reference per cached block. A block
//! whose refcount drops to zero returns to the free list. Copy-on-write
//! falls out of the refcounts: a block with more than one reference must
//! not be written in place — the writer allocates a copy first (the
//! `KvManager` enforces this at admission and first divergent grow).

/// An addressable KV block (index into the pool's refcount table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BlockId(pub u32);

/// Fixed-capacity refcounted block allocator.
#[derive(Debug)]
pub struct BlockPool {
    refcounts: Vec<u32>,
    /// Free stack; lowest ids pop first, so allocation order (and therefore
    /// every block table) is deterministic for a given call sequence.
    free: Vec<u32>,
}

impl BlockPool {
    pub fn new(total_blocks: usize) -> Self {
        Self {
            refcounts: vec![0; total_blocks],
            free: (0..total_blocks as u32).rev().collect(),
        }
    }

    pub fn total(&self) -> usize {
        self.refcounts.len()
    }

    pub fn free_len(&self) -> usize {
        self.free.len()
    }

    /// Take one free block (refcount 1), or `None` when the pool is empty.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcounts[id as usize], 0, "free block with live refs");
        self.refcounts[id as usize] = 1;
        Some(BlockId(id))
    }

    /// Add one reference (a sequence mapping the block, or the cache
    /// retaining it).
    pub fn incref(&mut self, b: BlockId) {
        debug_assert!(self.refcounts[b.0 as usize] > 0, "incref on a free block");
        self.refcounts[b.0 as usize] += 1;
    }

    /// Drop one reference; returns true when the block was freed.
    pub fn decref(&mut self, b: BlockId) -> bool {
        let rc = &mut self.refcounts[b.0 as usize];
        debug_assert!(*rc > 0, "decref on a free block");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b.0);
            true
        } else {
            false
        }
    }

    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refcounts[b.0 as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_incref_decref_cycle() {
        let mut p = BlockPool::new(3);
        assert_eq!(p.free_len(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.free_len(), 1);
        assert_eq!(p.refcount(a), 1);
        p.incref(a);
        assert_eq!(p.refcount(a), 2);
        assert!(!p.decref(a), "still one ref left");
        assert!(p.decref(a), "last ref frees");
        assert_eq!(p.free_len(), 2);
        assert!(p.decref(b));
        assert_eq!(p.free_len(), 3);
    }

    #[test]
    fn exhaustion_returns_none_and_freed_blocks_recycle() {
        let mut p = BlockPool::new(2);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert!(p.alloc().is_none());
        p.decref(a);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed block must be reusable");
    }

    #[test]
    fn allocation_order_is_deterministic() {
        let ids: Vec<u32> = {
            let mut p = BlockPool::new(4);
            (0..4).map(|_| p.alloc().unwrap().0).collect()
        };
        let again: Vec<u32> = {
            let mut p = BlockPool::new(4);
            (0..4).map(|_| p.alloc().unwrap().0).collect()
        };
        assert_eq!(ids, again);
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }
}
