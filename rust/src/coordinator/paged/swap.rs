//! Bounded swap tier for preempted sequences (suspend-to-swap).
//!
//! When the scheduler preempts a decode, the victim's KV blocks used to be
//! discarded — the resume paid `prompt + committed + inflight` tokens of
//! recompute. With a swap tier the `KvManager` instead moves the victim's
//! footprint to host-side swap space: the GPU-pool blocks still free
//! immediately (that is the point of preemption), but the sequence keeps a
//! [`SwapHandle`](crate::spec::task::SwapHandle) and restores without
//! re-scoring anything. Like the rest of the KV subsystem the bytes are
//! simulated (accounting-only substrate), but capacity is real: the tier
//! is bounded in blocks, reservation is all-or-nothing (a partially
//! swapped prefix would still force a full re-score in a real engine),
//! and when the tier is full preemption falls back to the PR 5 discard
//! path.

use std::collections::BTreeMap;

use crate::spec::task::SwapHandle;

/// Bounded accounting for swapped-out sequences.
#[derive(Debug)]
pub struct SwapPool {
    total_blocks: usize,
    used_blocks: usize,
    next_id: u64,
    /// Live reservations: handle id -> blocks held.
    entries: BTreeMap<u64, usize>,
}

impl SwapPool {
    pub fn new(total_blocks: usize) -> Self {
        Self { total_blocks, used_blocks: 0, next_id: 0, entries: BTreeMap::new() }
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    /// Reserve swap space for `blocks` blocks holding `tokens` tokens of
    /// KV. All-or-nothing: returns `None` when the tier is disabled
    /// (zero-sized) or cannot hold the whole footprint.
    pub fn reserve(&mut self, blocks: usize, tokens: usize) -> Option<SwapHandle> {
        if self.total_blocks == 0 || self.used_blocks + blocks > self.total_blocks {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.used_blocks += blocks;
        self.entries.insert(id, blocks);
        Some(SwapHandle { id, tokens, blocks })
    }

    /// Release a reservation (restore or discard). Idempotent: freeing an
    /// unknown/already-freed handle is a no-op returning false.
    pub fn free(&mut self, handle: &SwapHandle) -> bool {
        match self.entries.remove(&handle.id) {
            Some(blocks) => {
                self.used_blocks -= blocks;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_is_bounded_and_all_or_nothing() {
        let mut s = SwapPool::new(4);
        let a = s.reserve(3, 40).expect("fits");
        assert_eq!(a.blocks, 3);
        assert_eq!(a.tokens, 40);
        assert_eq!(s.used_blocks(), 3);
        assert!(s.reserve(2, 20).is_none(), "would exceed the tier");
        let b = s.reserve(1, 4).expect("exactly fills");
        assert!(s.free(&a));
        assert!(!s.free(&a), "double free is a no-op");
        assert_eq!(s.used_blocks(), 1);
        assert!(s.free(&b));
        assert_eq!(s.used_blocks(), 0);
    }

    #[test]
    fn zero_sized_tier_is_disabled() {
        let mut s = SwapPool::new(0);
        assert!(s.reserve(0, 0).is_none(), "disabled tier never issues handles");
    }

    #[test]
    fn handle_ids_are_unique() {
        let mut s = SwapPool::new(8);
        let a = s.reserve(1, 1).unwrap();
        s.free(&a);
        let b = s.reserve(1, 1).unwrap();
        assert_ne!(a.id, b.id, "freed ids are not recycled");
    }
}
