//! Paged KV subsystem: refcounted block tables, a radix prefix cache, and
//! a bounded swap tier.
//!
//! This module supplies the physical layer under
//! [`KvManager`](super::kv::KvManager) (which keeps its PR 4/5 admission
//! API so the router and scheduler migrated incrementally):
//!
//! * [`block`] — [`BlockPool`]: a free-list allocator of addressable
//!   [`BlockId`]s with per-block refcounts. A sequence's allocation is a
//!   *block table* (ordered list of `BlockId`s), not a counter; sharing
//!   and copy-on-write are refcount operations.
//! * [`radix`] — [`RadixCache`]: a trie over full-block token chunks
//!   mapping prompt prefixes to cached blocks. Requests sharing a system
//!   prompt / few-shot template / conversation transcript map the same
//!   physical blocks (one pool ref per mapper plus one held by the cache)
//!   instead of re-allocating them; LRU subtree eviction reclaims cached
//!   blocks on demand, so the cache is free capacity, never pressure.
//! * [`swap`] — [`SwapPool`]: bounded, all-or-nothing swap reservations
//!   for preemption victims, keyed by
//!   [`SwapHandle`](crate::spec::task::SwapHandle) carried in the victim's
//!   `ResumeState`. Restore re-admits from swap with zero wasted
//!   recompute; a full tier falls back to the discard path.
//!
//! The AOT substrate recomputes attention per forward (DESIGN.md §7), so
//! block *contents* are simulated — but the allocator, refcounts, sharing,
//! eviction, and swap capacity are the real vLLM-style mechanics and gate
//! admission exactly as a device-resident block manager would.

pub mod block;
pub mod radix;
pub mod swap;

pub use block::{BlockId, BlockPool};
pub use radix::{PrefixMatch, RadixCache};
pub use swap::SwapPool;
