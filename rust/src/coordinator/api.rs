//! Public request/response types of the serving coordinator.

use std::time::Duration;

use crate::spec::task::ResumeState;
use crate::spec::types::{FaultKind, ModelFault, SamplingParams, Token, VerifyRule};
use crate::workload::tasks::TaskKind;

/// Why a decode failed, as delivered to clients. Typed (rather than a
/// stringified `anyhow` chain) so callers can branch on the failure class:
/// retry elsewhere on [`EngineLost`](DecodeError::EngineLost), re-submit
/// with a longer budget on [`Timeout`](DecodeError::Timeout), shrink the
/// request on [`Saturated`](DecodeError::Saturated).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// The request ran past its deadline (`Request::deadline`) and was
    /// cancelled at a step boundary, or an engine call hung past the host's
    /// call deadline. Sessions and KV were released; partial output is
    /// discarded.
    Timeout,
    /// The engine thread serving the chain's target died or its channel
    /// closed; the request cannot complete on this worker.
    EngineLost,
    /// The KV pool is smaller than this one request's live footprint — no
    /// eviction can ever admit it.
    Saturated,
    /// Any other decode failure (model errors after retries, invalid
    /// configuration discovered at task-open time, ...).
    Internal(String),
}

impl DecodeError {
    /// Classify a decode-path error chain into its client-facing class.
    /// Engine faults keep their [`FaultKind`] through `anyhow` context
    /// chains; anything unrecognised is [`Internal`](DecodeError::Internal)
    /// with the full chain as text.
    pub fn classify(err: &anyhow::Error) -> Self {
        match err.downcast_ref::<ModelFault>() {
            Some(f) => match f.kind {
                FaultKind::Timeout => DecodeError::Timeout,
                FaultKind::Lost => DecodeError::EngineLost,
                FaultKind::Transient => DecodeError::Internal(format!("{err:#}")),
            },
            None => DecodeError::Internal(format!("{err:#}")),
        }
    }
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Timeout => write!(f, "decode deadline exceeded"),
            DecodeError::EngineLost => write!(f, "engine lost"),
            DecodeError::Saturated => {
                write!(f, "KV pool too small for the request's live footprint")
            }
            DecodeError::Internal(msg) => write!(f, "decode failed: {msg}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Which decoding engine serves the request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Vanilla autoregressive decoding with the target model.
    Autoregressive,
    /// Two-model draft/verify (Leviathan-style; the EAGLE2-like baseline).
    Dualistic { draft_k: usize },
    /// The paper's polybasic chain (target / intermediate / draft).
    Polybasic { draft_k: usize, mu: usize },
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Autoregressive => "vanilla",
            Method::Dualistic { .. } => "dualistic",
            Method::Polybasic { .. } => "polybasic",
        }
    }
}

impl Default for Method {
    fn default() -> Self {
        Method::Polybasic { draft_k: 6, mu: 8 }
    }
}

/// A generation request as accepted by the server.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<Token>,
    pub max_new: usize,
    pub sampling: SamplingParams,
    pub rule: VerifyRule,
    pub method: Method,
    /// Task tag (metrics aggregation + scheduling class).
    pub task: Option<TaskKind>,
    /// End-to-end budget (queue + service, across preemptions). A request
    /// still incomplete past this is cancelled at the next step boundary
    /// with [`DecodeError::Timeout`], its KV and sessions released. `None`
    /// (the default) never cancels.
    pub deadline: Option<Duration>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<Token>, max_new: usize) -> Self {
        Self {
            id,
            prompt,
            max_new,
            sampling: SamplingParams::default(),
            rule: VerifyRule::Speculative,
            method: Method::default(),
            task: None,
            deadline: None,
        }
    }
}

/// Completed generation with serving measurements.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<Token>,
    /// Time spent queued before a worker opened a decode task for the
    /// request, summed across re-queues if the request was preempted.
    pub queue_time: Duration,
    /// Task open -> finish, summed across run segments if the request was
    /// preempted. Under continuous batching this includes time spent
    /// sharing the worker with interleaved requests; the pure decode wall
    /// (sum of this task's step times) is smaller.
    pub service_time: Duration,
    /// Enqueue -> first committed token. `None` when the request never
    /// committed a token (e.g. `max_new == 0`) — there was no first token,
    /// so no TTFT exists and none is recorded in the histogram.
    pub ttft: Option<Duration>,
    /// How many times this request was preempted (suspended + resumed) by
    /// KV-pool pressure before completing. Zero on an uncontended pool.
    pub preemptions: u32,
    /// Mean acceptance length at the target (μ) for speculative methods.
    pub mean_accept: f64,
    /// Per-model forward passes, chain order.
    pub forward_passes: Vec<u64>,
    /// Chain members dropped mid-decode by graceful degradation (a failing
    /// or unhealthy drafter removed at a step boundary). Zero for a fully
    /// healthy chain. Degradation never changes the committed-token
    /// distribution — under deterministic verify rules the output is
    /// byte-identical to a healthy run.
    pub degraded: u32,
    pub task: Option<TaskKind>,
    pub method: Method,
}

impl Response {
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens.len() as f64 / self.service_time.as_secs_f64().max(1e-9)
    }
}

/// One item of a streamed generation (see `Server::submit_stream`):
/// committed-token deltas as decode steps complete, then the final
/// [`Response`] — or [`Failed`](StreamItem::Failed) with the reason, so a
/// decode error reaches the client instead of a bare channel close.
#[derive(Debug, Clone)]
pub enum StreamItem {
    /// Tokens committed by one decode step, in order.
    Delta(Vec<Token>),
    /// The generation finished; carries the full response (its `tokens`
    /// equal the concatenation of all deltas).
    Done(Response),
    /// The decode failed after zero or more deltas; carries the error.
    Failed(DecodeError),
}

/// A preempted request's scheduler-level baggage, carried alongside the
/// task-level [`ResumeState`] through the re-queue so nothing client-visible
/// resets: tokens already streamed are not re-delivered, TTFT is not
/// re-recorded, and queue/service times accumulate across segments.
#[derive(Debug)]
pub struct ResumeCarry {
    /// The suspended decode itself (see `DecodeTask::suspend`).
    pub state: ResumeState,
    /// Committed tokens already delivered as stream deltas.
    pub streamed: usize,
    /// Time-to-first-token, if a first token was committed before
    /// suspension (already recorded in the histogram — do not re-record).
    pub ttft: Option<Duration>,
    /// Queue time accumulated over all previous queue segments.
    pub queue_time: Duration,
    /// Service time accumulated over all previous run segments.
    pub service_time: Duration,
    /// How many times this request has been preempted so far.
    pub preemptions: u32,
}
