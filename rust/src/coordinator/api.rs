//! Public request/response types of the serving coordinator.

use std::time::Duration;

use crate::spec::types::{SamplingParams, Token, VerifyRule};
use crate::workload::tasks::TaskKind;

/// Which decoding engine serves the request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Vanilla autoregressive decoding with the target model.
    Autoregressive,
    /// Two-model draft/verify (Leviathan-style; the EAGLE2-like baseline).
    Dualistic { draft_k: usize },
    /// The paper's polybasic chain (target / intermediate / draft).
    Polybasic { draft_k: usize, mu: usize },
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Autoregressive => "vanilla",
            Method::Dualistic { .. } => "dualistic",
            Method::Polybasic { .. } => "polybasic",
        }
    }
}

impl Default for Method {
    fn default() -> Self {
        Method::Polybasic { draft_k: 6, mu: 8 }
    }
}

/// A generation request as accepted by the server.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<Token>,
    pub max_new: usize,
    pub sampling: SamplingParams,
    pub rule: VerifyRule,
    pub method: Method,
    /// Task tag (metrics aggregation + scheduling class).
    pub task: Option<TaskKind>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<Token>, max_new: usize) -> Self {
        Self {
            id,
            prompt,
            max_new,
            sampling: SamplingParams::default(),
            rule: VerifyRule::Speculative,
            method: Method::default(),
            task: None,
        }
    }
}

/// Completed generation with serving measurements.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<Token>,
    /// Time spent queued before a worker opened a decode task for the
    /// request.
    pub queue_time: Duration,
    /// Task open -> finish. Under continuous batching this includes time
    /// spent sharing the worker with interleaved requests; the pure decode
    /// wall (sum of this task's step times) is smaller.
    pub service_time: Duration,
    /// Enqueue -> first committed token.
    pub ttft: Duration,
    /// Mean acceptance length at the target (μ) for speculative methods.
    pub mean_accept: f64,
    /// Per-model forward passes, chain order.
    pub forward_passes: Vec<u64>,
    pub task: Option<TaskKind>,
    pub method: Method,
}

impl Response {
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens.len() as f64 / self.service_time.as_secs_f64().max(1e-9)
    }
}

/// One item of a streamed generation (see `Server::submit_stream`):
/// committed-token deltas as decode steps complete, then the final
/// [`Response`].
#[derive(Debug, Clone)]
pub enum StreamItem {
    /// Tokens committed by one decode step, in order.
    Delta(Vec<Token>),
    /// The generation finished; carries the full response (its `tokens`
    /// equal the concatenation of all deltas).
    Done(Response),
}
