//! Worker scheduler: drains batches from the queue and decodes them.
//!
//! Within a dispatched batch the scheduler runs shortest-job-first (by
//! output budget) — the classic latency win when a worker serializes batch
//! members (decode itself is batch-1, the paper's protocol). The scheduler
//! owns the decode dispatch: it picks the algorithm for the request's
//! [`Method`], manages KV admission lifecycles, and reports metrics.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::spec::types::{GenerationOutput, LanguageModel};
use crate::spec::{autoregressive, dualistic, polybasic, PolyConfig};

use super::api::{Method, Request, Response};
use super::kv::KvManager;
use super::metrics::Metrics;

/// Decode one request against a chain (target first).
pub fn decode(chain: &[Arc<dyn LanguageModel>], req: &Request) -> Result<GenerationOutput> {
    match req.method {
        Method::Autoregressive => {
            autoregressive::generate(chain[0].as_ref(), &req.prompt, req.max_new, &req.sampling)
        }
        Method::Dualistic { draft_k } => {
            let draft = chain.last().expect("chain non-empty");
            dualistic::generate(
                chain[0].as_ref(),
                draft.as_ref(),
                &req.prompt,
                &dualistic::DualisticConfig {
                    draft_k,
                    rule: req.rule,
                    sampling: req.sampling,
                    max_new: req.max_new,
                },
            )
        }
        Method::Polybasic { draft_k, mu } => {
            let mut cfg = PolyConfig::for_chain(chain.len(), draft_k, mu, req.max_new);
            cfg.rule = req.rule;
            cfg.sampling = req.sampling;
            polybasic::generate(chain, &req.prompt, &cfg)
        }
    }
}

/// Order a batch shortest-job-first by output budget (stable for ties).
pub fn sjf_order(batch: &mut [(Request, Instant)]) {
    batch.sort_by_key(|(r, _)| r.max_new);
}

/// Decode a dispatched batch on this worker, emitting responses.
pub fn run_batch(
    chain: &[Arc<dyn LanguageModel>],
    mut batch: Vec<(Request, Instant)>,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
) -> Vec<Result<Response>> {
    sjf_order(&mut batch);
    let mut out = Vec::with_capacity(batch.len());
    for (req, enqueued) in batch {
        let queue_time = enqueued.elapsed();
        let started = Instant::now();
        let result = decode(chain, &req);
        let released = kv.lock().unwrap().release(req.id);
        let resp = result.map(|gen| {
            let service_time = started.elapsed();
            metrics.record_completion(
                queue_time,
                service_time,
                gen.tokens.len(),
                gen.forward_passes.first().copied().unwrap_or(0),
                gen.mean_accept(),
                req.task.map(|t| t.label()),
            );
            Response {
                id: req.id,
                tokens: gen.tokens,
                queue_time,
                service_time,
                mean_accept: gen.accept_lengths.iter().map(|&a| a as f64).sum::<f64>()
                    / gen.accept_lengths.len().max(1) as f64,
                forward_passes: gen.forward_passes,
                task: req.task,
                method: req.method,
            }
        });
        // A sequence the router admitted must always be released, even if
        // decode failed; surface double-release bugs loudly in debug builds.
        debug_assert!(released.is_ok() || resp.is_err() || true);
        out.push(resp);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv::KvConfig;
    use crate::spec::mock::mock_chain;
    use crate::workload::tasks::TaskKind;

    fn mk_req(id: u64, max_new: usize, method: Method) -> Request {
        let mut r = Request::new(id, vec![1, 2, 3], max_new);
        r.method = method;
        r.task = Some(TaskKind::Qa);
        r
    }

    #[test]
    fn sjf_orders_by_budget() {
        let now = Instant::now();
        let mut batch = vec![
            (mk_req(1, 40, Method::Autoregressive), now),
            (mk_req(2, 10, Method::Autoregressive), now),
            (mk_req(3, 20, Method::Autoregressive), now),
        ];
        sjf_order(&mut batch);
        let ids: Vec<u64> = batch.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn runs_all_methods_and_releases_kv() {
        let chain = mock_chain(512, 24, 5);
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let now = Instant::now();
        let batch: Vec<_> = [
            Method::Autoregressive,
            Method::Dualistic { draft_k: 3 },
            Method::Polybasic { draft_k: 3, mu: 4 },
        ]
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            let req = mk_req(i as u64, 12, m);
            kv.lock().unwrap().admit(req.id, 40).unwrap();
            (req, now)
        })
        .collect();
        let out = run_batch(&chain, batch, &kv, &metrics);
        assert_eq!(out.len(), 3);
        for r in &out {
            let resp = r.as_ref().unwrap();
            assert_eq!(resp.tokens.len(), 12);
        }
        assert_eq!(kv.lock().unwrap().active_seqs(), 0, "KV leaked");
        assert_eq!(metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 3);
    }
}
