//! Continuous-batching step scheduler: the worker's decode loop.
//!
//! The pre-refactor scheduler dispatched *whole requests*: each batch
//! member ran `generate()` to completion, so a 512-token batch job
//! head-of-line-blocked a 10-token interactive one. [`run_batch`] now
//! schedules **decode steps**: every live request is a resumable
//! [`DecodeTask`] (one [`step`](DecodeTask::step) = one draft→verify
//! round), and the scheduler round-robins one step per task per sweep.
//! Between sweeps it admits newly queued requests
//! ([`DynamicBatcher::try_pop`]), so interactive arrivals join mid-flight
//! instead of waiting for the running work to drain; committed tokens
//! stream out as [`BatchEvent::Delta`]s the moment their step completes;
//! KV allocations grow with each task's live length; and [`Metrics`] gains
//! time-to-first-token and in-flight concurrency.
//!
//! The scheduler owns the decode dispatch: it picks the task type for the
//! request's [`Method`], manages KV admission lifecycles, and reports
//! metrics. Initial batches are ordered shortest-job-first (by output
//! budget) so short jobs take the early round-robin slots, but under
//! continuous batching ordering only affects step interleaving — nothing
//! waits for a longer neighbour to finish.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::spec::autoregressive::ArTask;
use crate::spec::dualistic::{self, DualisticTask};
use crate::spec::polybasic::PolyTask;
use crate::spec::task::DecodeTask;
use crate::spec::types::{GenerationOutput, LanguageModel, Token};
use crate::spec::PolyConfig;

use super::api::{Method, Request, Response};
use super::batcher::DynamicBatcher;
use super::kv::KvManager;
use super::metrics::Metrics;
use super::router::pipeline_headroom;

/// Open a resumable decode task for one request against a chain (target
/// first). The task borrows the chain and owns one scoring session per
/// member.
pub fn open_task<'m>(
    chain: &'m [Arc<dyn LanguageModel>],
    req: &Request,
) -> Result<Box<dyn DecodeTask + 'm>> {
    match req.method {
        Method::Autoregressive => Ok(Box::new(ArTask::new(
            chain[0].as_ref(),
            &req.prompt,
            req.max_new,
            req.sampling,
        )?)),
        Method::Dualistic { draft_k } => {
            let draft = chain.last().expect("chain non-empty");
            Ok(Box::new(DualisticTask::new(
                chain[0].as_ref(),
                draft.as_ref(),
                &req.prompt,
                dualistic::DualisticConfig {
                    draft_k,
                    rule: req.rule,
                    sampling: req.sampling,
                    max_new: req.max_new,
                },
            )?))
        }
        Method::Polybasic { draft_k, mu } => {
            let mut cfg = PolyConfig::for_chain(chain.len(), draft_k, mu, req.max_new);
            cfg.rule = req.rule;
            cfg.sampling = req.sampling;
            Ok(Box::new(PolyTask::new(chain, &req.prompt, cfg)?))
        }
    }
}

/// Decode one request to completion (the single-shot path: CLI, benches).
/// Shares the Method-to-task dispatch with the serving path through
/// [`open_task`], so served and one-shot output cannot drift.
pub fn decode(chain: &[Arc<dyn LanguageModel>], req: &Request) -> Result<GenerationOutput> {
    for m in chain {
        m.reset_counters();
    }
    let mut task = open_task(chain, req)?;
    while !task.finished() {
        task.step()?;
    }
    Ok(task.finish())
}

/// Order a batch shortest-job-first by output budget (stable for ties).
pub fn sjf_order(batch: &mut [(Request, Instant)]) {
    batch.sort_by_key(|(r, _)| r.max_new);
}

/// Progress notifications emitted by [`run_batch`] as it schedules steps.
#[derive(Debug)]
pub enum BatchEvent<'a> {
    /// One decode step committed new tokens for request `id` (in order;
    /// concatenated deltas equal the final response's tokens).
    Delta { id: u64, tokens: &'a [Token] },
    /// Request `id` left the scheduler: finished, failed, or refused at
    /// task-open time. Carries the response by value — the scheduler
    /// retains nothing per completed request, so a server worker can stay
    /// inside one `run_batch` call indefinitely under sustained load
    /// without accumulating memory.
    Done { id: u64, response: Result<Response> },
}

/// A request with a live decode task on this worker.
struct Live<'m> {
    req: Request,
    enqueued: Instant,
    opened: Instant,
    queue_time: std::time::Duration,
    headroom: usize,
    ttft: Option<std::time::Duration>,
    /// Committed tokens already emitted as deltas.
    streamed: usize,
    task: Box<dyn DecodeTask + 'm>,
}

/// Continuous-batching decode of `batch` (plus anything `admit` delivers
/// while work is in flight) on this worker.
///
/// Round-robin, one step per live task per sweep; between sweeps up to
/// `max_live` tasks are kept alive by pulling newly queued requests from
/// `admit` — an interactive request completes while a long batch request
/// is still mid-decode instead of waiting behind it. Returns when the live
/// set and (momentarily) the admission queue are empty. All output flows
/// through `on_event`: every committed-token delta as it lands, then one
/// [`BatchEvent::Done`] per request in **completion order** (failures
/// surface as `Err` responses rather than silent drops). KV for every
/// request is released exactly once.
pub fn run_batch(
    chain: &[Arc<dyn LanguageModel>],
    mut batch: Vec<(Request, Instant)>,
    admit: Option<&DynamicBatcher>,
    max_live: usize,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
    mut on_event: impl FnMut(BatchEvent<'_>),
) {
    let max_live = max_live.max(1);
    sjf_order(&mut batch);
    let mut waiting: VecDeque<(Request, Instant)> = batch.into();
    let mut live: Vec<Live<'_>> = Vec::new();

    loop {
        // ---- admission: new requests join between steps ------------------
        if let Some(queue) = admit {
            if live.len() + waiting.len() < max_live {
                waiting.extend(queue.try_pop(max_live - live.len() - waiting.len()));
            }
        }
        while live.len() < max_live {
            let Some((req, enqueued)) = waiting.pop_front() else { break };
            let opened = Instant::now();
            match open_task(chain, &req) {
                Ok(task) => {
                    metrics.task_started();
                    live.push(Live {
                        headroom: pipeline_headroom(&req.method, chain.len()),
                        queue_time: opened.duration_since(enqueued),
                        req,
                        enqueued,
                        opened,
                        ttft: None,
                        streamed: 0,
                        task,
                    });
                }
                Err(e) => {
                    // The router admitted it, so the KV reservation exists
                    // and must be returned even though no task ever ran.
                    let released = kv.lock().unwrap().release(req.id);
                    debug_assert!(
                        released.is_ok(),
                        "KV release failed for request {}: every admitted request \
                         must hold exactly one allocation ({released:?})",
                        req.id
                    );
                    on_event(BatchEvent::Done { id: req.id, response: Err(e) });
                }
            }
        }
        if live.is_empty() {
            break;
        }

        // ---- one sweep: one step per live task, round-robin --------------
        let mut i = 0;
        while i < live.len() {
            let (step_err, finished) = {
                let l = &mut live[i];
                match l.task.step() {
                    Ok(_) => {
                        let mut err = None;
                        let committed_len = l.task.committed().len();
                        if committed_len > l.streamed {
                            if l.ttft.is_none() {
                                let ttft = l.enqueued.elapsed();
                                l.ttft = Some(ttft);
                                metrics.record_first_token(ttft);
                            }
                            on_event(BatchEvent::Delta {
                                id: l.req.id,
                                tokens: &l.task.committed()[l.streamed..],
                            });
                            l.streamed = committed_len;
                            // Track the live length in the KV manager; a
                            // saturated pool fails the request (no silent
                            // overcommit).
                            let target = l.req.prompt.len() + l.streamed + l.headroom;
                            let mut kv = kv.lock().unwrap();
                            if kv.seq_tokens(l.req.id).is_some_and(|cur| target > cur) {
                                if let Err(e) = kv.grow(l.req.id, target) {
                                    err = Some(e);
                                }
                            }
                        }
                        let finished = err.is_none() && l.task.finished();
                        (err, finished)
                    }
                    Err(e) => (Some(e), false),
                }
            };
            if step_err.is_none() && !finished {
                i += 1;
                continue;
            }

            // ---- completion: release KV, record metrics, emit ------------
            let Live { req, opened, queue_time, ttft, task, .. } = live.remove(i);
            metrics.task_ended();
            let released = kv.lock().unwrap().release(req.id);
            debug_assert!(
                released.is_ok(),
                "KV release failed for request {}: every admitted request must \
                 hold exactly one allocation ({released:?})",
                req.id
            );
            let id = req.id;
            let resp: Result<Response> = match step_err {
                Some(e) => Err(e),
                None => {
                    let gen = task.finish();
                    let service_time = opened.elapsed();
                    let mean_accept = gen.mean_accept();
                    metrics.record_completion(
                        queue_time,
                        service_time,
                        gen.tokens.len(),
                        gen.forward_passes.first().copied().unwrap_or(0),
                        mean_accept,
                        req.task.map(|t| t.label()),
                    );
                    Ok(Response {
                        id,
                        tokens: gen.tokens,
                        queue_time,
                        service_time,
                        ttft: ttft.unwrap_or(queue_time + service_time),
                        mean_accept,
                        forward_passes: gen.forward_passes,
                        task: req.task,
                        method: req.method,
                    })
                }
            };
            on_event(BatchEvent::Done { id, response: resp });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv::KvConfig;
    use crate::spec::mock::mock_chain;
    use crate::workload::tasks::TaskKind;

    fn mk_req(id: u64, max_new: usize, method: Method) -> Request {
        let mut r = Request::new(id, vec![1, 2, 3], max_new);
        r.method = method;
        r.task = Some(TaskKind::Qa);
        r
    }

    #[test]
    fn sjf_orders_by_budget() {
        let now = Instant::now();
        let mut batch = vec![
            (mk_req(1, 40, Method::Autoregressive), now),
            (mk_req(2, 10, Method::Autoregressive), now),
            (mk_req(3, 20, Method::Autoregressive), now),
        ];
        sjf_order(&mut batch);
        let ids: Vec<u64> = batch.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn runs_all_methods_and_releases_kv() {
        let chain = mock_chain(512, 24, 5);
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let now = Instant::now();
        let batch: Vec<_> = [
            Method::Autoregressive,
            Method::Dualistic { draft_k: 3 },
            Method::Polybasic { draft_k: 3, mu: 4 },
        ]
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            let req = mk_req(i as u64, 12, m);
            kv.lock().unwrap().admit(req.id, 40).unwrap();
            (req, now)
        })
        .collect();
        let mut out: Vec<Result<Response>> = Vec::new();
        run_batch(&chain, batch, None, 4, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        assert_eq!(out.len(), 3);
        for r in &out {
            let resp = r.as_ref().unwrap();
            assert_eq!(resp.tokens.len(), 12);
        }
        assert_eq!(kv.lock().unwrap().active_seqs(), 0, "KV leaked");
        assert_eq!(metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 3);
        assert_eq!(metrics.inflight(), 0);
        assert!(metrics.inflight_peak() >= 2, "steps should interleave");
        assert_eq!(metrics.ttft_latency.count(), 3);
    }

    #[test]
    fn response_mean_accept_matches_generation_output() {
        let chain = mock_chain(512, 24, 9);
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let req = mk_req(1, 16, Method::Polybasic { draft_k: 3, mu: 4 });
        kv.lock().unwrap().admit(1, 60).unwrap();
        let gen = decode(&chain, &req).unwrap();
        let mut out: Vec<Result<Response>> = Vec::new();
        run_batch(&chain, vec![(req, Instant::now())], None, 1, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        let resp = out[0].as_ref().unwrap();
        assert_eq!(resp.tokens, gen.tokens, "stepped serving must match one-shot decode");
        assert!(
            (resp.mean_accept - gen.mean_accept()).abs() < 1e-12,
            "response mean_accept {} != generation {}",
            resp.mean_accept,
            gen.mean_accept()
        );
    }

    #[test]
    fn open_failure_releases_kv_and_reports_error() {
        let chain = mock_chain(64, 24, 5); // tiny context
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        // max_new far beyond the 64-token context: task open must fail.
        let req = mk_req(1, 600, Method::Polybasic { draft_k: 3, mu: 4 });
        kv.lock().unwrap().admit(1, 30).unwrap();
        let mut out: Vec<Result<Response>> = Vec::new();
        run_batch(&chain, vec![(req, Instant::now())], None, 2, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        assert_eq!(out.len(), 1);
        assert!(out[0].is_err());
        assert_eq!(kv.lock().unwrap().active_seqs(), 0, "KV leaked on open failure");
    }
}
