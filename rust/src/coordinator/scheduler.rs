//! Continuous-batching step scheduler: the worker's decode loop.
//!
//! The pre-refactor scheduler dispatched *whole requests*: each batch
//! member ran `generate()` to completion, so a 512-token batch job
//! head-of-line-blocked a 10-token interactive one. [`run_batch`] now
//! schedules **decode steps**: every live request is a resumable
//! [`DecodeTask`] (one [`step`](DecodeTask::step) = one draft→verify
//! round), and the scheduler round-robins one step per task per sweep.
//! Between sweeps it admits newly queued requests
//! ([`DynamicBatcher::try_pop`]), so interactive arrivals join mid-flight
//! instead of waiting for the running work to drain (the
//! continuous-batching admission path; see `coordinator::scheduler`);
//! committed tokens stream out as [`BatchEvent::Delta`]s the moment their
//! step completes; KV allocations grow with each task's live length; and
//! [`Metrics`] gains time-to-first-token and in-flight concurrency.
//!
//! **Preempt-and-resume.** Live-length KV admission deliberately
//! overcommits the pool, so a mid-decode [`KvManager::grow`] can find it
//! saturated. That used to fail the growing request outright — discarding
//! tokens already committed and streamed. Now the scheduler *preempts*
//! instead: it picks a victim by class-then-cost ([`select_victim`]:
//! batch-class before interactive, largest KV holding first — never the
//! growing request itself while other candidates exist), suspends the
//! victim's task into a [`ResumeState`](crate::spec::task::ResumeState),
//! releases its KV, and re-queues it through
//! [`DynamicBatcher::push_front_resumed`], where it outranks fresh
//! arrivals of its class. When space frees, the victim re-reserves
//! `prompt + committed + headroom` and resumes **byte-identically** — a
//! client sees a pause, never a spurious failure. A grow error surfaces
//! only when the pool is smaller than one lone request's footprint.
//!
//! **Cross-request batched verification (plan → submit → absorb).** With
//! B live requests decoding against the same chain, the naive sweep costs
//! B engine calls per member per tick. Each sweep therefore opens with a
//! *submit* pass ([`submit_batched`]): every live task is asked to **plan**
//! its next engine call ([`DecodeTask::plan_append`] — `Some` exactly when
//! that call is a pure, non-empty session append on a batch-capable
//! session); plans are grouped by chain member (matching each plan's model
//! key against `Arc::as_ptr` of the chain entries) and each member with
//! any plans receives **one**
//! [`append_batch`](LanguageModel::append_batch) — one engine call, one
//! `SessionAppendBatch` over the channel on remote engines — whose
//! per-entry results are handed back through
//! [`DecodeTask::absorb_append`]. An absorbed append makes the task's
//! first in-step `reconcile` a free no-op, so the sweep's `step()` calls
//! then run unchanged and committed output stays **byte-identical** to
//! the unbatched dispatch (pinned per Method × VerifyRule by the property
//! tests). Fallback is per-task and total: a task that declines to plan
//! (mid-verify in-flight state, unhealthy drafter, non-batchable session)
//! or a member with no batched path simply appends in-step as before; a
//! per-entry fault inside a batch reaches only its own task, which
//! surfaces it on its next step exactly like an in-step append failure
//! (drafter faults degrade, target faults fail — PR 6's trichotomy is
//! unchanged). [`SchedulerOpts::coalesce`] turns the submit pass off,
//! which is the oracle the batched path is tested against; coalesced
//! calls are counted by [`Metrics::record_engine_call`].
//!
//! **Deadlines and degradation.** A request with a
//! [`deadline`](Request::deadline) is checked at every step boundary (and
//! once more at admission): overdue requests are cancelled with
//! [`DecodeError::Timeout`], their sessions dropped and KV released — the
//! exact resources a normal completion returns, so cancellation can never
//! leak pool space. Decode tasks degrade gracefully when drafters fail
//! mid-decode (see `spec::task::DecodeTask::degraded`); the scheduler
//! counts each dropped chain member into the degradation metric exactly
//! once and reports the total on the [`Response`]. Failures reach clients
//! as typed [`DecodeError`]s, never stringly-typed reasons.
//!
//! The scheduler owns the decode dispatch: it picks the task type for the
//! request's [`Method`], manages KV admission lifecycles, and reports
//! metrics. Initial batches are ordered shortest-job-first (by output
//! budget) so short jobs take the early round-robin slots, but under
//! continuous batching ordering only affects step interleaving — nothing
//! waits for a longer neighbour to finish.

use std::collections::VecDeque;
use std::time::Duration;

use crate::sync::time::Instant;
use crate::sync::{Arc, Mutex};

use anyhow::Result;

use crate::spec::autoregressive::ArTask;
use crate::spec::dualistic::{self, DualisticTask};
use crate::spec::polybasic::PolyTask;
use crate::spec::task::{DecodeTask, InflightState, PlannedAppend, ResumeState};
use crate::spec::types::{GenerationOutput, LanguageModel, Token};
use crate::spec::PolyConfig;

use super::api::{DecodeError, Method, Request, Response, ResumeCarry};
use super::batcher::{classify, Batch, DynamicBatcher, Priority, QueueEntry};
use super::kv::KvManager;
use super::metrics::Metrics;
use super::router::pipeline_headroom;

/// The single Request→task dispatch both [`open_task`] and [`resume_task`]
/// share: Method selection, chain-member roles, and per-method config are
/// built in exactly one place, so a fresh open and a post-preemption
/// resume can never drift apart (drift would silently break the
/// byte-identity guarantee).
fn dispatch_task<'m>(
    chain: &'m [Arc<dyn LanguageModel>],
    req: &Request,
    state: Option<ResumeState>,
) -> Result<Box<dyn DecodeTask + 'm>> {
    match req.method {
        Method::Autoregressive => {
            let model = chain[0].as_ref();
            Ok(match state {
                None => Box::new(ArTask::new(model, &req.prompt, req.max_new, req.sampling)?),
                Some(s) => {
                    Box::new(ArTask::resume(model, &req.prompt, req.max_new, req.sampling, s)?)
                }
            })
        }
        Method::Dualistic { draft_k } => {
            let target = chain[0].as_ref();
            // xtask:allow(panic): dispatch_task validated the chain is non-empty.
            let draft = chain.last().expect("chain non-empty").as_ref();
            let cfg = dualistic::DualisticConfig {
                draft_k,
                rule: req.rule,
                sampling: req.sampling,
                max_new: req.max_new,
            };
            Ok(match state {
                None => Box::new(DualisticTask::new(target, draft, &req.prompt, cfg)?),
                Some(s) => Box::new(DualisticTask::resume(target, draft, &req.prompt, cfg, s)?),
            })
        }
        Method::Polybasic { draft_k, mu } => {
            let mut cfg = PolyConfig::for_chain(chain.len(), draft_k, mu, req.max_new);
            cfg.rule = req.rule;
            cfg.sampling = req.sampling;
            Ok(match state {
                None => Box::new(PolyTask::new(chain, &req.prompt, cfg)?),
                Some(s) => Box::new(PolyTask::resume(chain, &req.prompt, cfg, s)?),
            })
        }
    }
}

/// Open a resumable decode task for one request against a chain (target
/// first). The task borrows the chain and owns one scoring session per
/// member.
pub fn open_task<'m>(
    chain: &'m [Arc<dyn LanguageModel>],
    req: &Request,
) -> Result<Box<dyn DecodeTask + 'm>> {
    dispatch_task(chain, req, None)
}

/// Re-open a preempted request's decode from its captured [`ResumeState`].
/// Shares [`open_task`]'s Method dispatch, so a resumed task runs under
/// exactly the configuration the original did.
pub fn resume_task<'m>(
    chain: &'m [Arc<dyn LanguageModel>],
    req: &Request,
    state: ResumeState,
) -> Result<Box<dyn DecodeTask + 'm>> {
    dispatch_task(chain, req, Some(state))
}

/// Decode one request to completion (the single-shot path: CLI, benches).
/// Shares the Method-to-task dispatch with the serving path through
/// [`open_task`], so served and one-shot output cannot drift.
pub fn decode(chain: &[Arc<dyn LanguageModel>], req: &Request) -> Result<GenerationOutput> {
    for m in chain {
        m.reset_counters();
    }
    let mut task = open_task(chain, req)?;
    while !task.finished() {
        task.step()?;
    }
    Ok(task.finish())
}

/// Order a batch shortest-job-first by output budget (stable for ties).
pub fn sjf_order(batch: &mut [QueueEntry]) {
    batch.sort_by_key(|e| e.req.max_new);
}

/// Progress notifications emitted by [`run_batch`] as it schedules steps.
#[derive(Debug)]
pub enum BatchEvent<'a> {
    /// One decode step committed new tokens for request `id` (in order;
    /// concatenated deltas equal the final response's tokens). A request
    /// preempted and resumed mid-decode never re-emits tokens: deltas
    /// continue from where its last segment stopped.
    Delta { id: u64, tokens: &'a [Token] },
    /// Request `id` left the scheduler: finished, failed, or refused at
    /// task-open time. Carries the response by value — the scheduler
    /// retains nothing per completed request, so a server worker can stay
    /// inside one `run_batch` call indefinitely under sustained load
    /// without accumulating memory. Failures are typed: clients branch on
    /// the [`DecodeError`] class instead of parsing an error string.
    Done { id: u64, response: Result<Response, DecodeError> },
}

/// A request with a live decode task on this worker.
struct Live<'m> {
    req: Request,
    opened: Instant,
    /// Queue time accumulated over every queue segment (re-queues included).
    queue_time: Duration,
    /// Service time accumulated over run segments before the current one.
    prior_service: Duration,
    headroom: usize,
    ttft: Option<Duration>,
    /// Committed tokens already emitted as deltas (carried across
    /// preemption so nothing is re-delivered).
    streamed: usize,
    /// Times this request has been preempted so far.
    preemptions: u32,
    /// Chain-member drops already counted into the degradation metric, so
    /// each drop increments the counter exactly once across step sweeps
    /// and preemption cycles.
    degraded_seen: u32,
    task: Box<dyn DecodeTask + 'm>,
}

impl Live<'_> {
    /// End-to-end time this request has consumed: queue + service, summed
    /// across preemption segments — the quantity `Request::deadline` bounds.
    fn elapsed_total(&self) -> Duration {
        self.queue_time + self.prior_service + self.opened.elapsed()
    }
}

/// One preemption candidate as seen by the victim policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VictimInfo {
    /// Position in the live set.
    pub index: usize,
    /// Scheduling class: interactive tasks are preempted last.
    pub interactive: bool,
    /// KV blocks the task currently holds — evicting the largest holding
    /// frees the most pool per suspension.
    pub kv_blocks: usize,
}

/// Pick the task to preempt when the KV pool saturates: batch-class before
/// interactive, then the largest KV holding, ties broken by the highest
/// index (most recently admitted — LIFO, so the longest-running work keeps
/// its worker). Callers exclude the growing request themselves; it is
/// suspended only as a last resort when no other candidate exists.
pub fn select_victim(candidates: impl IntoIterator<Item = VictimInfo>) -> Option<usize> {
    candidates
        .into_iter()
        .max_by_key(|c| (!c.interactive, c.kv_blocks, c.index))
        .map(|c| c.index)
}

enum Opened<'m> {
    Live(Live<'m>),
    /// A resumed request the pool cannot re-admit yet; retried next pass.
    Deferred(QueueEntry),
    Failed { id: u64, err: DecodeError },
}

/// Open (or re-open) one queue entry as a live task, reserving KV for
/// resumed requests (fresh ones already hold their router reservation).
/// A request already past its deadline is refused here — before any
/// session opens — with its KV reservation (or resume debt) returned.
fn open_entry<'m>(
    chain: &'m [Arc<dyn LanguageModel>],
    entry: QueueEntry,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
) -> Opened<'m> {
    let QueueEntry { req, enqueued, resume } = entry;
    let opened = Instant::now();
    let headroom = pipeline_headroom(&req.method, chain.len());
    if let Some(deadline) = req.deadline {
        let spent = opened.duration_since(enqueued)
            + resume.as_ref().map_or(Duration::ZERO, |c| c.queue_time + c.service_time);
        if spent > deadline {
            let mut kvm = kv.lock();
            match &resume {
                None => {
                    // The router admitted it, so a KV reservation exists.
                    let released = kvm.release(req.id);
                    debug_assert!(
                        released.is_ok(),
                        "KV release failed for deadline-expired request {}: every \
                         admitted request must hold exactly one allocation ({released:?})",
                        req.id
                    );
                }
                Some(c) => {
                    // A preempted request holds no allocation, only the
                    // debt earmarked at suspension; hand that back (plus
                    // the swap reservation, if its KV was swapped out).
                    kvm.settle_resume_debt(
                        req.prompt.len() + c.state.committed.len() + headroom,
                    );
                    if let Some(h) = &c.state.swap {
                        kvm.discard_swap(h);
                    }
                }
            }
            drop(kvm);
            metrics.record_failure();
            metrics.record_deadline_cancel();
            return Opened::Failed { id: req.id, err: DecodeError::Timeout };
        }
    }
    let Some(mut carry) = resume else {
        return match open_task(chain, &req) {
            Ok(task) => {
                metrics.task_started();
                // A chain member can already be degraded away at open time
                // (health breaker open): count it now, once.
                let degraded_seen = task.degraded();
                if degraded_seen > 0 {
                    metrics.record_degradation(degraded_seen);
                }
                Opened::Live(Live {
                    headroom,
                    queue_time: opened.duration_since(enqueued),
                    prior_service: Duration::ZERO,
                    req,
                    opened,
                    ttft: None,
                    streamed: 0,
                    preemptions: 0,
                    degraded_seen,
                    task,
                })
            }
            Err(err) => {
                // The router admitted it, so the KV reservation exists
                // and must be returned even though no task ever ran.
                let released = kv.lock().release(req.id);
                debug_assert!(
                    released.is_ok(),
                    "KV release failed for request {}: every admitted request \
                     must hold exactly one allocation ({released:?})",
                    req.id
                );
                metrics.record_failure();
                Opened::Failed { id: req.id, err: DecodeError::classify(&err) }
            }
        };
    };

    // A preempted request released its KV at suspension; re-reserve its
    // live footprint (prompt + committed + headroom) before reopening.
    // Re-admission deliberately ignores resume debt — this request IS the
    // debt, earmarked at preemption. Two shapes:
    //   - swap-restored: the victim's blocks sat in the swap tier, so
    //     `restore` re-admits and the wasted-recompute accounting only
    //     counts what the tier did not hold (usually nothing);
    //   - discarded: prefix-aware re-admission (the request's own prompt
    //     or a shared prefix may still be block-cached), but the fresh
    //     sessions re-score the full prefix regardless, so prefix hits
    //     never reduce the wasted accounting — only swap does.
    let need = req.prompt.len() + carry.state.committed.len() + headroom;
    let full_recompute = need - headroom
        + match &carry.state.inflight {
            InflightState::Polybasic { drafted, .. } => drafted.len(),
            InflightState::None => 0,
        };
    let wasted;
    {
        let mut kvm = kv.lock();
        if !kvm.fits(need) {
            kvm.settle_resume_debt(need);
            if let Some(h) = &carry.state.swap {
                kvm.discard_swap(h);
            }
            metrics.record_failure();
            return Opened::Failed { id: req.id, err: DecodeError::Saturated };
        }
        match carry.state.swap.take() {
            Some(h) => {
                if kvm.restore(req.id, &h, need).is_err() {
                    // Saturated right now, but possible once space frees:
                    // someone else holds the pool (fits() just passed).
                    // Keep the swap reservation and retry later.
                    carry.state.swap = Some(h);
                    return Opened::Deferred(QueueEntry { req, enqueued, resume: Some(carry) });
                }
                wasted = full_recompute.saturating_sub(h.tokens);
            }
            None => {
                let mut content = req.prompt.clone();
                content.extend_from_slice(&carry.state.committed);
                if kvm.admit_resumed_prefixed(req.id, &content, need).is_err() {
                    return Opened::Deferred(QueueEntry { req, enqueued, resume: Some(carry) });
                }
                wasted = full_recompute;
            }
        }
        kvm.settle_resume_debt(need);
    }
    let ResumeCarry { state, streamed, ttft, queue_time, service_time, preemptions } = carry;
    let prior_degraded = state.degraded;
    match resume_task(chain, &req, state) {
        Ok(task) => {
            metrics.task_started();
            metrics.record_resume(wasted);
            // Drops before suspension were already counted; only members
            // that failed to re-open (new drops) increment the metric.
            let degraded_seen = task.degraded();
            if degraded_seen > prior_degraded {
                metrics.record_degradation(degraded_seen - prior_degraded);
            }
            Opened::Live(Live {
                headroom,
                queue_time: queue_time + opened.duration_since(enqueued),
                prior_service: service_time,
                req,
                opened,
                ttft,
                streamed,
                preemptions,
                degraded_seen,
                task,
            })
        }
        Err(err) => {
            let released = kv.lock().release(req.id);
            debug_assert!(
                released.is_ok(),
                "KV release failed for resumed request {}: re-admission just \
                 reserved it ({released:?})",
                req.id
            );
            metrics.record_failure();
            Opened::Failed { id: req.id, err: DecodeError::classify(&err) }
        }
    }
}

/// Suspend live task `v`, release its KV, and re-queue it with its resume
/// baggage — through the shared batcher's resumed lane when one exists,
/// else at the front of the local waiting queue.
fn preempt<'m>(
    v: usize,
    live: &mut Vec<Live<'m>>,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
    admit: Option<&DynamicBatcher>,
    waiting: &mut VecDeque<QueueEntry>,
) {
    let Live {
        req, opened, queue_time, prior_service, headroom, ttft, streamed, preemptions, task, ..
    } = live.remove(v);
    metrics.task_ended();
    metrics.record_preemption();
    let mut carry = ResumeCarry {
        state: task.suspend(),
        streamed,
        ttft,
        queue_time,
        service_time: prior_service + opened.elapsed(),
        preemptions: preemptions + 1,
    };
    {
        // Suspend atomically — release, debt-earmark, and swap-reserve
        // under ONE lock scope: a fresh router admission between release
        // and earmark would see the freed blocks with no debt and occupy
        // exactly the space the victim needs back. When the bounded swap
        // tier can hold the victim's KV content (prompt + committed +
        // in-flight draft), the resume path restores it instead of
        // re-scoring; a full tier degrades to the discard path.
        let drafted = match &carry.state.inflight {
            InflightState::Polybasic { drafted, .. } => drafted.len(),
            InflightState::None => 0,
        };
        let content = req.prompt.len() + carry.state.committed.len() + drafted;
        let resume_need = req.prompt.len() + carry.state.committed.len() + headroom;
        let suspended = kv.lock().suspend(req.id, content, resume_need);
        match suspended {
            Ok(handle) => carry.state.swap = handle,
            Err(e) => debug_assert!(
                false,
                "KV suspend failed for preempted request {}: every live task must \
                 hold exactly one allocation ({e:?})",
                req.id
            ),
        }
    }
    match admit {
        Some(queue) => queue.push_front_resumed(req, carry),
        None => {
            waiting.push_front(QueueEntry { enqueued: Instant::now(), req, resume: Some(carry) })
        }
    }
}

enum GrowOutcome {
    Grown,
    /// The growing task itself was suspended and re-queued (no other
    /// victim existed but other sequences hold pool space).
    SelfPreempted,
    /// The pool is smaller than this one request's live footprint; no
    /// eviction can help (surfaced as [`DecodeError::Saturated`]).
    Failed,
}

/// Grow `live[*i]`'s allocation to `target` tokens, evicting victims under
/// the class-then-cost policy until it fits. Adjusts `*i` when victims at
/// lower indices are removed.
fn grow_with_preemption<'m>(
    i: &mut usize,
    target: usize,
    live: &mut Vec<Live<'m>>,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
    admit: Option<&DynamicBatcher>,
    waiting: &mut VecDeque<QueueEntry>,
) -> GrowOutcome {
    loop {
        let id = live[*i].req.id;
        let (grown, fits, others) = {
            let mut kvm = kv.lock();
            (kvm.grow(id, target), kvm.fits(target), kvm.active_seqs() > 1)
        };
        if grown.is_ok() {
            return GrowOutcome::Grown;
        }
        if !fits {
            return GrowOutcome::Failed;
        }
        let victim = {
            let kvm = kv.lock();
            select_victim(live.iter().enumerate().filter_map(|(v, l)| {
                if v == *i || l.task.finished() {
                    return None;
                }
                Some(VictimInfo {
                    index: v,
                    interactive: classify(&l.req) == Priority::Interactive,
                    kv_blocks: kvm.seq_blocks(l.req.id).unwrap_or(0),
                })
            }))
        };
        match victim {
            Some(v) => {
                preempt(v, live, kv, metrics, admit, waiting);
                if v < *i {
                    *i -= 1;
                }
            }
            None if others => {
                // Sole live task on this worker, but queued reservations or
                // other workers hold the rest of the pool: suspend the
                // grower itself and resume it once space frees.
                preempt(*i, live, kv, metrics, admit, waiting);
                return GrowOutcome::SelfPreempted;
            }
            None => return GrowOutcome::Failed,
        }
    }
}

/// Scheduler tuning knobs for [`run_batch_opts`]; [`run_batch`] runs the
/// defaults.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerOpts {
    /// Coalesce the live tasks' planned session appends into one batched
    /// engine call per (chain member, sweep) — see the module docs. Off
    /// reproduces the per-task unbatched dispatch, byte-identically: the
    /// oracle the batched path is pinned against.
    pub coalesce: bool,
}

impl Default for SchedulerOpts {
    fn default() -> Self {
        Self { coalesce: true }
    }
}

/// The submit half of plan → submit → absorb (module docs): collect every
/// live task's planned append, group by chain member, issue **one**
/// [`append_batch`](LanguageModel::append_batch) per member holding any,
/// and hand each per-entry result back through
/// [`DecodeTask::absorb_append`]. Entry order is live order (the order
/// the sweep steps tasks), so fault-injection scripts observe batched
/// appends in the same sequence the unbatched dispatch would issue them.
/// A member whose `append_batch` returns `None` has no batched path; its
/// group's tasks silently fall back to in-step appends.
fn submit_batched(
    chain: &[Arc<dyn LanguageModel>],
    live: &mut [Live<'_>],
    metrics: &Arc<Metrics>,
) {
    let mut plans: Vec<(usize, PlannedAppend)> = Vec::new();
    for (i, l) in live.iter_mut().enumerate() {
        if let Some(p) = l.task.plan_append() {
            plans.push((i, p));
        }
    }
    if plans.is_empty() {
        return;
    }
    for (m, member) in chain.iter().enumerate() {
        let key = Arc::as_ptr(member) as *const () as usize;
        // An aliased chain entry (same Arc twice) batches at its first slot.
        if chain[..m].iter().any(|c| Arc::as_ptr(c) as *const () as usize == key) {
            continue;
        }
        let group: Vec<usize> = (0..plans.len()).filter(|&p| plans[p].1.model_key == key).collect();
        if group.is_empty() {
            continue;
        }
        let entries: Vec<(u64, Arc<[Token]>)> =
            group.iter().map(|&p| (plans[p].1.handle, plans[p].1.tokens.clone())).collect();
        let Some(results) = member.append_batch(&entries) else {
            continue;
        };
        let appended: usize = entries.iter().map(|(_, t)| t.len()).sum();
        // Recompute-avoided accounting: each planned append scored only its
        // suffix; a stateless engine would have re-scored `prefix_len` more
        // rows per session (what the KV cache makes O(suffix)).
        let avoided: usize = group.iter().map(|&p| plans[p].1.prefix_len).sum();
        metrics.record_engine_call(entries.len(), appended);
        metrics.record_suffix_work(appended, avoided);
        let mut results = results.into_iter();
        for &p in &group {
            let r = results
                .next()
                .unwrap_or_else(|| Err(anyhow::anyhow!("batched append reply missing an entry")));
            live[plans[p].0].task.absorb_append(r);
        }
    }
}

/// Continuous-batching decode of `batch` (plus anything `admit` delivers
/// while work is in flight) on this worker.
///
/// Round-robin, one step per live task per sweep; between sweeps up to
/// `max_live` tasks are kept alive by pulling newly queued requests from
/// `admit` — an interactive request completes while a long batch request
/// is still mid-decode instead of waiting behind it. A saturated KV pool
/// preempts a victim task (suspended + re-queued, resumed byte-identically
/// later) instead of failing anyone; see the module docs for the policy.
/// Returns when the live set and (momentarily) the admission queue are
/// empty. All output flows through `on_event`: every committed-token delta
/// as it lands, then one [`BatchEvent::Done`] per request in **completion
/// order** (failures surface as `Err` responses rather than silent drops).
/// KV for every request is released exactly once per run segment.
///
/// Runs with [`SchedulerOpts::default`] — batched verification on; see
/// [`run_batch_opts`] to change that.
pub fn run_batch(
    chain: &[Arc<dyn LanguageModel>],
    batch: Batch,
    admit: Option<&DynamicBatcher>,
    max_live: usize,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
    on_event: impl FnMut(BatchEvent<'_>),
) {
    run_batch_opts(chain, batch, admit, max_live, kv, metrics, SchedulerOpts::default(), on_event)
}

/// [`run_batch`] with explicit [`SchedulerOpts`].
#[allow(clippy::too_many_arguments)]
pub fn run_batch_opts(
    chain: &[Arc<dyn LanguageModel>],
    mut batch: Batch,
    admit: Option<&DynamicBatcher>,
    max_live: usize,
    kv: &Arc<Mutex<KvManager>>,
    metrics: &Arc<Metrics>,
    opts: SchedulerOpts,
    mut on_event: impl FnMut(BatchEvent<'_>),
) {
    let max_live = max_live.max(1);
    sjf_order(&mut batch);
    let mut waiting: VecDeque<QueueEntry> = batch.into();
    let mut live: Vec<Live<'_>> = Vec::new();

    loop {
        // ---- admission: new + resumed requests join between steps --------
        if let Some(queue) = admit {
            if live.len() + waiting.len() < max_live {
                waiting.extend(queue.try_pop(max_live - live.len() - waiting.len()));
            }
        }
        let mut deferred: Vec<QueueEntry> = Vec::new();
        while live.len() < max_live {
            let Some(entry) = waiting.pop_front() else { break };
            match open_entry(chain, entry, kv, metrics) {
                Opened::Live(l) => live.push(l),
                Opened::Deferred(entry) => deferred.push(entry),
                Opened::Failed { id, err } => {
                    on_event(BatchEvent::Done { id, response: Err(err) })
                }
            }
        }
        // Deferred resumed requests keep their place at the front.
        for entry in deferred.into_iter().rev() {
            waiting.push_front(entry);
        }

        if live.is_empty() {
            if waiting.is_empty() {
                break;
            }
            // Only deferred resumed requests remain. The pool space they
            // need may be reserved by *queued* fresh requests — pull one in
            // even though `waiting` is formally at capacity, because its
            // completion is exactly what frees the pool (otherwise a sole
            // worker would spin here forever while the fresh request that
            // holds the reservation never dispatches).
            if let Some(queue) = admit {
                let fresh = queue.try_pop(1);
                if !fresh.is_empty() {
                    waiting.extend(fresh);
                    continue;
                }
            }
            // Nothing to pull: space is held by other workers' tasks and
            // will free. Back off briefly and retry.
            crate::sync::thread::sleep(Duration::from_micros(200));
            continue;
        }

        // ---- submit: one batched engine call per chain member ------------
        if opts.coalesce {
            submit_batched(chain, &mut live, metrics);
        }
        // Publish this sweep's cache residency (gauge: overwrite, not add).
        metrics.set_cache_resident(kv.lock().resident_tokens());

        // ---- one sweep: one step per live task, round-robin --------------
        let mut i = 0;
        while i < live.len() {
            // Deadline enforcement at the step boundary: an overdue request
            // is cancelled before its next step. Dropping the task closes
            // every scoring session; the KV allocation is released below —
            // the same resources a normal completion returns, so a timeout
            // can never leak pool space.
            if live[i].req.deadline.is_some_and(|d| live[i].elapsed_total() > d) {
                let Live { req, task, .. } = live.remove(i);
                drop(task);
                metrics.task_ended();
                let released = kv.lock().release(req.id);
                debug_assert!(
                    released.is_ok(),
                    "KV release failed for deadline-cancelled request {}: every \
                     live task must hold exactly one allocation ({released:?})",
                    req.id
                );
                metrics.record_failure();
                metrics.record_deadline_cancel();
                on_event(BatchEvent::Done { id: req.id, response: Err(DecodeError::Timeout) });
                continue;
            }
            let mut step_err: Option<DecodeError> = None;
            let mut grow_target: Option<usize> = None;
            {
                let l = &mut live[i];
                match l.task.step() {
                    Ok(_) => {
                        // Chain members dropped by this step (graceful
                        // degradation) increment the metric exactly once.
                        let degraded = l.task.degraded();
                        if degraded > l.degraded_seen {
                            metrics.record_degradation(degraded - l.degraded_seen);
                            l.degraded_seen = degraded;
                        }
                        let committed_len = l.task.committed().len();
                        if committed_len > l.streamed {
                            if l.ttft.is_none() {
                                // First token of the whole request (resumed
                                // segments carry their TTFT over): time since
                                // the original enqueue across all segments.
                                let ttft = l.queue_time + l.prior_service + l.opened.elapsed();
                                l.ttft = Some(ttft);
                                metrics.record_first_token(ttft);
                            }
                            on_event(BatchEvent::Delta {
                                id: l.req.id,
                                tokens: &l.task.committed()[l.streamed..],
                            });
                            l.streamed = committed_len;
                            // Track the live length in the KV manager; a
                            // saturated pool preempts instead of failing.
                            // A task that just finished skips the grow: it
                            // releases its whole allocation a few lines
                            // down, so evicting a victim (or suspending a
                            // finished task, which suspend() forbids) to
                            // reserve headroom it will never use would be
                            // pure waste.
                            if !l.task.finished() {
                                let target = l.req.prompt.len() + l.streamed + l.headroom;
                                if kv
                                    .lock()
                                    .seq_tokens(l.req.id)
                                    .is_some_and(|cur| target > cur)
                                {
                                    grow_target = Some(target);
                                }
                            }
                        }
                    }
                    Err(e) => step_err = Some(DecodeError::classify(&e)),
                }
            }
            if let Some(target) = grow_target {
                let outcome =
                    grow_with_preemption(&mut i, target, &mut live, kv, metrics, admit, &mut waiting);
                match outcome {
                    GrowOutcome::Grown => {}
                    // live[i] was suspended + re-queued; the next task
                    // shifted into slot i.
                    GrowOutcome::SelfPreempted => continue,
                    // The pool can never host this request's footprint.
                    GrowOutcome::Failed => step_err = Some(DecodeError::Saturated),
                }
            }
            let finished = step_err.is_none() && live[i].task.finished();
            if step_err.is_none() && !finished {
                i += 1;
                continue;
            }

            // ---- completion: release KV, record metrics, emit ------------
            let Live { req, opened, queue_time, prior_service, ttft, preemptions, task, .. } =
                live.remove(i);
            metrics.task_ended();
            let id = req.id;
            let resp: Result<Response, DecodeError> = match step_err {
                Some(e) => {
                    let released = kv.lock().release(req.id);
                    debug_assert!(
                        released.is_ok(),
                        "KV release failed for request {}: every admitted request \
                         must hold exactly one allocation ({released:?})",
                        req.id
                    );
                    metrics.record_failure();
                    Err(e)
                }
                None => {
                    let gen = task.finish();
                    // Register the finished transcript (prompt + committed)
                    // in the prefix cache on the way out, so follow-up
                    // turns and prompt-sharing arrivals map these blocks
                    // instead of re-allocating. Cached blocks are free
                    // capacity: reclaimed LRU-first the moment admission
                    // needs them.
                    let mut content = req.prompt.clone();
                    content.extend_from_slice(&gen.tokens);
                    let released = kv.lock().release_cached(req.id, &content);
                    debug_assert!(
                        released.is_ok(),
                        "KV release failed for request {}: every admitted request \
                         must hold exactly one allocation ({released:?})",
                        req.id
                    );
                    let service_time = prior_service + opened.elapsed();
                    let mean_accept = gen.mean_accept();
                    metrics.record_completion(
                        queue_time,
                        service_time,
                        gen.tokens.len(),
                        gen.forward_passes.first().copied().unwrap_or(0),
                        mean_accept,
                        req.task.map(|t| t.label()),
                    );
                    Ok(Response {
                        id,
                        tokens: gen.tokens,
                        queue_time,
                        service_time,
                        ttft,
                        preemptions,
                        mean_accept,
                        forward_passes: gen.forward_passes,
                        degraded: gen.degraded,
                        task: req.task,
                        method: req.method,
                    })
                }
            };
            on_event(BatchEvent::Done { id, response: resp });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv::KvConfig;
    use crate::spec::mock::mock_chain;
    use crate::workload::tasks::TaskKind;

    fn mk_req(id: u64, max_new: usize, method: Method) -> Request {
        let mut r = Request::new(id, vec![1, 2, 3], max_new);
        r.method = method;
        r.task = Some(TaskKind::Qa);
        r
    }

    #[test]
    fn sjf_orders_by_budget() {
        let now = Instant::now();
        let mut batch = vec![
            QueueEntry::fresh(mk_req(1, 40, Method::Autoregressive), now),
            QueueEntry::fresh(mk_req(2, 10, Method::Autoregressive), now),
            QueueEntry::fresh(mk_req(3, 20, Method::Autoregressive), now),
        ];
        sjf_order(&mut batch);
        let ids: Vec<u64> = batch.iter().map(|e| e.req.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn victim_policy_prefers_batch_class_then_largest_holding() {
        // Batch-class beats interactive even with a smaller holding.
        let v = select_victim([
            VictimInfo { index: 0, interactive: true, kv_blocks: 50 },
            VictimInfo { index: 1, interactive: false, kv_blocks: 2 },
        ]);
        assert_eq!(v, Some(1));
        // Within a class, the largest holding goes first.
        let v = select_victim([
            VictimInfo { index: 0, interactive: false, kv_blocks: 3 },
            VictimInfo { index: 1, interactive: false, kv_blocks: 9 },
            VictimInfo { index: 2, interactive: false, kv_blocks: 4 },
        ]);
        assert_eq!(v, Some(1));
        // All interactive: still picks the largest holding.
        let v = select_victim([
            VictimInfo { index: 0, interactive: true, kv_blocks: 3 },
            VictimInfo { index: 1, interactive: true, kv_blocks: 7 },
        ]);
        assert_eq!(v, Some(1));
        // Ties: most recently admitted (highest index) is evicted.
        let v = select_victim([
            VictimInfo { index: 0, interactive: false, kv_blocks: 5 },
            VictimInfo { index: 3, interactive: false, kv_blocks: 5 },
        ]);
        assert_eq!(v, Some(3));
        assert_eq!(select_victim(Vec::<VictimInfo>::new()), None);
    }

    #[test]
    fn runs_all_methods_and_releases_kv() {
        let chain = mock_chain(512, 24, 5);
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let now = Instant::now();
        let batch: Vec<_> = [
            Method::Autoregressive,
            Method::Dualistic { draft_k: 3 },
            Method::Polybasic { draft_k: 3, mu: 4 },
        ]
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            let req = mk_req(i as u64, 12, m);
            kv.lock().admit(req.id, 40).unwrap();
            QueueEntry::fresh(req, now)
        })
        .collect();
        let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
        run_batch(&chain, batch, None, 4, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        assert_eq!(out.len(), 3);
        for r in &out {
            let resp = r.as_ref().unwrap();
            assert_eq!(resp.tokens.len(), 12);
            assert!(resp.ttft.is_some());
        }
        assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
        assert_eq!(metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 3);
        assert_eq!(metrics.inflight(), 0);
        assert!(metrics.inflight_peak() >= 2, "steps should interleave");
        assert_eq!(metrics.ttft_latency.count(), 3);
    }

    #[test]
    fn response_mean_accept_matches_generation_output() {
        let chain = mock_chain(512, 24, 9);
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let req = mk_req(1, 16, Method::Polybasic { draft_k: 3, mu: 4 });
        kv.lock().admit(1, 60).unwrap();
        let gen = decode(&chain, &req).unwrap();
        let batch = vec![QueueEntry::fresh(req, Instant::now())];
        let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
        run_batch(&chain, batch, None, 1, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        let resp = out[0].as_ref().unwrap();
        assert_eq!(resp.tokens, gen.tokens, "stepped serving must match one-shot decode");
        assert!(
            (resp.mean_accept - gen.mean_accept()).abs() < 1e-12,
            "response mean_accept {} != generation {}",
            resp.mean_accept,
            gen.mean_accept()
        );
    }

    #[test]
    fn open_failure_releases_kv_and_reports_error() {
        let chain = mock_chain(64, 24, 5); // tiny context
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        // max_new far beyond the 64-token context: task open must fail.
        let req = mk_req(1, 600, Method::Polybasic { draft_k: 3, mu: 4 });
        kv.lock().admit(1, 30).unwrap();
        let batch = vec![QueueEntry::fresh(req, Instant::now())];
        let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
        run_batch(&chain, batch, None, 2, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        assert_eq!(out.len(), 1);
        assert!(out[0].is_err());
        assert_eq!(kv.lock().active_seqs(), 0, "KV leaked on open failure");
        assert_eq!(metrics.requests_failed.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn zero_budget_request_reports_no_ttft() {
        // A request that commits zero tokens has no first token: the
        // response's ttft must be None (not a queue+service fallback) and
        // the TTFT histogram must stay empty.
        let chain = mock_chain(512, 24, 5);
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let req = mk_req(1, 0, Method::Autoregressive);
        kv.lock().admit(1, 10).unwrap();
        let batch = vec![QueueEntry::fresh(req, Instant::now())];
        let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
        run_batch(&chain, batch, None, 1, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        let resp = out[0].as_ref().unwrap();
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.ttft, None, "no first token -> no TTFT");
        assert_eq!(metrics.ttft_latency.count(), 0, "histogram must not see a fake TTFT");
        assert_eq!(kv.lock().active_seqs(), 0);
    }

    #[test]
    fn coalesced_sweep_issues_one_engine_call_per_tick() {
        // B identical autoregressive requests against one target: every
        // sweep plans B appends and submits ONE batched call, so the
        // target observes exactly T calls (one per tick) instead of B×T —
        // while every response stays byte-identical to the one-shot
        // decode oracle.
        const B: u64 = 4;
        const T: usize = 10;
        let chain = mock_chain(512, 24, 5);
        let oracle = decode(&chain, &mk_req(0, T, Method::Autoregressive)).unwrap();
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
        let metrics = Arc::new(Metrics::default());
        let now = Instant::now();
        let batch: Vec<_> = (0..B)
            .map(|id| {
                let req = mk_req(id, T, Method::Autoregressive);
                kv.lock().admit(req.id, 40).unwrap();
                QueueEntry::fresh(req, now)
            })
            .collect();
        for m in &chain {
            m.reset_counters();
        }
        let mut out: Vec<Result<Response, DecodeError>> = Vec::new();
        run_batch(&chain, batch, None, B as usize, &kv, &metrics, |ev| {
            if let BatchEvent::Done { response, .. } = ev {
                out.push(response);
            }
        });
        assert_eq!(out.len(), B as usize);
        for r in &out {
            assert_eq!(r.as_ref().unwrap().tokens, oracle.tokens, "batched decode diverged");
        }
        assert_eq!(chain[0].calls(), T as u64, "one engine call per (member, tick)");
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.engine_calls.load(Ordering::Relaxed), T as u64);
        assert_eq!(metrics.batched_calls.load(Ordering::Relaxed), T as u64);
        assert_eq!(metrics.batch_occupancy.max(), B);
        assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
    }

    #[test]
    fn unbatched_opts_reproduce_batched_output() {
        // Mixed-method live set, coalescing on vs off: committed tokens
        // must be byte-identical (absorbed batched rows are bit-identical
        // to the in-step appends they replace).
        let methods = [
            Method::Autoregressive,
            Method::Dualistic { draft_k: 3 },
            Method::Polybasic { draft_k: 3, mu: 4 },
        ];
        let run = |coalesce: bool| -> Vec<(u64, Vec<Token>)> {
            let chain = mock_chain(512, 24, 7);
            let kv = Arc::new(Mutex::new(KvManager::new(KvConfig::default())));
            let metrics = Arc::new(Metrics::default());
            let now = Instant::now();
            let batch: Vec<_> = methods
                .iter()
                .enumerate()
                .map(|(i, &m)| {
                    let req = mk_req(i as u64, 12, m);
                    kv.lock().admit(req.id, 60).unwrap();
                    QueueEntry::fresh(req, now)
                })
                .collect();
            let mut out: Vec<(u64, Vec<Token>)> = Vec::new();
            run_batch_opts(
                &chain,
                batch,
                None,
                4,
                &kv,
                &metrics,
                SchedulerOpts { coalesce },
                |ev| {
                    if let BatchEvent::Done { id, response } = ev {
                        out.push((id, response.unwrap().tokens));
                    }
                },
            );
            out.sort_by_key(|&(id, _)| id);
            out
        };
        assert_eq!(run(true), run(false), "coalescing changed committed output");
    }
}
