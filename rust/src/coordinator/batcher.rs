//! Dynamic batcher: the admission queue feeding the continuous-batching
//! step scheduler, with priority classes and a starvation guard.
//!
//! Two ways out of the queue:
//!
//! * [`DynamicBatcher::pop_batch`] — blocking pull of an *initial* batch
//!   under a size-or-deadline policy (vLLM-style); an idle worker parks
//!   here until work arrives.
//! * [`DynamicBatcher::try_pop`] — non-blocking pull the step scheduler
//!   calls **between decode steps**, so new requests join a mid-flight
//!   round-robin instead of waiting for the running work to drain (the
//!   continuous-batching admission path; see `coordinator::scheduler`).
//!
//! Interactive requests are drained before batch-class ones, except that a
//! batch-class request that has waited longer than
//! [`BatchPolicy::starvation_wait`] is promoted ahead of the interactive
//! queue — sustained interactive load can no longer starve batch traffic.
//!
//! Two fairness refinements on top of the class policy:
//!
//! * **Resumed lane** — a request preempted mid-decode (KV saturation; see
//!   `coordinator::scheduler`) re-enters through
//!   [`DynamicBatcher::push_front_resumed`], which puts it at the *front*
//!   of its class queue: a resumed request outranks every fresh arrival of
//!   the same class, so preemption delays work but never re-queues it
//!   behind traffic that arrived later.
//! * **Parked-worker reservation** — a busy worker's between-step
//!   [`try_pop`](DynamicBatcher::try_pop) used to outrace an idle worker
//!   parked in [`pop_batch`](DynamicBatcher::pop_batch), concentrating
//!   arrivals on one thread. The queue now tracks how many workers are
//!   parked and `try_pop` leaves that many requests behind for them.

use std::collections::VecDeque;
use std::time::Duration;

use crate::sync::time::Instant;
use crate::sync::{Condvar, Mutex};

use super::api::{Request, ResumeCarry};

/// Scheduling class, derived from the task tag: interactive tasks preempt
/// long-form batch tasks in the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    Batch,
}

pub fn classify(req: &Request) -> Priority {
    use crate::workload::tasks::TaskKind::*;
    match req.task {
        Some(MultiTurn) | Some(Qa) | Some(Math) => Priority::Interactive,
        Some(Summarization) | Some(Rag) | Some(Translation) => Priority::Batch,
        None => Priority::Interactive,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests per initial batch AND maximum decode tasks a worker
    /// keeps in flight at once (the continuous-batching concurrency cap).
    pub max_batch: usize,
    /// Dispatch a partial batch once its oldest member waited this long.
    /// Under continuous batching stragglers also join mid-flight via
    /// [`DynamicBatcher::try_pop`], so this window only shapes the
    /// *initial* batch; latency-sensitive deployments can set it to zero
    /// to shave its cost off time-to-first-token at an idle server.
    pub max_wait: Duration,
    /// Anti-starvation: a batch-class request that has queued this long is
    /// drained ahead of interactive requests.
    pub starvation_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            starvation_wait: Duration::from_millis(250),
        }
    }
}

#[derive(Debug)]
struct Queued {
    req: Request,
    enqueued: Instant,
    /// Present when this is a preempted request re-entering the queue.
    resume: Option<ResumeCarry>,
}

#[derive(Debug, Default)]
struct State {
    interactive: VecDeque<Queued>,
    batch: VecDeque<Queued>,
    closed: bool,
    /// Workers currently blocked in [`DynamicBatcher::pop_batch`];
    /// [`DynamicBatcher::try_pop`] leaves this many requests for them.
    parked: usize,
}

/// Thread-safe request queue with batching semantics.
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    cv: Condvar,
}

/// One dispatched request: the request, its queue-entry timestamp, and —
/// for a preempted request re-entering the scheduler — its resume baggage.
#[derive(Debug)]
pub struct QueueEntry {
    pub req: Request,
    pub enqueued: Instant,
    pub resume: Option<ResumeCarry>,
}

impl QueueEntry {
    /// A fresh (non-resumed) entry — the shape tests and the one-shot path
    /// construct directly.
    pub fn fresh(req: Request, enqueued: Instant) -> Self {
        Self { req, enqueued, resume: None }
    }
}

/// A dispatched batch.
pub type Batch = Vec<QueueEntry>;

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    pub fn push(&self, req: Request) {
        let mut st = self.state.lock();
        let q = Queued { req, enqueued: Instant::now(), resume: None };
        match classify(&q.req) {
            Priority::Interactive => st.interactive.push_back(q),
            Priority::Batch => st.batch.push_back(q),
        }
        self.cv.notify_one();
    }

    /// Re-queue a preempted request at the *front* of its class queue: it
    /// outranks every fresh arrival of the same class, so KV-pressure
    /// preemption delays its decode but never demotes it behind later
    /// traffic. Accepted even after [`close`](Self::close) — a preempted
    /// request is in-flight work that must drain, not a new arrival.
    pub fn push_front_resumed(&self, req: Request, carry: ResumeCarry) {
        let mut st = self.state.lock();
        let q = Queued { req, enqueued: Instant::now(), resume: Some(carry) };
        match classify(&q.req) {
            Priority::Interactive => st.interactive.push_front(q),
            Priority::Batch => st.batch.push_front(q),
        }
        self.cv.notify_one();
    }

    /// Workers currently parked in [`pop_batch`](Self::pop_batch).
    pub fn parked_workers(&self) -> usize {
        self.state.lock().parked
    }

    pub fn len(&self) -> usize {
        let st = self.state.lock();
        st.interactive.len() + st.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop accepting work and wake all waiting workers.
    pub fn close(&self) {
        self.state.lock().closed = true;
        self.cv.notify_all();
    }

    /// Blocking pull: returns `None` only when the queue is closed AND
    /// drained. Interactive requests are drained first, subject to the
    /// starvation guard.
    pub fn pop_batch(&self) -> Option<Batch> {
        let mut st = self.state.lock();
        loop {
            let total = st.interactive.len() + st.batch.len();
            if total > 0 {
                // Dispatch immediately when full, otherwise wait out the
                // batching window for stragglers.
                if total < self.policy.max_batch && !st.closed {
                    let oldest = st
                        .interactive
                        .front()
                        .iter()
                        .chain(st.batch.front().iter())
                        .map(|q| q.enqueued)
                        .min()
                        // xtask:allow(panic): total > 0 guarantees a queue front.
                        .unwrap();
                    let waited = oldest.elapsed();
                    if waited < self.policy.max_wait {
                        st.parked += 1;
                        let (next, _timeout) =
                            self.cv.wait_timeout(st, self.policy.max_wait - waited);
                        st = next;
                        st.parked -= 1;
                        continue;
                    }
                }
                return Some(self.drain_locked(&mut st, self.policy.max_batch));
            }
            if st.closed {
                return None;
            }
            st.parked += 1;
            st = self.cv.wait(st);
            st.parked -= 1;
        }
    }

    /// Non-blocking pull of up to `n` requests — the step scheduler's
    /// between-steps admission path. Returns an empty batch when the queue
    /// is idle; never waits out the batching window. One request is left
    /// behind per worker parked in [`pop_batch`](Self::pop_batch), so a
    /// busy worker topping up between steps cannot drain arrivals out from
    /// under idle workers (multi-worker pull fairness).
    pub fn try_pop(&self, n: usize) -> Batch {
        let mut st = self.state.lock();
        let queued = st.interactive.len() + st.batch.len();
        let reserve = st.parked.min(queued);
        self.drain_locked(&mut st, n.min(queued - reserve))
    }

    /// Drain up to `n` queued requests under the priority policy:
    /// interactive first, except that a batch-class head past
    /// `starvation_wait` is promoted.
    fn drain_locked(&self, st: &mut State, n: usize) -> Batch {
        let mut out: Batch = Vec::with_capacity(n.min(st.interactive.len() + st.batch.len()));
        while out.len() < n {
            let starved = st
                .batch
                .front()
                .is_some_and(|q| q.enqueued.elapsed() >= self.policy.starvation_wait);
            let q = if starved {
                st.batch.pop_front()
            } else {
                st.interactive.pop_front().or_else(|| st.batch.pop_front())
            };
            match q {
                Some(q) => {
                    out.push(QueueEntry { req: q.req, enqueued: q.enqueued, resume: q.resume })
                }
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tasks::TaskKind;

    fn req(id: u64, task: Option<TaskKind>) -> Request {
        let mut r = Request::new(id, vec![1, 2], 4);
        r.task = task;
        r
    }

    #[test]
    fn batches_up_to_max() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::ZERO, ..Default::default() });
        for i in 0..3 {
            b.push(req(i, None));
        }
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn interactive_preempts_batch() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 1, max_wait: Duration::ZERO, ..Default::default() });
        b.push(req(1, Some(TaskKind::Summarization)));
        b.push(req(2, Some(TaskKind::Math)));
        let first = b.pop_batch().unwrap();
        assert_eq!(first[0].req.id, 2, "interactive request should dispatch first");
    }

    #[test]
    fn try_pop_is_nonblocking_and_bounded() {
        let b = DynamicBatcher::new(BatchPolicy::default());
        assert!(b.try_pop(4).is_empty(), "idle queue must return immediately");
        for i in 0..3 {
            b.push(req(i, None));
        }
        let got = b.try_pop(2);
        assert_eq!(got.len(), 2);
        assert_eq!(b.try_pop(2).len(), 1);
        assert!(b.try_pop(2).is_empty());
    }

    #[test]
    fn starved_batch_request_promoted_over_interactive() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::ZERO,
            starvation_wait: Duration::from_millis(10),
        });
        b.push(req(1, Some(TaskKind::Summarization))); // batch class
        std::thread::sleep(Duration::from_millis(15)); // let it starve
        b.push(req(2, Some(TaskKind::Math))); // interactive
        b.push(req(3, Some(TaskKind::Qa))); // interactive
        let got = b.try_pop(2);
        assert_eq!(got[0].req.id, 1, "starved batch request must be promoted");
        assert_eq!(got[1].req.id, 2);
    }

    #[test]
    fn fresh_batch_request_still_yields_to_interactive() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::ZERO,
            starvation_wait: Duration::from_secs(60),
        });
        b.push(req(1, Some(TaskKind::Summarization)));
        b.push(req(2, Some(TaskKind::Math)));
        let got = b.try_pop(2);
        assert_eq!(got[0].req.id, 2);
        assert_eq!(got[1].req.id, 1);
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO, ..Default::default() });
        b.push(req(1, None));
        b.close();
        assert!(b.pop_batch().is_some());
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        use std::sync::Arc;
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
            ..Default::default()
        }));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.pop_batch().map(|v| v[0].req.id));
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(7, None));
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn waits_for_stragglers_within_window() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(30),
            ..Default::default()
        });
        b.push(req(1, None));
        let t0 = Instant::now();
        let handle = {
            use std::sync::Arc;
            let b = Arc::new(b);
            let b2 = b.clone();
            let h = std::thread::spawn(move || b2.pop_batch().map(|v| v.len()));
            std::thread::sleep(Duration::from_millis(5));
            b.push(req(2, None));
            h
        };
        assert_eq!(handle.join().unwrap(), Some(2), "straggler should join the batch");
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    fn dummy_carry() -> ResumeCarry {
        ResumeCarry {
            state: crate::spec::task::ResumeState {
                committed: vec![],
                rng: crate::spec::rng::Pcg32::seeded(0),
                accept_lengths: vec![],
                stage_accepts: vec![],
                wall: Duration::ZERO,
                forward_passes: vec![0],
                forward_time: vec![Duration::ZERO],
                inflight: crate::spec::task::InflightState::None,
                live_models: vec![0],
                degraded: 0,
                swap: None,
            },
            streamed: 0,
            ttft: None,
            queue_time: Duration::ZERO,
            service_time: Duration::ZERO,
            preemptions: 1,
        }
    }

    #[test]
    fn resumed_request_outranks_fresh_same_class() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
            starvation_wait: Duration::from_secs(60),
        });
        // Fresh arrivals of both classes, then a preempted batch-class
        // request re-enters: it must pop before the fresh batch-class one
        // but still yield to fresh interactive traffic (class order wins
        // between classes; resumption wins within a class).
        b.push(req(1, Some(TaskKind::Summarization))); // fresh batch
        b.push(req(2, Some(TaskKind::Math))); // fresh interactive
        b.push_front_resumed(req(3, Some(TaskKind::Rag)), dummy_carry()); // resumed batch
        let got = b.try_pop(3);
        let ids: Vec<u64> = got.iter().map(|e| e.req.id).collect();
        assert_eq!(ids, vec![2, 3, 1], "resumed batch request must lead its class");
        assert!(got[1].resume.is_some(), "resume baggage must survive the queue");
        assert!(got[0].resume.is_none() && got[2].resume.is_none());
    }

    #[test]
    fn resumed_interactive_outranks_fresh_interactive() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
            ..Default::default()
        });
        b.push(req(1, Some(TaskKind::Qa)));
        b.push_front_resumed(req(2, Some(TaskKind::Math)), dummy_carry());
        let got = b.try_pop(2);
        assert_eq!(got[0].req.id, 2, "resumed interactive must lead the interactive lane");
        assert_eq!(got[1].req.id, 1);
    }

    #[test]
    fn resumed_request_accepted_after_close() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO, ..Default::default() });
        b.close();
        b.push_front_resumed(req(9, None), dummy_carry());
        let batch = b.pop_batch().expect("in-flight work must drain after close");
        assert_eq!(batch[0].req.id, 9);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn try_pop_leaves_work_for_parked_workers() {
        use std::sync::Arc;
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
            ..Default::default()
        }));
        // Park a worker on the empty queue.
        let b2 = b.clone();
        let parked = std::thread::spawn(move || b2.pop_batch().map(|v| v[0].req.id));
        while b.parked_workers() == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // A lone arrival is reserved for the parked worker: the busy
        // worker's between-step top-up must come back empty.
        {
            let mut st = b.state.lock();
            st.interactive.push_back(Queued {
                req: req(1, None),
                enqueued: Instant::now(),
                resume: None,
            });
            // No notify: keep the worker parked to observe the reservation.
        }
        assert!(
            b.try_pop(4).is_empty(),
            "try_pop must leave the lone request for the parked worker"
        );
        // With two queued, try_pop may take at most one.
        b.push(req(2, None));
        let got = b.try_pop(4);
        assert!(got.len() <= 1, "try_pop must reserve one request per parked worker");
        // Wake the parked worker; it gets the reserved request.
        b.cv.notify_all();
        let woken = parked.join().unwrap();
        assert!(woken.is_some(), "parked worker must receive the reserved request");
    }
}
