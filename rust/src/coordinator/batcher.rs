//! Dynamic batcher: groups queued requests into dispatch batches under a
//! size-or-deadline policy (vLLM-style), with priority classes.
//!
//! The paper's SpecBench protocol is batch-1 *decoding*; batching here
//! operates at the request-dispatch level — workers pull batches and decode
//! their members, so a multi-worker server drains bursts in parallel while
//! a single worker degrades gracefully to FCFS.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::api::Request;

/// Scheduling class, derived from the task tag: interactive tasks preempt
/// long-form batch tasks in the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    Batch,
}

pub fn classify(req: &Request) -> Priority {
    use crate::workload::tasks::TaskKind::*;
    match req.task {
        Some(MultiTurn) | Some(Qa) | Some(Math) => Priority::Interactive,
        Some(Summarization) | Some(Rag) | Some(Translation) => Priority::Batch,
        None => Priority::Interactive,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Dispatch a partial batch once its oldest member waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(5) }
    }
}

#[derive(Debug)]
struct Queued {
    req: Request,
    enqueued: Instant,
}

#[derive(Debug, Default)]
struct State {
    interactive: VecDeque<Queued>,
    batch: VecDeque<Queued>,
    closed: bool,
}

/// Thread-safe request queue with batching semantics.
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    cv: Condvar,
}

/// A dispatched batch: requests plus their queue-entry timestamps.
pub type Batch = Vec<(Request, Instant)>;

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    pub fn push(&self, req: Request) {
        let mut st = self.state.lock().unwrap();
        let q = Queued { req, enqueued: Instant::now() };
        match classify(&q.req) {
            Priority::Interactive => st.interactive.push_back(q),
            Priority::Batch => st.batch.push_back(q),
        }
        self.cv.notify_one();
    }

    pub fn len(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.interactive.len() + st.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop accepting work and wake all waiting workers.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Blocking pull: returns `None` only when the queue is closed AND
    /// drained. Interactive requests are always drained first.
    pub fn pop_batch(&self) -> Option<Batch> {
        let mut st = self.state.lock().unwrap();
        loop {
            let total = st.interactive.len() + st.batch.len();
            if total > 0 {
                // Dispatch immediately when full, otherwise wait out the
                // batching window for stragglers.
                if total < self.policy.max_batch && !st.closed {
                    let oldest = st
                        .interactive
                        .front()
                        .iter()
                        .chain(st.batch.front().iter())
                        .map(|q| q.enqueued)
                        .min()
                        .unwrap();
                    let waited = oldest.elapsed();
                    if waited < self.policy.max_wait {
                        let (next, _timeout) =
                            self.cv.wait_timeout(st, self.policy.max_wait - waited).unwrap();
                        st = next;
                        continue;
                    }
                }
                let mut out: Batch = Vec::with_capacity(self.policy.max_batch);
                while out.len() < self.policy.max_batch {
                    let q = if let Some(q) = st.interactive.pop_front() {
                        q
                    } else if let Some(q) = st.batch.pop_front() {
                        q
                    } else {
                        break;
                    };
                    out.push((q.req, q.enqueued));
                }
                return Some(out);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tasks::TaskKind;

    fn req(id: u64, task: Option<TaskKind>) -> Request {
        let mut r = Request::new(id, vec![1, 2], 4);
        r.task = task;
        r
    }

    #[test]
    fn batches_up_to_max() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::ZERO });
        for i in 0..3 {
            b.push(req(i, None));
        }
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn interactive_preempts_batch() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 1, max_wait: Duration::ZERO });
        b.push(req(1, Some(TaskKind::Summarization)));
        b.push(req(2, Some(TaskKind::Math)));
        let first = b.pop_batch().unwrap();
        assert_eq!(first[0].0.id, 2, "interactive request should dispatch first");
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
        b.push(req(1, None));
        b.close();
        assert!(b.pop_batch().is_some());
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        use std::sync::Arc;
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.pop_batch().map(|v| v[0].0.id));
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(7, None));
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn waits_for_stragglers_within_window() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(30),
        });
        b.push(req(1, None));
        let t0 = Instant::now();
        let handle = {
            use std::sync::Arc;
            let b = Arc::new(b);
            let b2 = b.clone();
            let h = std::thread::spawn(move || b2.pop_batch().map(|v| v.len()));
            std::thread::sleep(Duration::from_millis(5));
            b.push(req(2, None));
            h
        };
        assert_eq!(handle.join().unwrap(), Some(2), "straggler should join the batch");
        assert!(t0.elapsed() < Duration::from_millis(200));
    }
}
