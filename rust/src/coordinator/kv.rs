//! Paged KV-cache manager.
//!
//! The paper (§4.6) identifies multi-model KV footprint as the binding
//! resource of polybasic serving: every chain member keeps its own cache,
//! so capacity scales with the chain.  Our AOT substrate recomputes
//! attention per forward (DESIGN.md §7), so the *bytes* here are an
//! accounting model rather than live buffers — but the allocator, admission
//! control and utilization accounting are the real thing and gate the
//! router exactly as a vLLM-style block manager would.
//!
//! Under continuous batching a sequence's allocation tracks its **live
//! length**: the router admits `prompt + speculative headroom`, and the
//! step scheduler [`grow`](KvManager::grow)s the allocation as tokens
//! commit ([`seq_tokens`](KvManager::seq_tokens) reports the tracked
//! length).  Admission therefore deliberately overcommits: it reserves
//! what a request *holds*, not its worst-case finished size, so more
//! concurrent sequences fit.  The bill comes due when a mid-decode `grow`
//! finds the pool saturated.  The scheduler resolves that by
//! **preemption, not failure**: it suspends a victim task (batch-class
//! before interactive, largest holding first — see
//! `scheduler::select_victim`), [`release`](KvManager::release)s the
//! victim's blocks, and re-queues it with its full decode state; the
//! victim re-reserves `prompt + committed + headroom` through
//! [`admit`](KvManager::admit) once space frees and resumes
//! byte-identically.  A `grow` error therefore never surfaces to a client
//! unless the pool is smaller than one lone request's footprint
//! ([`fits`](KvManager::fits) is false) — genuine capacity overflow, the
//! only case that still fails.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Block-granular allocator configuration.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    /// Tokens per block (vLLM-style paging granularity).
    pub block_size: usize,
    /// Total number of blocks in the (simulated) KV pool.
    pub total_blocks: usize,
    /// Bytes of KV per token *per chain member* (2 x layers x d_model x 4,
    /// summed over the chain), used for byte-level reporting.
    pub bytes_per_token: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        Self { block_size: 16, total_blocks: 256, bytes_per_token: 0 }
    }
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    blocks: usize,
    tokens: usize,
}

/// Tracks block allocation per active sequence.
#[derive(Debug)]
pub struct KvManager {
    cfg: KvConfig,
    free_blocks: usize,
    seqs: BTreeMap<u64, SeqAlloc>,
    /// High-water mark of allocated blocks (reporting).
    peak_blocks: usize,
    /// Blocks owed to preempted requests awaiting re-admission,
    /// accumulated per debtor (each preemption contributes
    /// `blocks_for(its footprint)`, so rounding never under-reserves).
    /// Fresh admissions ([`admit_fresh`](Self::admit_fresh)) must leave
    /// this many blocks free, so sustained fresh load cannot grab every
    /// freed block ahead of a request the scheduler already suspended —
    /// the resumed lane's queue priority, enforced at the KV altitude
    /// where the contention actually is.
    resume_debt_blocks: usize,
}

impl KvManager {
    pub fn new(cfg: KvConfig) -> Self {
        Self {
            free_blocks: cfg.total_blocks,
            cfg,
            seqs: BTreeMap::new(),
            peak_blocks: 0,
            resume_debt_blocks: 0,
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    /// Can a sequence of `tokens` total length be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free_blocks
    }

    /// Could a sequence of `tokens` total length *ever* fit, i.e. with the
    /// whole pool free? False means no amount of preemption helps.
    pub fn fits(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.cfg.total_blocks
    }

    /// Reserve blocks for a new sequence (prompt + planned generation).
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            bail!(
                "KV pool exhausted: need {need} blocks, {} free of {}",
                self.free_blocks,
                self.cfg.total_blocks
            );
        }
        self.free_blocks -= need;
        self.seqs.insert(seq, SeqAlloc { blocks: need, tokens });
        self.peak_blocks = self.peak_blocks.max(self.allocated_blocks());
        Ok(())
    }

    /// Admission for **fresh** arrivals (the router's path): like
    /// [`admit`](Self::admit), but refuses to eat into the blocks owed to
    /// preempted requests awaiting re-admission. Preempted requests
    /// re-admit through plain `admit`, which ignores the debt they are
    /// owed.
    pub fn admit_fresh(&mut self, seq: u64, tokens: usize) -> Result<()> {
        let owed = self.resume_debt_blocks;
        let need = self.blocks_for(tokens);
        if need + owed > self.free_blocks {
            bail!(
                "KV pool exhausted: need {need} blocks, {} free of {} \
                 ({owed} blocks owed to preempted requests)",
                self.free_blocks,
                self.cfg.total_blocks
            );
        }
        self.admit(seq, tokens)
    }

    /// Record that a preempted request will need `tokens` of pool to
    /// resume; fresh admissions keep `blocks_for(tokens)` blocks free
    /// until [`settle_resume_debt`](Self::settle_resume_debt). Converted
    /// to blocks per call, so several concurrent debtors' rounding never
    /// under-reserves.
    pub fn add_resume_debt(&mut self, tokens: usize) {
        self.resume_debt_blocks += self.blocks_for(tokens);
    }

    /// The preempted request re-admitted (or permanently failed): stop
    /// holding pool back on its behalf. Pass the same token count given
    /// to [`add_resume_debt`](Self::add_resume_debt).
    pub fn settle_resume_debt(&mut self, tokens: usize) {
        self.resume_debt_blocks = self.resume_debt_blocks.saturating_sub(self.blocks_for(tokens));
    }

    /// Blocks currently owed to preempted requests.
    pub fn resume_debt(&self) -> usize {
        self.resume_debt_blocks
    }

    /// Grow an existing sequence to `tokens` total length.
    pub fn grow(&mut self, seq: u64, tokens: usize) -> Result<()> {
        let need = self.blocks_for(tokens);
        let alloc = match self.seqs.get_mut(&seq) {
            Some(a) => a,
            None => bail!("sequence {seq} not admitted"),
        };
        if tokens < alloc.tokens {
            bail!("sequence {seq} cannot shrink via grow()");
        }
        let extra = need.saturating_sub(alloc.blocks);
        if extra > self.free_blocks {
            bail!("KV pool exhausted growing seq {seq}");
        }
        self.free_blocks -= extra;
        alloc.blocks += extra;
        alloc.tokens = tokens;
        self.peak_blocks = self.peak_blocks.max(self.allocated_blocks());
        Ok(())
    }

    /// Release a finished sequence.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        match self.seqs.remove(&seq) {
            Some(a) => {
                self.free_blocks += a.blocks;
                Ok(())
            }
            None => bail!("sequence {seq} not admitted"),
        }
    }

    /// Tracked live length (tokens) of an admitted sequence, if any.
    pub fn seq_tokens(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|a| a.tokens)
    }

    /// Blocks held by an admitted sequence, if any — the quantity the
    /// preemption policy ranks victims by (evicting the largest holding
    /// frees the most pool).
    pub fn seq_blocks(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|a| a.blocks)
    }

    pub fn allocated_blocks(&self) -> usize {
        self.cfg.total_blocks - self.free_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn peak_blocks(&self) -> usize {
        self.peak_blocks
    }

    pub fn utilization(&self) -> f64 {
        self.allocated_blocks() as f64 / self.cfg.total_blocks as f64
    }

    /// Allocated KV bytes under the configured per-token cost.
    pub fn allocated_bytes(&self) -> usize {
        self.seqs.values().map(|a| a.tokens * self.cfg.bytes_per_token).sum()
    }
}

/// Bytes of KV per token for one chain: `sum_i 2 * layers_i * d_model_i * 4`.
pub fn chain_bytes_per_token(metas: &[crate::runtime::manifest::ModelMeta]) -> usize {
    metas.iter().map(|m| 2 * m.n_layers * m.d_model * 4).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(blocks: usize) -> KvManager {
        KvManager::new(KvConfig { block_size: 4, total_blocks: blocks, bytes_per_token: 8 })
    }

    #[test]
    fn admit_grow_release_cycle() {
        let mut m = mgr(10);
        m.admit(1, 7).unwrap(); // 2 blocks
        assert_eq!(m.allocated_blocks(), 2);
        assert_eq!(m.seq_tokens(1), Some(7));
        assert_eq!(m.seq_tokens(2), None);
        assert_eq!(m.seq_blocks(1), Some(2));
        assert_eq!(m.seq_blocks(2), None);
        assert!(m.fits(40)); // 10 blocks of 4
        assert!(!m.fits(41));
        m.grow(1, 13).unwrap(); // 4 blocks total
        assert_eq!(m.allocated_blocks(), 4);
        assert_eq!(m.seq_tokens(1), Some(13));
        assert_eq!(m.allocated_bytes(), 13 * 8);
        m.release(1).unwrap();
        assert_eq!(m.allocated_blocks(), 0);
        assert_eq!(m.peak_blocks(), 4);
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let mut m = mgr(3);
        m.admit(1, 12).unwrap(); // all 3 blocks
        assert!(!m.can_admit(1));
        assert!(m.admit(2, 1).is_err());
        m.release(1).unwrap();
        assert!(m.can_admit(12));
    }

    #[test]
    fn grow_rejects_beyond_capacity() {
        let mut m = mgr(3);
        m.admit(1, 8).unwrap(); // 2 blocks
        assert!(m.grow(1, 17).is_err()); // needs 5
        // Unchanged after failed grow.
        assert_eq!(m.allocated_blocks(), 2);
        m.grow(1, 12).unwrap();
    }

    #[test]
    fn double_admit_and_unknown_release_fail() {
        let mut m = mgr(4);
        m.admit(1, 4).unwrap();
        assert!(m.admit(1, 4).is_err());
        assert!(m.release(9).is_err());
        assert!(m.grow(9, 4).is_err());
    }

    #[test]
    fn shrinking_grow_fails() {
        let mut m = mgr(4);
        m.admit(1, 8).unwrap();
        assert!(m.grow(1, 4).is_err());
    }

    #[test]
    fn resume_debt_blocks_fresh_admissions_but_not_readmission() {
        let mut m = mgr(10); // 10 blocks of 4 tokens
        m.admit(1, 16).unwrap(); // 4 blocks, 6 free
        m.add_resume_debt(20); // 5 blocks owed to a preempted request
        assert_eq!(m.resume_debt(), 5);
        // Fresh arrivals must leave the owed blocks free: only 1 spare.
        assert!(m.admit_fresh(2, 8).is_err(), "2 blocks would eat the debt");
        m.admit_fresh(3, 4).unwrap(); // 1 block still fits
        // The preempted request itself re-admits through plain admit.
        m.admit(4, 20).unwrap(); // exactly the owed 5 blocks
        m.settle_resume_debt(20);
        assert_eq!(m.resume_debt(), 0);
        // Debt settled: fresh admissions see the whole free pool again.
        m.release(3).unwrap();
        m.admit_fresh(5, 1).unwrap();
        // Over-settling saturates instead of underflowing.
        m.settle_resume_debt(999);
        assert_eq!(m.resume_debt(), 0);
    }

    #[test]
    fn resume_debt_rounds_per_debtor_not_in_aggregate() {
        // Two debtors each owing 6 tokens need 2 blocks apiece; summing
        // tokens first (12 -> 3 blocks) would under-reserve by one block.
        let mut m = mgr(10);
        m.add_resume_debt(6);
        m.add_resume_debt(6);
        assert_eq!(m.resume_debt(), 4, "debt must round per debtor");
        // 10 free - 4 owed: a 7-block fresh admission must be refused.
        assert!(m.admit_fresh(1, 28).is_err());
        m.admit_fresh(2, 24).unwrap(); // 6 blocks fits
        m.settle_resume_debt(6);
        m.settle_resume_debt(6);
        assert_eq!(m.resume_debt(), 0);
    }
}
