//! Paged KV-cache manager: block tables, prefix sharing, suspend-to-swap.
//!
//! The paper (§4.6) identifies multi-model KV footprint as the binding
//! resource of polybasic serving: every chain member keeps its own cache,
//! so capacity scales with the chain. This manager is the admission
//! gatekeeper over a real vLLM-style paged layer
//! ([`coordinator::paged`](super::paged)): a sequence's allocation is a
//! **block table** — an ordered list of refcounted [`BlockId`]s from a
//! free-list [`BlockPool`] — not a counter. Our AOT substrate recomputes
//! attention per forward (DESIGN.md §7), so block *contents* are simulated,
//! but allocation, sharing, eviction and swap capacity are the real
//! mechanics and gate the router exactly as a device-resident block manager
//! would.
//!
//! **Prefix sharing.** Prompt prefixes are cached in a [`RadixCache`]
//! keyed on full-block token chunks. [`admit_fresh_prefixed`]
//! (KvManager::admit_fresh_prefixed) maps a new request's shared prefix
//! onto cached blocks (one incref each) and allocates only the unshared
//! suffix; a prompt that diverges *inside* a cached block — or ends
//! mid-block and later commits past it — triggers a **copy-on-write
//! split** (at admission, or lazily at the first [`grow`](KvManager::grow)
//! past the shared prefix). Finished sequences re-register their full
//! content via [`release_cached`](KvManager::release_cached), so multi-turn
//! conversations find each prior turn's transcript already mapped. Cached
//! blocks nobody maps are reclaimed LRU-subtree-first **on demand**: the
//! cache is free capacity, never admission pressure.
//!
//! **Live-length admission, preemption, swap.** Under continuous batching
//! an allocation tracks its live length: the router admits `prompt +
//! speculative headroom`, the step scheduler [`grow`](KvManager::grow)s it
//! as tokens commit, and admission deliberately overcommits. When a
//! mid-decode grow saturates the pool the scheduler preempts a victim
//! (see `scheduler::select_victim`); [`suspend`](KvManager::suspend)
//! releases the victim's table, earmarks its re-admission footprint as
//! **resume debt** that fresh admissions must leave free, and — new in the
//! paged design — moves the footprint into a bounded [`SwapPool`] when it
//! fits, returning a [`SwapHandle`] carried in the victim's `ResumeState`.
//! [`restore`](KvManager::restore) later redeems the handle for a
//! re-admission with **zero wasted recompute**; a full swap tier falls
//! back to the discard path (resume re-scores its prefix, the PR 5
//! behavior). A grow error still never surfaces to a client unless the
//! pool is smaller than one lone request's footprint
//! ([`fits`](KvManager::fits) is false).

use std::collections::BTreeMap;
use crate::sync::Arc;

use anyhow::{bail, Result};

use crate::spec::task::SwapHandle;
use crate::spec::types::Token;

use super::metrics::Metrics;
use super::paged::{BlockId, BlockPool, RadixCache, SwapPool};

/// Block-granular allocator configuration.
#[derive(Debug, Clone, Copy)]
pub struct KvConfig {
    /// Tokens per block (vLLM-style paging granularity).
    pub block_size: usize,
    /// Total number of blocks in the (simulated) KV pool.
    pub total_blocks: usize,
    /// Bytes of KV per token *per chain member* (2 x layers x d_model x 4,
    /// summed over the chain), used for byte-level reporting.
    pub bytes_per_token: usize,
    /// Blocks in the bounded suspend-to-swap tier (0 disables swap:
    /// preemption falls back to discard-and-re-score).
    pub swap_blocks: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        Self { block_size: 16, total_blocks: 256, bytes_per_token: 0, swap_blocks: 0 }
    }
}

/// One sequence's allocation: its block table plus sharing state.
#[derive(Debug, Clone)]
struct SeqAlloc {
    /// Physical blocks, in token order. `table[j]` backs tokens
    /// `[j*block_size, (j+1)*block_size)`.
    table: Vec<BlockId>,
    /// Reserved capacity in tokens (live length + headroom).
    tokens: usize,
    /// Tokens mapped from the prefix cache at admission.
    shared_prefix: usize,
    /// The tail shared block ends mid-block and has not been split yet:
    /// the first `grow` past the shared prefix performs the CoW split.
    cow_pending: bool,
}

/// Tracks block allocation per active sequence over the paged subsystem.
#[derive(Debug)]
pub struct KvManager {
    cfg: KvConfig,
    pool: BlockPool,
    radix: RadixCache,
    swap: SwapPool,
    seqs: BTreeMap<u64, SeqAlloc>,
    /// High-water mark of allocated blocks (reporting).
    peak_blocks: usize,
    /// Blocks owed to preempted requests awaiting re-admission,
    /// accumulated per debtor (each preemption contributes
    /// `blocks_for(its footprint)`, so rounding never under-reserves).
    /// Fresh admissions ([`admit_fresh`](Self::admit_fresh)) must leave
    /// this many blocks free, so sustained fresh load cannot grab every
    /// freed block ahead of a request the scheduler already suspended —
    /// the resumed lane's queue priority, enforced at the KV altitude
    /// where the contention actually is.
    resume_debt_blocks: usize,
    // Paged-subsystem meters (mirrored into `metrics` when attached).
    prefix_hit_tokens: u64,
    cow_splits: u64,
    swapped_out_blocks: u64,
    restore_tokens_saved: u64,
    metrics: Option<Arc<Metrics>>,
}

impl KvManager {
    pub fn new(cfg: KvConfig) -> Self {
        Self {
            pool: BlockPool::new(cfg.total_blocks),
            radix: RadixCache::new(cfg.block_size),
            swap: SwapPool::new(cfg.swap_blocks),
            cfg,
            seqs: BTreeMap::new(),
            peak_blocks: 0,
            resume_debt_blocks: 0,
            prefix_hit_tokens: 0,
            cow_splits: 0,
            swapped_out_blocks: 0,
            restore_tokens_saved: 0,
            metrics: None,
        }
    }

    /// Mirror the paged-subsystem meters (prefix hits, CoW splits, swap
    /// traffic) into a server-wide [`Metrics`] registry.
    pub fn attach_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    /// Blocks obtainable right now: free-list blocks plus cached blocks no
    /// sequence maps (reclaimed LRU-first on demand). The cache therefore
    /// never costs an admission the uncached allocator would accept.
    fn available(&self) -> usize {
        self.pool.free_len() + self.radix.evictable(&self.pool)
    }

    /// Take `n` physical blocks, evicting unreferenced cache entries as
    /// needed. Callers check [`available`](Self::available) first.
    fn take_blocks(&mut self, n: usize) -> Result<Vec<BlockId>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if self.pool.free_len() == 0 && self.radix.evict_lru(&mut self.pool) == 0 {
                for b in out {
                    self.pool.decref(b);
                }
                bail!("KV pool exhausted mid-allocation (availability changed)");
            }
            // xtask:allow(panic): the branch above freed or found a block.
            out.push(self.pool.alloc().expect("block free after eviction"));
        }
        Ok(out)
    }

    fn bump_peak(&mut self) {
        self.peak_blocks = self.peak_blocks.max(self.allocated_blocks());
    }

    fn note_prefix_hit(&mut self, tokens: usize) {
        self.prefix_hit_tokens += tokens as u64;
        if let Some(m) = &self.metrics {
            m.record_prefix_hit(tokens);
        }
    }

    fn note_cow_split(&mut self) {
        self.cow_splits += 1;
        if let Some(m) = &self.metrics {
            m.record_cow_split();
        }
    }

    /// Can a sequence of `tokens` total length be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.available()
    }

    /// Could a sequence of `tokens` total length *ever* fit, i.e. with the
    /// whole pool free? False means no amount of preemption helps.
    pub fn fits(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.cfg.total_blocks
    }

    /// Reserve blocks for a new sequence (prompt + planned generation).
    /// Count-based (no prefix sharing): the re-admission path for resumed
    /// and swap-restored sequences, and the pre-paged API surface.
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        let need = self.blocks_for(tokens);
        if need > self.available() {
            bail!(
                "KV pool exhausted: need {need} blocks, {} free of {}",
                self.available(),
                self.cfg.total_blocks
            );
        }
        let table = self.take_blocks(need)?;
        self.seqs.insert(seq, SeqAlloc { table, tokens, shared_prefix: 0, cow_pending: false });
        self.bump_peak();
        Ok(())
    }

    /// Admission for **fresh** arrivals (the router's path): like
    /// [`admit`](Self::admit), but refuses to eat into the blocks owed to
    /// preempted requests awaiting re-admission. Preempted requests
    /// re-admit through plain `admit`, which ignores the debt they are
    /// owed.
    pub fn admit_fresh(&mut self, seq: u64, tokens: usize) -> Result<()> {
        let owed = self.resume_debt_blocks;
        let need = self.blocks_for(tokens);
        if need + owed > self.available() {
            bail!(
                "KV pool exhausted: need {need} blocks, {} free of {} \
                 ({owed} blocks owed to preempted requests)",
                self.available(),
                self.cfg.total_blocks
            );
        }
        self.admit(seq, tokens)
    }

    /// Prefix-aware fresh admission (the router's paged path): reserve
    /// `tokens` of capacity for `seq`, mapping the longest cached prefix of
    /// `prompt` onto shared blocks and allocating only the unshared
    /// remainder. Registers the prompt's full blocks for future sharing.
    /// Honors resume debt like [`admit_fresh`](Self::admit_fresh). Returns
    /// the shared token count.
    pub fn admit_fresh_prefixed(
        &mut self,
        seq: u64,
        prompt: &[Token],
        tokens: usize,
    ) -> Result<usize> {
        self.admit_prefixed_inner(seq, prompt, tokens, true)
    }

    /// Prefix-aware re-admission for a resumed (not swap-restored)
    /// sequence: `content` is `prompt + committed`. Ignores resume debt —
    /// the caller IS the debt. Returns the shared token count.
    pub fn admit_resumed_prefixed(
        &mut self,
        seq: u64,
        content: &[Token],
        tokens: usize,
    ) -> Result<usize> {
        self.admit_prefixed_inner(seq, content, tokens, false)
    }

    fn admit_prefixed_inner(
        &mut self,
        seq: u64,
        content: &[Token],
        tokens: usize,
        honor_debt: bool,
    ) -> Result<usize> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        let b = self.cfg.block_size;
        let tokens = tokens.max(content.len());
        let pm = self.radix.lookup(content);
        let m = pm.tokens;
        let mut shared = pm.blocks;
        let mut cow_pending = false;
        let mut split_now = false;
        if m % b != 0 {
            if m < content.len() {
                // The prompt diverges *inside* the matched tail block:
                // writing the divergent rows needs a private copy now.
                shared.pop();
                split_now = true;
            } else {
                // The whole content matched but ends mid-block: share the
                // tail copy-on-write; the first grow past it splits.
                cow_pending = true;
            }
        }
        // Pin the shared blocks before sizing the remainder, so on-demand
        // eviction (and the availability math) can no longer reclaim them.
        for &blk in &shared {
            self.pool.incref(blk);
        }
        let need_new = self.blocks_for(tokens) - shared.len();
        let owed = if honor_debt { self.resume_debt_blocks } else { 0 };
        if need_new + owed > self.available() {
            for &blk in &shared {
                self.pool.decref(blk);
            }
            bail!(
                "KV pool exhausted: need {need_new} blocks, {} free of {} \
                 ({owed} blocks owed to preempted requests)",
                self.available(),
                self.cfg.total_blocks
            );
        }
        let fresh = match self.take_blocks(need_new) {
            Ok(f) => f,
            Err(e) => {
                for &blk in &shared {
                    self.pool.decref(blk);
                }
                return Err(e);
            }
        };
        let mut table = shared;
        table.extend(fresh);
        if split_now {
            self.note_cow_split();
        }
        if m > 0 {
            self.note_prefix_hit(m);
        }
        self.seqs
            .insert(seq, SeqAlloc { table, tokens, shared_prefix: m, cow_pending });
        // Register the content's full blocks so later requests share them.
        // (Cloning the small table sidesteps a seqs/pool split borrow.)
        let snapshot = self.seqs[&seq].table.clone();
        self.radix.register(content, &snapshot, &mut self.pool);
        self.bump_peak();
        Ok(m)
    }

    /// Record that a preempted request will need `tokens` of pool to
    /// resume; fresh admissions keep `blocks_for(tokens)` blocks free
    /// until [`settle_resume_debt`](Self::settle_resume_debt). Converted
    /// to blocks per call, so several concurrent debtors' rounding never
    /// under-reserves.
    pub fn add_resume_debt(&mut self, tokens: usize) {
        self.resume_debt_blocks += self.blocks_for(tokens);
    }

    /// The preempted request re-admitted (or permanently failed): stop
    /// holding pool back on its behalf. Pass the same token count given
    /// to [`add_resume_debt`](Self::add_resume_debt).
    pub fn settle_resume_debt(&mut self, tokens: usize) {
        self.resume_debt_blocks = self.resume_debt_blocks.saturating_sub(self.blocks_for(tokens));
    }

    /// Blocks currently owed to preempted requests.
    pub fn resume_debt(&self) -> usize {
        self.resume_debt_blocks
    }

    /// Grow an existing sequence to `tokens` total length. Performs the
    /// pending copy-on-write split on the first grow past a mid-block
    /// shared prefix (growth implies commits beyond the prompt). On
    /// failure the allocation is unchanged.
    pub fn grow(&mut self, seq: u64, tokens: usize) -> Result<()> {
        let (cur_blocks, split) = {
            let alloc = match self.seqs.get(&seq) {
                Some(a) => a,
                None => bail!("sequence {seq} not admitted"),
            };
            if tokens < alloc.tokens {
                bail!("sequence {seq} cannot shrink via grow()");
            }
            // A pending CoW tail whose cache entry was meanwhile evicted
            // (we are the only mapper) can be written in place: no split.
            let split = alloc.cow_pending
                && self.pool.refcount(alloc.table[alloc.shared_prefix / self.cfg.block_size]) > 1;
            (alloc.table.len(), split)
        };
        let extra = self.blocks_for(tokens).saturating_sub(cur_blocks);
        if extra + usize::from(split) > self.available() {
            bail!("KV pool exhausted growing seq {seq}");
        }
        let mut fresh = self.take_blocks(extra + usize::from(split))?;
        if split {
            // xtask:allow(panic): take_blocks returned extra + 1 blocks.
            let copy = fresh.pop().expect("reserved the split block");
            let old = {
                // xtask:allow(panic): presence checked at the top of grow.
                let alloc = self.seqs.get_mut(&seq).expect("checked above");
                let idx = alloc.shared_prefix / self.cfg.block_size;
                let old = std::mem::replace(&mut alloc.table[idx], copy);
                alloc.cow_pending = false;
                old
            };
            self.pool.decref(old);
            self.note_cow_split();
        }
        // xtask:allow(panic): presence checked at the top of grow.
        let alloc = self.seqs.get_mut(&seq).expect("checked above");
        alloc.table.append(&mut fresh);
        alloc.tokens = tokens;
        alloc.cow_pending = false;
        self.bump_peak();
        Ok(())
    }

    /// Release a finished (or failed) sequence without caching its blocks.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        match self.seqs.remove(&seq) {
            Some(a) => {
                for b in a.table {
                    self.pool.decref(b);
                }
                Ok(())
            }
            None => bail!("sequence {seq} not admitted"),
        }
    }

    /// Release a **successfully finished** sequence, first registering its
    /// content (`prompt + committed`) in the prefix cache so later
    /// requests — multi-turn follow-ups above all — map the transcript's
    /// blocks instead of re-allocating them. Cached blocks remain
    /// allocated but are reclaimed on demand; they never block admission.
    pub fn release_cached(&mut self, seq: u64, content: &[Token]) -> Result<()> {
        let snapshot = match self.seqs.get(&seq) {
            Some(a) => a.table.clone(),
            None => bail!("sequence {seq} not admitted"),
        };
        self.radix.register(content, &snapshot, &mut self.pool);
        self.release(seq)
    }

    /// Preempt `seq` in one atomic operation: release its table, earmark
    /// `resume_need` tokens of resume debt, and — when the bounded swap
    /// tier can hold the whole footprint — reserve swap space for
    /// `content_tokens` tokens, returning the handle the resume path
    /// redeems via [`restore`](Self::restore). `None` means the discard
    /// path: the resume will re-score its prefix.
    pub fn suspend(
        &mut self,
        seq: u64,
        content_tokens: usize,
        resume_need: usize,
    ) -> Result<Option<SwapHandle>> {
        self.release(seq)?;
        self.resume_debt_blocks += self.blocks_for(resume_need);
        let blocks = self.blocks_for(content_tokens);
        let handle = self.swap.reserve(blocks, content_tokens);
        if let Some(h) = &handle {
            self.swapped_out_blocks += h.blocks as u64;
            if let Some(m) = &self.metrics {
                m.record_swap_out(h.blocks);
            }
        }
        Ok(handle)
    }

    /// Re-admit a swapped-out sequence at `tokens` total capacity, freeing
    /// its swap reservation and crediting the recompute the swap saved.
    /// On failure (pool momentarily busy) the reservation is untouched —
    /// the caller defers and retries. The caller settles the resume debt
    /// exactly as on the discard path.
    pub fn restore(&mut self, seq: u64, handle: &SwapHandle, tokens: usize) -> Result<()> {
        self.admit(seq, tokens)?;
        self.swap.free(handle);
        self.restore_tokens_saved += handle.tokens as u64;
        if let Some(m) = &self.metrics {
            m.record_restore_saved(handle.tokens);
        }
        Ok(())
    }

    /// Drop a swap reservation without restoring (the request died:
    /// deadline, capacity overflow, failed re-open).
    pub fn discard_swap(&mut self, handle: &SwapHandle) {
        self.swap.free(handle);
    }

    /// Tracked live length (tokens) of an admitted sequence, if any.
    pub fn seq_tokens(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|a| a.tokens)
    }

    /// Blocks held by an admitted sequence, if any — the quantity the
    /// preemption policy ranks victims by (evicting the largest holding
    /// frees the most pool).
    pub fn seq_blocks(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|a| a.table.len())
    }

    /// The sequence's physical block table (sharing-visible: two sequences
    /// mapping the same prefix report the same leading [`BlockId`]s).
    pub fn seq_block_ids(&self, seq: u64) -> Option<Vec<BlockId>> {
        self.seqs.get(&seq).map(|a| a.table.clone())
    }

    /// Pool refcount of a block (sequence mappings + one per cache entry).
    pub fn block_refcount(&self, block: BlockId) -> u32 {
        self.pool.refcount(block)
    }

    /// Blocks held by the prefix cache (allocated but reclaimable unless
    /// also mapped by a live sequence).
    pub fn cached_blocks(&self) -> usize {
        self.radix.len()
    }

    /// Swap-tier blocks currently holding suspended sequences.
    pub fn swapped_blocks(&self) -> usize {
        self.swap.used_blocks()
    }

    /// Cumulative prompt/content tokens served from the prefix cache.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// Cumulative copy-on-write block splits.
    pub fn cow_splits(&self) -> u64 {
        self.cow_splits
    }

    /// Cumulative blocks moved to the swap tier at preemption.
    pub fn swapped_out_blocks(&self) -> u64 {
        self.swapped_out_blocks
    }

    /// Cumulative recompute tokens saved by swap restores.
    pub fn restore_tokens_saved(&self) -> u64 {
        self.restore_tokens_saved
    }

    pub fn allocated_blocks(&self) -> usize {
        self.cfg.total_blocks - self.pool.free_len()
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_len()
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn peak_blocks(&self) -> usize {
        self.peak_blocks
    }

    /// Fraction of the pool pinned by live sequences. Cached-but-unmapped
    /// blocks are reclaimable on demand and count as free — matching the
    /// admission math, so a drained server reads 0% even with a warm
    /// prefix cache. A zero-block pool is 0% utilized, not NaN.
    pub fn utilization(&self) -> f64 {
        if self.cfg.total_blocks == 0 {
            return 0.0;
        }
        let pinned =
            self.cfg.total_blocks - self.pool.free_len() - self.radix.evictable(&self.pool);
        pinned as f64 / self.cfg.total_blocks as f64
    }

    /// Allocated KV bytes under the configured per-token cost.
    pub fn allocated_bytes(&self) -> usize {
        self.seqs.values().map(|a| a.tokens * self.cfg.bytes_per_token).sum()
    }

    /// Tokens of KV currently resident for live sequences — what the
    /// scheduler publishes as the `cache_resident_tokens` gauge each sweep.
    /// Counts mapped sequence tokens only (cached-but-unmapped radix blocks
    /// and swapped-out sequences are excluded: nothing live attends them).
    pub fn resident_tokens(&self) -> usize {
        self.seqs.values().map(|a| a.tokens).sum()
    }
}

/// Bytes of KV per token for one chain: `sum_i 2 * layers_i * d_model_i * 4`.
pub fn chain_bytes_per_token(metas: &[crate::runtime::manifest::ModelMeta]) -> usize {
    metas.iter().map(|m| 2 * m.n_layers * m.d_model * 4).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(blocks: usize) -> KvManager {
        KvManager::new(KvConfig {
            block_size: 4,
            total_blocks: blocks,
            bytes_per_token: 8,
            swap_blocks: 0,
        })
    }

    #[test]
    fn admit_grow_release_cycle() {
        let mut m = mgr(10);
        m.admit(1, 7).unwrap(); // 2 blocks
        assert_eq!(m.allocated_blocks(), 2);
        assert_eq!(m.seq_tokens(1), Some(7));
        assert_eq!(m.seq_tokens(2), None);
        assert_eq!(m.seq_blocks(1), Some(2));
        assert_eq!(m.seq_blocks(2), None);
        assert!(m.fits(40)); // 10 blocks of 4
        assert!(!m.fits(41));
        m.grow(1, 13).unwrap(); // 4 blocks total
        assert_eq!(m.allocated_blocks(), 4);
        assert_eq!(m.seq_tokens(1), Some(13));
        assert_eq!(m.allocated_bytes(), 13 * 8);
        m.release(1).unwrap();
        assert_eq!(m.allocated_blocks(), 0);
        assert_eq!(m.peak_blocks(), 4);
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let mut m = mgr(3);
        m.admit(1, 12).unwrap(); // all 3 blocks
        assert!(!m.can_admit(1));
        assert!(m.admit(2, 1).is_err());
        m.release(1).unwrap();
        assert!(m.can_admit(12));
    }

    #[test]
    fn grow_rejects_beyond_capacity() {
        let mut m = mgr(3);
        m.admit(1, 8).unwrap(); // 2 blocks
        assert!(m.grow(1, 17).is_err()); // needs 5
        // Unchanged after failed grow.
        assert_eq!(m.allocated_blocks(), 2);
        m.grow(1, 12).unwrap();
    }

    #[test]
    fn double_admit_and_unknown_release_fail() {
        let mut m = mgr(4);
        m.admit(1, 4).unwrap();
        assert!(m.admit(1, 4).is_err());
        assert!(m.release(9).is_err());
        assert!(m.grow(9, 4).is_err());
    }

    #[test]
    fn shrinking_grow_fails() {
        let mut m = mgr(4);
        m.admit(1, 8).unwrap();
        assert!(m.grow(1, 4).is_err());
    }

    #[test]
    fn resume_debt_blocks_fresh_admissions_but_not_readmission() {
        let mut m = mgr(10); // 10 blocks of 4 tokens
        m.admit(1, 16).unwrap(); // 4 blocks, 6 free
        m.add_resume_debt(20); // 5 blocks owed to a preempted request
        assert_eq!(m.resume_debt(), 5);
        // Fresh arrivals must leave the owed blocks free: only 1 spare.
        assert!(m.admit_fresh(2, 8).is_err(), "2 blocks would eat the debt");
        m.admit_fresh(3, 4).unwrap(); // 1 block still fits
        // The preempted request itself re-admits through plain admit.
        m.admit(4, 20).unwrap(); // exactly the owed 5 blocks
        m.settle_resume_debt(20);
        assert_eq!(m.resume_debt(), 0);
        // Debt settled: fresh admissions see the whole free pool again.
        m.release(3).unwrap();
        m.admit_fresh(5, 1).unwrap();
        // Over-settling saturates instead of underflowing.
        m.settle_resume_debt(999);
        assert_eq!(m.resume_debt(), 0);
    }

    #[test]
    fn resume_debt_rounds_per_debtor_not_in_aggregate() {
        // Two debtors each owing 6 tokens need 2 blocks apiece; summing
        // tokens first (12 -> 3 blocks) would under-reserve by one block.
        let mut m = mgr(10);
        m.add_resume_debt(6);
        m.add_resume_debt(6);
        assert_eq!(m.resume_debt(), 4, "debt must round per debtor");
        // 10 free - 4 owed: a 7-block fresh admission must be refused.
        assert!(m.admit_fresh(1, 28).is_err());
        m.admit_fresh(2, 24).unwrap(); // 6 blocks fits
        m.settle_resume_debt(6);
        m.settle_resume_debt(6);
        assert_eq!(m.resume_debt(), 0);
    }

    #[test]
    fn utilization_is_zero_not_nan_on_empty_pool() {
        let m = KvManager::new(KvConfig {
            block_size: 4,
            total_blocks: 0,
            bytes_per_token: 8,
            swap_blocks: 0,
        });
        assert_eq!(m.utilization(), 0.0, "zero-block pool must report 0.0, not NaN");
        assert!(!m.can_admit(1));
        let mut m = mgr(4);
        m.admit(1, 8).unwrap();
        assert!((m.utilization() - 0.5).abs() < 1e-12);
        // Cached-but-unmapped blocks are reclaimable: a drained pool with a
        // warm cache reads 0% utilized, matching what admission sees.
        let p: Vec<Token> = (0..8).collect();
        m.release(1).unwrap();
        m.admit_fresh_prefixed(2, &p, 8).unwrap();
        m.release_cached(2, &p).unwrap();
        assert_eq!(m.active_seqs(), 0);
        assert!(m.cached_blocks() > 0);
        assert_eq!(m.utilization(), 0.0, "warm cache must not read as utilization");
    }

    /// THE refcount acceptance criterion: two requests sharing a K-token
    /// prefix consume strictly fewer than 2x the blocks of one.
    #[test]
    fn prefix_sharing_consumes_less_than_twice_the_blocks() {
        let mut m = mgr(32);
        let prompt: Vec<Token> = (0..16).collect(); // 4 full blocks
        let one = m.admit_fresh_prefixed(1, &prompt, 24).unwrap(); // 6 blocks
        assert_eq!(one, 0, "cold cache: nothing shared yet");
        let solo_blocks = m.seq_blocks(1).unwrap();
        assert_eq!(solo_blocks, 6);
        assert_eq!(m.cached_blocks(), 4, "prompt's full blocks registered");

        let shared = m.admit_fresh_prefixed(2, &prompt, 24).unwrap();
        assert_eq!(shared, 16, "whole prompt served from cache");
        assert_eq!(m.prefix_hit_tokens(), 16);
        assert!(
            m.allocated_blocks() < 2 * solo_blocks,
            "sharing must beat 2x: {} vs {}",
            m.allocated_blocks(),
            2 * solo_blocks
        );
        // The physical tables overlap on the prompt blocks...
        let t1 = m.seq_block_ids(1).unwrap();
        let t2 = m.seq_block_ids(2).unwrap();
        assert_eq!(t1[..4], t2[..4], "prompt blocks must be the same physical blocks");
        assert_ne!(t1[4..], t2[4..], "headroom blocks are private");
        // ...with refcounts seq1 + seq2 + cache.
        for &b in &t1[..4] {
            assert_eq!(m.block_refcount(b), 3);
        }
        // Releasing both leaves only the cache's references.
        m.release(2).unwrap();
        m.release(1).unwrap();
        assert_eq!(m.active_seqs(), 0);
        assert_eq!(m.allocated_blocks(), m.cached_blocks());
        for &b in &t1[..4] {
            assert_eq!(m.block_refcount(b), 1, "cache ref survives the sequences");
        }
    }

    #[test]
    fn divergence_inside_a_block_splits_copy_on_write_at_admission() {
        let mut m = mgr(32);
        let p1: Vec<Token> = (0..12).collect(); // 3 full blocks
        m.admit_fresh_prefixed(1, &p1, 12).unwrap();
        // Diverges at token 10, inside the third block.
        let mut p2 = p1.clone();
        p2[10] = 99;
        p2[11] = 98;
        let shared = m.admit_fresh_prefixed(2, &p2, 12).unwrap();
        assert_eq!(shared, 10, "2 full blocks + 2 tokens into the third");
        assert_eq!(m.cow_splits(), 1, "mid-block divergence forces a private copy");
        let t1 = m.seq_block_ids(1).unwrap();
        let t2 = m.seq_block_ids(2).unwrap();
        assert_eq!(t1[..2], t2[..2]);
        assert_ne!(t1[2], t2[2], "the divergent block must be private");
    }

    #[test]
    fn mid_block_prefix_splits_lazily_on_first_grow() {
        let mut m = mgr(32);
        let p1: Vec<Token> = (0..12).collect();
        m.admit_fresh_prefixed(1, &p1, 12).unwrap();
        let t1 = m.seq_block_ids(1).unwrap();
        // A shorter prompt that IS a prefix, ending mid-block: the tail
        // block is shared copy-on-write, no split yet.
        let p2 = p1[..10].to_vec();
        let shared = m.admit_fresh_prefixed(2, &p2, 10).unwrap();
        assert_eq!(shared, 10);
        assert_eq!(m.cow_splits(), 0, "pure prefix: nothing to split at admission");
        let t2 = m.seq_block_ids(2).unwrap();
        assert_eq!(t1[..3], t2[..3], "tail block shared CoW");
        assert_eq!(m.block_refcount(t1[2]), 3); // seq1 + seq2 + cache
        // First grow past the shared prefix = first divergent write: split.
        m.grow(2, 14).unwrap();
        assert_eq!(m.cow_splits(), 1);
        let t2 = m.seq_block_ids(2).unwrap();
        assert_ne!(t1[2], t2[2], "written tail must now be private");
        assert_eq!(m.block_refcount(t1[2]), 2, "seq2's mapping moved off");
        assert_eq!(m.seq_tokens(2), Some(14));
    }

    #[test]
    fn cache_is_reclaimed_on_demand_never_admission_pressure() {
        let mut m = mgr(4);
        let p: Vec<Token> = (0..8).collect();
        m.admit_fresh_prefixed(1, &p, 8).unwrap(); // 2 blocks, both cached
        m.release_cached(1, &p).unwrap();
        assert_eq!(m.active_seqs(), 0);
        assert_eq!(m.allocated_blocks(), 2, "cache retains the blocks");
        assert_eq!(m.cached_blocks(), 2);
        // A full-pool admission evicts the cache rather than failing.
        assert!(m.can_admit(16));
        m.admit(2, 16).unwrap();
        assert_eq!(m.cached_blocks(), 0, "cache evicted to make room");
        assert_eq!(m.free_blocks(), 0);
    }

    #[test]
    fn release_cached_enables_transcript_reuse() {
        let mut m = mgr(32);
        let prompt: Vec<Token> = (0..8).collect();
        m.admit(1, 12).unwrap(); // plain admission: nothing cached yet
        m.grow(1, 16).unwrap();
        // Finished with 8 committed tokens: register the full transcript.
        let content: Vec<Token> = (0..16).collect();
        m.release_cached(1, &content).unwrap();
        assert_eq!(m.cached_blocks(), 4);
        // A follow-up turn re-submits the transcript as its prompt prefix.
        let mut next = content.clone();
        next.extend([100, 101, 102, 103]);
        let shared = m.admit_fresh_prefixed(2, &next, 24).unwrap();
        assert_eq!(shared, 16, "the whole prior transcript is served from cache");
        assert!(prompt.len() < shared);
    }

    #[test]
    fn suspend_to_swap_restores_without_recompute() {
        let mut m = KvManager::new(KvConfig {
            block_size: 4,
            total_blocks: 10,
            bytes_per_token: 8,
            swap_blocks: 6,
        });
        m.admit(1, 20).unwrap(); // 5 blocks
        let h = m.suspend(1, 20, 20).unwrap().expect("swap tier has room");
        assert_eq!(h.blocks, 5);
        assert_eq!(h.tokens, 20);
        assert_eq!(m.active_seqs(), 0, "pool blocks freed immediately");
        assert_eq!(m.allocated_blocks(), 0);
        assert_eq!(m.resume_debt(), 5, "suspend earmarks the re-admission");
        assert_eq!(m.swapped_blocks(), 5);
        assert_eq!(m.swapped_out_blocks(), 5);
        // A second victim does not fit the 6-block tier: discard path.
        m.admit(2, 20).unwrap();
        let none = m.suspend(2, 20, 20).unwrap();
        assert!(none.is_none(), "full swap tier falls back to discard");
        m.settle_resume_debt(20);
        // Restore redeems the handle: re-admitted, swap freed, recompute
        // credited.
        m.restore(1, &h, 20).unwrap();
        m.settle_resume_debt(20);
        assert_eq!(m.seq_tokens(1), Some(20));
        assert_eq!(m.swapped_blocks(), 0);
        assert_eq!(m.restore_tokens_saved(), 20);
        assert_eq!(m.resume_debt(), 0);
        m.release(1).unwrap();
        // Discarding a dead request's handle frees the tier too.
        m.admit(3, 8).unwrap();
        let h3 = m.suspend(3, 8, 8).unwrap().unwrap();
        m.settle_resume_debt(8);
        m.discard_swap(&h3);
        assert_eq!(m.swapped_blocks(), 0);
    }

    #[test]
    fn failed_restore_keeps_the_swap_reservation() {
        let mut m = KvManager::new(KvConfig {
            block_size: 4,
            total_blocks: 4,
            bytes_per_token: 8,
            swap_blocks: 8,
        });
        m.admit(1, 16).unwrap();
        let h = m.suspend(1, 16, 16).unwrap().unwrap();
        m.admit(2, 16).unwrap(); // someone else takes the whole pool
        assert!(m.restore(1, &h, 16).is_err(), "pool busy");
        assert_eq!(m.swapped_blocks(), 4, "reservation must survive a failed restore");
        m.release(2).unwrap();
        m.restore(1, &h, 16).unwrap();
        assert_eq!(m.swapped_blocks(), 0);
    }
}
