//! Request router: validation + admission control in front of the queue.
//!
//! Checks that a request fits the chain's context budget (prompt + output +
//! speculative pipeline headroom) and that the KV pool can host it, then
//! routes it to the family's queue. Multi-family deployments route by the
//! request's family tag.
//!
//! KV admission is **live-length** based: the router reserves only what the
//! request holds on arrival (prompt + the speculative pipeline window); the
//! step scheduler grows the allocation as tokens commit, preempting a
//! victim when the overcommitted pool saturates. A preempted request
//! re-enters through this same reservation shape — the scheduler re-admits
//! `prompt + committed + headroom` before resuming it. See
//! `coordinator::kv` and `coordinator::scheduler`.

use std::collections::BTreeMap;
use crate::sync::{Arc, Mutex};

use super::api::{Method, Request};
use super::batcher::DynamicBatcher;
use super::kv::KvManager;
use crate::spec::polybasic::PolyConfig;

/// Why a request was refused at the door.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    ContextOverflow { need: usize, cap: usize },
    KvExhausted,
    UnknownFamily(String),
    EmptyPrompt,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::ContextOverflow { need, cap } => {
                write!(f, "context overflow: need {need} tokens, window {cap}")
            }
            RejectReason::KvExhausted => write!(f, "KV pool exhausted"),
            RejectReason::UnknownFamily(s) => write!(f, "unknown family {s:?}"),
            RejectReason::EmptyPrompt => write!(f, "empty prompt"),
        }
    }
}

/// Pipeline headroom a request needs beyond prompt + output.
pub fn pipeline_headroom(method: &Method, n_models: usize) -> usize {
    match method {
        Method::Autoregressive => 0,
        Method::Dualistic { draft_k } => draft_k + 1,
        Method::Polybasic { draft_k, mu } => {
            let mut cfg = PolyConfig::for_chain(n_models.max(2), *draft_k, *mu, 1);
            cfg.draft_k = *draft_k;
            cfg.headroom()
        }
    }
}

/// One routed destination: a family's queue + its capacity limits.
pub struct FamilyLane {
    pub batcher: Arc<DynamicBatcher>,
    pub kv: Arc<Mutex<KvManager>>,
    pub seq_len: usize,
    pub n_models: usize,
}

/// Routes requests to family lanes with validation + admission.
pub struct Router {
    lanes: BTreeMap<String, FamilyLane>,
    default_family: String,
}

impl Router {
    pub fn new(default_family: impl Into<String>) -> Self {
        Self { lanes: BTreeMap::new(), default_family: default_family.into() }
    }

    pub fn add_lane(&mut self, family: impl Into<String>, lane: FamilyLane) {
        self.lanes.insert(family.into(), lane);
    }

    pub fn lane(&self, family: &str) -> Option<&FamilyLane> {
        self.lanes.get(family)
    }

    /// Validate + admit + enqueue. On success the sequence is registered
    /// with the lane's KV manager under `req.id`.
    pub fn route(&self, family: Option<&str>, req: Request) -> Result<(), RejectReason> {
        let fam = family.unwrap_or(&self.default_family);
        let lane = self
            .lanes
            .get(fam)
            .ok_or_else(|| RejectReason::UnknownFamily(fam.to_string()))?;
        if req.prompt.is_empty() {
            return Err(RejectReason::EmptyPrompt);
        }
        let headroom = pipeline_headroom(&req.method, lane.n_models);
        let need = req.prompt.len() + req.max_new + headroom;
        if need > lane.seq_len {
            return Err(RejectReason::ContextOverflow { need, cap: lane.seq_len });
        }
        {
            // Reserve the live footprint only (prompt + speculative
            // window); the scheduler grows it as tokens commit. Fresh
            // admission leaves room owed to preempted requests awaiting
            // re-admission (see KvManager::admit_fresh), so new arrivals
            // cannot starve a decode the scheduler already suspended.
            // Prefix-aware: the longest cached prefix of the prompt maps
            // onto shared blocks instead of fresh ones, so concurrent
            // requests over a common prompt (or a conversation follow-up
            // over its own transcript) cost only their unshared suffix.
            let mut kv = lane.kv.lock();
            // xtask:allow(kv-pairing): admission transfers ownership of
            // the allocation to the scheduler, which releases/suspends it
            // on every exit path of run_batch_opts.
            kv.admit_fresh_prefixed(req.id, &req.prompt, req.prompt.len() + headroom)
                .map_err(|_| RejectReason::KvExhausted)?;
        }
        lane.batcher.push(req);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::kv::KvConfig;

    fn lane(seq_len: usize, blocks: usize) -> FamilyLane {
        FamilyLane {
            batcher: Arc::new(DynamicBatcher::new(BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::ZERO,
                ..Default::default()
            })),
            kv: Arc::new(Mutex::new(KvManager::new(KvConfig {
                block_size: 16,
                total_blocks: blocks,
                bytes_per_token: 4,
                swap_blocks: 0,
            }))),
            seq_len,
            n_models: 3,
        }
    }

    fn router(seq_len: usize, blocks: usize) -> Router {
        let mut r = Router::new("fam");
        r.add_lane("fam", lane(seq_len, blocks));
        r
    }

    #[test]
    fn routes_valid_request() {
        let r = router(144, 64);
        let req = Request::new(1, vec![1; 30], 40);
        r.route(None, req).unwrap();
        assert_eq!(r.lane("fam").unwrap().batcher.len(), 1);
        assert_eq!(r.lane("fam").unwrap().kv.lock().active_seqs(), 1);
    }

    #[test]
    fn rejects_context_overflow() {
        let r = router(64, 64);
        let req = Request::new(1, vec![1; 40], 40);
        match r.route(None, req) {
            Err(RejectReason::ContextOverflow { need, cap }) => {
                assert!(need > cap);
            }
            other => panic!("{other:?}"),
        }
        // Nothing admitted on rejection.
        assert_eq!(r.lane("fam").unwrap().kv.lock().active_seqs(), 0);
    }

    #[test]
    fn rejects_when_kv_full() {
        let r = router(144, 4); // 4 blocks x 16 = 64 tokens of KV
        r.route(None, Request::new(1, vec![1; 20], 10)).unwrap();
        let res = r.route(None, Request::new(2, vec![1; 20], 10));
        assert_eq!(res, Err(RejectReason::KvExhausted));
    }

    #[test]
    fn rejects_unknown_family_and_empty_prompt() {
        let r = router(144, 64);
        assert!(matches!(
            r.route(Some("nope"), Request::new(1, vec![1], 4)),
            Err(RejectReason::UnknownFamily(_))
        ));
        assert_eq!(r.route(None, Request::new(2, vec![], 4)), Err(RejectReason::EmptyPrompt));
    }

    #[test]
    fn headroom_scales_with_method() {
        let ar = pipeline_headroom(&Method::Autoregressive, 3);
        let dual = pipeline_headroom(&Method::Dualistic { draft_k: 4 }, 3);
        let poly = pipeline_headroom(&Method::Polybasic { draft_k: 6, mu: 8 }, 3);
        assert_eq!(ar, 0);
        assert!(dual > 0);
        assert!(poly > dual);
    }
}
