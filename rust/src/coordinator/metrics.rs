//! Serving metrics: log-bucketed latency histograms, throughput counters,
//! JSON snapshots (via the in-tree JSON writer).

use std::collections::BTreeMap;
// xtask:allow(facade): metrics are monitoring-only and never part of a
// modeled protocol; the histograms rely on `fetch_max`, which the loom
// atomics do not guarantee, so the counters stay on std atomics.
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::sync::{Arc, Mutex};

use crate::runtime::json::Json;
use crate::spec::types::HealthTracker;

/// Log2-bucketed duration histogram from 1us to ~1hour.
#[derive(Debug)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^{i+1}) microseconds.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const NBUCKETS: usize = 32;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record(&self, d: Duration) {
        let us = (d.as_micros() as u64).max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Upper bound of the bucket containing quantile `q` (0..1).
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let rank = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Log2-bucketed histogram over small counts (batch occupancy: bucket i
/// counts samples in `[2^i, 2^{i+1})` sessions — 1, 2–3, 4–7, …).
#[derive(Debug)]
pub struct CountHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

const COUNT_NBUCKETS: usize = 16;

impl Default for CountHistogram {
    fn default() -> Self {
        Self {
            buckets: (0..COUNT_NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl CountHistogram {
    pub fn record(&self, n: u64) {
        let n = n.max(1);
        let bucket = (63 - n.leading_zeros() as usize).min(COUNT_NBUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(n, Ordering::Relaxed);
        self.max.fetch_max(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }
}

/// Whole-server metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queue_latency: LatencyHistogram,
    pub service_latency: LatencyHistogram,
    pub e2e_latency: LatencyHistogram,
    /// Time-to-first-token: enqueue -> first committed token of a request
    /// (the latency continuous batching exists to protect).
    pub ttft_latency: LatencyHistogram,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Requests that entered a worker and ended in an error (task open or
    /// decode failure). KV-pressure preemption does NOT count here — a
    /// preempted request resumes and completes.
    pub requests_failed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub target_forwards: AtomicU64,
    /// Decode tasks suspended mid-flight because a KV `grow` found the
    /// pool saturated (each increments once per eviction).
    pub preemptions: AtomicU64,
    /// Preempted tasks re-admitted and resumed.
    pub resumes: AtomicU64,
    /// Prefix tokens re-scored because a resumed task's dropped sessions
    /// had to be rebuilt — the recompute cost preemption trades for not
    /// failing requests.
    pub wasted_recompute_tokens: AtomicU64,
    /// Chain members dropped mid-decode by graceful degradation (each
    /// drafter drop counts once; the request itself still completes).
    pub chains_degraded: AtomicU64,
    /// Requests cancelled because they ran past their deadline.
    pub deadline_cancellations: AtomicU64,
    /// Prompt/content tokens served from the radix prefix cache instead of
    /// freshly allocated (admission-time sharing, paged KV).
    pub prefix_hit_tokens: AtomicU64,
    /// Copy-on-write block splits: a sequence's first divergent write into
    /// a block it shared with the prefix cache or another sequence.
    pub cow_splits: AtomicU64,
    /// Blocks moved to the bounded swap tier at preemption (cumulative).
    pub swapped_blocks: AtomicU64,
    /// Recompute tokens avoided because a preempted request restored its
    /// KV from swap instead of re-scoring its prefix.
    pub restore_tokens_saved: AtomicU64,
    /// Engine calls issued by the scheduler's coalescing path: one
    /// `SessionAppendBatch` per (chain member, sweep) holding planned
    /// appends. Unbatched in-step calls are visible only through the
    /// models' own [`calls`](crate::spec::types::LanguageModel::calls)
    /// counters.
    pub engine_calls: AtomicU64,
    /// The subset of [`engine_calls`](Self::engine_calls) that coalesced
    /// two or more sessions — the calls cross-request batching saved.
    pub batched_calls: AtomicU64,
    /// Tokens appended through batched engine calls.
    pub batched_tokens: AtomicU64,
    /// Sessions-per-batched-call occupancy distribution.
    pub batch_occupancy: CountHistogram,
    /// Suffix tokens the coalescing path actually computed — the O(suffix)
    /// work a KV-cached engine pays per planned append.
    pub suffix_tokens_computed: AtomicU64,
    /// Prefix tokens the KV cache spared those appends from re-scoring (a
    /// stateless engine would recompute each session's whole prefix). The
    /// recompute-avoided ratio is `avoided / (avoided + computed)`.
    pub prefix_tokens_avoided: AtomicU64,
    /// Gauge: tokens currently resident in device/host KV across all live
    /// sequences (store semantics — last sweep's observation wins).
    pub cache_resident_tokens: AtomicU64,
    /// Requests currently holding a live decode task on some worker.
    inflight: AtomicU64,
    inflight_peak: AtomicU64,
    /// Mean-acceptance accumulator (sum of per-request μ x 1000, fixed point).
    accept_milli_sum: AtomicU64,
    accept_count: AtomicU64,
    /// Per-task completion counters.
    per_task: Mutex<BTreeMap<String, u64>>,
    /// Per-model health trackers (error/retry/timeout counters + breaker
    /// state), registered by workers at engine-load time so snapshots show
    /// engine-boundary health alongside serving throughput.
    model_health: Mutex<BTreeMap<String, Arc<HealthTracker>>>,
}

impl Metrics {
    pub fn record_completion(
        &self,
        queue: Duration,
        service: Duration,
        tokens: usize,
        target_forwards: u64,
        mean_accept: f64,
        task: Option<&str>,
    ) {
        self.queue_latency.record(queue);
        self.service_latency.record(service);
        self.e2e_latency.record(queue + service);
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.target_forwards.fetch_add(target_forwards, Ordering::Relaxed);
        self.accept_milli_sum
            .fetch_add((mean_accept * 1000.0) as u64, Ordering::Relaxed);
        self.accept_count.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = task {
            *self.per_task.lock().entry(t.to_string()).or_insert(0) += 1;
        }
    }

    /// Record a request's time-to-first-token (enqueue -> first commit).
    /// Only called when a first token actually committed — a request that
    /// commits nothing (e.g. `max_new == 0`) has no TTFT and must not
    /// pollute the histogram.
    pub fn record_first_token(&self, ttft: Duration) {
        self.ttft_latency.record(ttft);
    }

    /// A live decode task was suspended to free KV for another request.
    pub fn record_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    /// A preempted task was re-admitted; `wasted_tokens` is the prefix its
    /// fresh sessions must re-score (prompt + committed + in-flight).
    pub fn record_resume(&self, wasted_tokens: usize) {
        self.resumes.fetch_add(1, Ordering::Relaxed);
        self.wasted_recompute_tokens.fetch_add(wasted_tokens as u64, Ordering::Relaxed);
    }

    /// A request failed inside a worker (task open or decode error).
    pub fn record_failure(&self) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` chain members were dropped by graceful degradation (the request
    /// keeps running on the surviving chain).
    pub fn record_degradation(&self, n: u32) {
        self.chains_degraded.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// A request was cancelled for running past its deadline.
    pub fn record_deadline_cancel(&self) {
        self.deadline_cancellations.fetch_add(1, Ordering::Relaxed);
    }

    /// `tokens` of a new sequence's content were mapped from the prefix
    /// cache at admission.
    pub fn record_prefix_hit(&self, tokens: usize) {
        self.prefix_hit_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// A shared block was split copy-on-write.
    pub fn record_cow_split(&self) {
        self.cow_splits.fetch_add(1, Ordering::Relaxed);
    }

    /// A preemption victim's `blocks` moved to the swap tier.
    pub fn record_swap_out(&self, blocks: usize) {
        self.swapped_blocks.fetch_add(blocks as u64, Ordering::Relaxed);
    }

    /// A swap restore spared `tokens` of prefix recompute.
    pub fn record_restore_saved(&self, tokens: usize) {
        self.restore_tokens_saved.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// One coalesced engine call: `sessions` live sessions' planned
    /// appends went out as a single `SessionAppendBatch` carrying
    /// `tokens` tokens total.
    pub fn record_engine_call(&self, sessions: usize, tokens: usize) {
        self.engine_calls.fetch_add(1, Ordering::Relaxed);
        if sessions >= 2 {
            self.batched_calls.fetch_add(1, Ordering::Relaxed);
        }
        self.batched_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.batch_occupancy.record(sessions as u64);
    }

    /// One sweep's coalesced appends: `computed` suffix tokens were scored,
    /// while the sessions' caches spared `avoided` prefix tokens from being
    /// re-scored (what a stateless engine would have recomputed).
    pub fn record_suffix_work(&self, computed: usize, avoided: usize) {
        self.suffix_tokens_computed.fetch_add(computed as u64, Ordering::Relaxed);
        self.prefix_tokens_avoided.fetch_add(avoided as u64, Ordering::Relaxed);
    }

    /// Overwrite the cache-residency gauge with this sweep's observation.
    pub fn set_cache_resident(&self, tokens: usize) {
        self.cache_resident_tokens.store(tokens as u64, Ordering::Relaxed);
    }

    /// Fraction of would-be recompute the KV cache avoided:
    /// `avoided / (avoided + computed)`, 0.0 before any coalesced append.
    pub fn recompute_avoided_ratio(&self) -> f64 {
        let avoided = self.prefix_tokens_avoided.load(Ordering::Relaxed) as f64;
        let computed = self.suffix_tokens_computed.load(Ordering::Relaxed) as f64;
        if avoided + computed == 0.0 {
            0.0
        } else {
            avoided / (avoided + computed)
        }
    }

    /// Expose a model's [`HealthTracker`] in metrics snapshots. Workers
    /// call this once per chain member at engine-load time; re-registering
    /// the same name replaces the handle (workers share per-model trackers
    /// only if they share the model instance).
    pub fn register_model_health(&self, name: &str, tracker: Arc<HealthTracker>) {
        self.model_health.lock().insert(name.to_string(), tracker);
    }

    /// A decode task went live on a worker. Returns the new concurrency.
    pub fn task_started(&self) -> u64 {
        let now = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.inflight_peak.fetch_max(now, Ordering::Relaxed);
        now
    }

    /// A live decode task finished (or failed).
    pub fn task_ended(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Decode tasks currently in flight across all workers.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// High-water mark of in-flight concurrency.
    pub fn inflight_peak(&self) -> u64 {
        self.inflight_peak.load(Ordering::Relaxed)
    }

    pub fn mean_accept(&self) -> f64 {
        let n = self.accept_count.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.accept_milli_sum.load(Ordering::Relaxed) as f64 / 1000.0 / n as f64
        }
    }

    /// JSON snapshot for dumps / the `serve` example's final report.
    pub fn snapshot(&self) -> Json {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            obj.insert(k.to_string(), v);
        };
        put("requests_completed",
            Json::Num(self.requests_completed.load(Ordering::Relaxed) as f64));
        put("requests_rejected",
            Json::Num(self.requests_rejected.load(Ordering::Relaxed) as f64));
        put("requests_failed",
            Json::Num(self.requests_failed.load(Ordering::Relaxed) as f64));
        put("tokens_generated",
            Json::Num(self.tokens_generated.load(Ordering::Relaxed) as f64));
        put("target_forwards",
            Json::Num(self.target_forwards.load(Ordering::Relaxed) as f64));
        put("preemptions", Json::Num(self.preemptions.load(Ordering::Relaxed) as f64));
        put("resumes", Json::Num(self.resumes.load(Ordering::Relaxed) as f64));
        put("wasted_recompute_tokens",
            Json::Num(self.wasted_recompute_tokens.load(Ordering::Relaxed) as f64));
        put("chains_degraded",
            Json::Num(self.chains_degraded.load(Ordering::Relaxed) as f64));
        put("deadline_cancellations",
            Json::Num(self.deadline_cancellations.load(Ordering::Relaxed) as f64));
        put("prefix_hit_tokens",
            Json::Num(self.prefix_hit_tokens.load(Ordering::Relaxed) as f64));
        put("cow_splits", Json::Num(self.cow_splits.load(Ordering::Relaxed) as f64));
        put("swapped_blocks",
            Json::Num(self.swapped_blocks.load(Ordering::Relaxed) as f64));
        put("restore_tokens_saved",
            Json::Num(self.restore_tokens_saved.load(Ordering::Relaxed) as f64));
        put("engine_calls", Json::Num(self.engine_calls.load(Ordering::Relaxed) as f64));
        put("batched_calls", Json::Num(self.batched_calls.load(Ordering::Relaxed) as f64));
        put("batched_tokens",
            Json::Num(self.batched_tokens.load(Ordering::Relaxed) as f64));
        put("suffix_tokens_computed",
            Json::Num(self.suffix_tokens_computed.load(Ordering::Relaxed) as f64));
        put("prefix_tokens_avoided",
            Json::Num(self.prefix_tokens_avoided.load(Ordering::Relaxed) as f64));
        put("recompute_avoided_ratio", Json::Num(self.recompute_avoided_ratio()));
        put("cache_resident_tokens",
            Json::Num(self.cache_resident_tokens.load(Ordering::Relaxed) as f64));
        {
            let mut occ = BTreeMap::new();
            occ.insert("calls".into(), Json::Num(self.batch_occupancy.count() as f64));
            occ.insert("mean_sessions".into(), Json::Num(self.batch_occupancy.mean()));
            occ.insert("max_sessions".into(), Json::Num(self.batch_occupancy.max() as f64));
            occ.insert(
                "log2_buckets".into(),
                Json::Arr(
                    self.batch_occupancy
                        .buckets
                        .iter()
                        .map(|b| Json::Num(b.load(Ordering::Relaxed) as f64))
                        .collect(),
                ),
            );
            obj.insert("batch_occupancy".into(), Json::Obj(occ));
        }
        put("mean_accept", Json::Num(self.mean_accept()));
        put("inflight", Json::Num(self.inflight() as f64));
        put("inflight_peak", Json::Num(self.inflight_peak() as f64));
        for (name, h) in [
            ("queue", &self.queue_latency),
            ("service", &self.service_latency),
            ("e2e", &self.e2e_latency),
            ("ttft", &self.ttft_latency),
        ] {
            let mut lat = BTreeMap::new();
            lat.insert("mean_ms".into(), Json::Num(h.mean().as_secs_f64() * 1e3));
            lat.insert("p50_ms".into(), Json::Num(h.quantile(0.5).as_secs_f64() * 1e3));
            lat.insert("p95_ms".into(), Json::Num(h.quantile(0.95).as_secs_f64() * 1e3));
            lat.insert("p99_ms".into(), Json::Num(h.quantile(0.99).as_secs_f64() * 1e3));
            lat.insert("max_ms".into(), Json::Num(h.max().as_secs_f64() * 1e3));
            obj.insert(format!("{name}_latency"), Json::Obj(lat));
        }
        let per_task = self.per_task.lock();
        obj.insert(
            "per_task".into(),
            Json::Obj(per_task.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect()),
        );
        let model_health = self.model_health.lock();
        obj.insert(
            "model_health".into(),
            Json::Obj(
                model_health
                    .iter()
                    .map(|(name, h)| {
                        let mut m = BTreeMap::new();
                        m.insert("errors".into(), Json::Num(h.errors() as f64));
                        m.insert("retries".into(), Json::Num(h.retries() as f64));
                        m.insert("timeouts".into(), Json::Num(h.timeouts() as f64));
                        m.insert(
                            "consecutive_failures".into(),
                            Json::Num(h.consecutive_failures() as f64),
                        );
                        m.insert(
                            "breaker".into(),
                            Json::Str(h.breaker_state().as_str().to_string()),
                        );
                        (name.clone(), Json::Obj(m))
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::default();
        for ms in [1u64, 2, 4, 8, 16, 32, 64, 128] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 8);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(1.0).max(h.max()));
        assert!(h.mean() >= Duration::from_millis(10));
    }

    #[test]
    fn inflight_gauge_tracks_peak() {
        let m = Metrics::default();
        assert_eq!(m.task_started(), 1);
        assert_eq!(m.task_started(), 2);
        m.task_ended();
        assert_eq!(m.task_started(), 2);
        m.task_ended();
        m.task_ended();
        assert_eq!(m.inflight(), 0);
        assert_eq!(m.inflight_peak(), 2);
        m.record_first_token(Duration::from_millis(3));
        assert_eq!(m.ttft_latency.count(), 1);
    }

    #[test]
    fn metrics_snapshot_is_valid_json() {
        let m = Metrics::default();
        m.record_completion(
            Duration::from_millis(2),
            Duration::from_millis(40),
            32,
            5,
            6.4,
            Some("Math"),
        );
        m.record_preemption();
        m.record_resume(37);
        m.record_failure();
        m.record_degradation(2);
        m.record_deadline_cancel();
        m.record_prefix_hit(16);
        m.record_cow_split();
        m.record_swap_out(5);
        m.record_restore_saved(20);
        m.record_engine_call(3, 12); // coalesced: 3 sessions in one call
        m.record_engine_call(1, 2); // singleton batch: engine call, not "batched"
        m.record_suffix_work(14, 42); // 14 suffix rows scored, 42 prefix spared
        m.set_cache_resident(100);
        m.set_cache_resident(56); // gauge: last observation wins
        let health = Arc::new(HealthTracker::default());
        health.record_failure(crate::spec::types::FaultKind::Transient);
        health.record_retry();
        m.register_model_health("target", health);
        let snap = m.snapshot().to_string();
        let parsed = Json::parse(&snap).unwrap();
        assert_eq!(parsed.req("requests_completed").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("tokens_generated").unwrap().as_usize(), Some(32));
        assert!(parsed.req("per_task").unwrap().get("Math").is_some());
        assert!((parsed.req("mean_accept").unwrap().as_f64().unwrap() - 6.4).abs() < 1e-9);
        assert_eq!(parsed.req("preemptions").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("resumes").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("wasted_recompute_tokens").unwrap().as_usize(), Some(37));
        assert_eq!(parsed.req("requests_failed").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("chains_degraded").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.req("deadline_cancellations").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("prefix_hit_tokens").unwrap().as_usize(), Some(16));
        assert_eq!(parsed.req("cow_splits").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("swapped_blocks").unwrap().as_usize(), Some(5));
        assert_eq!(parsed.req("restore_tokens_saved").unwrap().as_usize(), Some(20));
        assert_eq!(parsed.req("engine_calls").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.req("batched_calls").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.req("batched_tokens").unwrap().as_usize(), Some(14));
        assert_eq!(parsed.req("suffix_tokens_computed").unwrap().as_usize(), Some(14));
        assert_eq!(parsed.req("prefix_tokens_avoided").unwrap().as_usize(), Some(42));
        assert!((parsed.req("recompute_avoided_ratio").unwrap().as_f64().unwrap() - 0.75).abs()
            < 1e-9);
        assert_eq!(parsed.req("cache_resident_tokens").unwrap().as_usize(), Some(56));
        let occ = parsed.req("batch_occupancy").unwrap();
        assert_eq!(occ.get("calls").unwrap().as_usize(), Some(2));
        assert!((occ.get("mean_sessions").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(occ.get("max_sessions").unwrap().as_usize(), Some(3));
        // 3 sessions -> bucket 1 ([2,4)); 1 session -> bucket 0.
        let buckets = occ.get("log2_buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets[0].as_usize(), Some(1));
        assert_eq!(buckets[1].as_usize(), Some(1));
        let target = parsed.req("model_health").unwrap().get("target").unwrap();
        assert_eq!(target.get("errors").unwrap().as_usize(), Some(1));
        assert_eq!(target.get("retries").unwrap().as_usize(), Some(1));
        assert_eq!(target.get("timeouts").unwrap().as_usize(), Some(0));
        assert_eq!(target.get("consecutive_failures").unwrap().as_usize(), Some(1));
        assert!(matches!(target.get("breaker"), Some(Json::Str(s)) if s == "closed"));
    }
}
