//! The serving loop: router -> batcher -> worker threads -> responses.
//!
//! Each worker thread owns its own [`EngineHost`] (PJRT objects are
//! thread-bound), parks on the shared queue, and runs the
//! continuous-batching step scheduler ([`scheduler::run_batch`]) over a
//! chain of resumable decode tasks: new requests are admitted between
//! decode steps, committed tokens stream out per step, and a short
//! interactive request finishes while a long batch request is still
//! mid-decode. Clients receive either a single final [`Response`]
//! ([`Server::submit`]) or a live [`StreamItem`] feed of per-step token
//! deltas ([`Server::submit_stream`]). No Python anywhere near this path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::runtime::EngineHost;
use crate::workload::tasks::TaskKind;

use super::api::{Method, Request, Response, StreamItem};
use super::batcher::{BatchPolicy, DynamicBatcher};
use super::kv::{chain_bytes_per_token, KvConfig, KvManager};
use super::metrics::Metrics;
use super::router::{FamilyLane, RejectReason, Router};
use super::scheduler::{self, BatchEvent};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub family: String,
    /// Chain roles, target first.
    pub roles: Vec<String>,
    pub workers: usize,
    pub batch: BatchPolicy,
    /// KV pool size in blocks of 16 tokens.
    pub kv_blocks: usize,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>, family: &str) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            family: family.to_string(),
            roles: vec!["target".into(), "intermediate".into(), "draft".into()],
            workers: 1,
            batch: BatchPolicy::default(),
            kv_blocks: 512,
        }
    }
}

/// Where a request's output goes: one final response, or a live stream of
/// per-step deltas followed by the final response.
enum ReplySink {
    Final(mpsc::Sender<Response>),
    Stream(mpsc::Sender<StreamItem>),
}

type SinkMap = Arc<Mutex<HashMap<u64, ReplySink>>>;

/// A running server instance.
pub struct Server {
    router: Router,
    batcher: Arc<DynamicBatcher>,
    metrics: Arc<Metrics>,
    kv: Arc<Mutex<KvManager>>,
    replies: SinkMap,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    seq_len: usize,
}

impl Server {
    /// Start the server: load engines on every worker and begin serving.
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        let batcher = Arc::new(DynamicBatcher::new(cfg.batch));
        let metrics = Arc::new(Metrics::default());
        let replies: SinkMap = Arc::new(Mutex::new(HashMap::new()));

        // Probe the manifest once for chain geometry.
        let manifest = crate::runtime::Manifest::load(&cfg.artifacts_dir)?;
        let fam = manifest.family(&cfg.family)?;
        let metas: Vec<_> = cfg
            .roles
            .iter()
            .map(|r| fam.role(r).map(|s| s.meta.clone()))
            .collect::<Result<_>>()?;
        let seq_len = metas.iter().map(|m| m.seq_len).min().context("empty chain")?;
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
            block_size: 16,
            total_blocks: cfg.kv_blocks,
            bytes_per_token: chain_bytes_per_token(&metas),
        })));

        let mut router = Router::new(cfg.family.clone());
        router.add_lane(
            cfg.family.clone(),
            FamilyLane {
                batcher: batcher.clone(),
                kv: kv.clone(),
                seq_len,
                n_models: cfg.roles.len(),
            },
        );

        let mut workers = Vec::with_capacity(cfg.workers);
        let roles: Vec<String> = cfg.roles.clone();
        let max_live = cfg.batch.max_batch;
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let metrics = metrics.clone();
            let kv = kv.clone();
            let replies = replies.clone();
            let artifacts = cfg.artifacts_dir.clone();
            let family = cfg.family.clone();
            let roles = roles.clone();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    let role_refs: Vec<&str> = roles.iter().map(|s| s.as_str()).collect();
                    let host = match EngineHost::load(artifacts, &family, &role_refs) {
                        Ok(h) => {
                            let _ = ready_tx.send(Ok(()));
                            h
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    let chain = host.chain();
                    // Park until work arrives, then continuously batch: the
                    // step scheduler keeps admitting from the queue between
                    // steps and returns only once it drains.
                    while let Some(batch) = batcher.pop_batch() {
                        scheduler::run_batch(
                            &chain,
                            batch,
                            Some(&batcher),
                            max_live,
                            &kv,
                            &metrics,
                            |event| deliver(&replies, event),
                        );
                    }
                })
                .context("spawning worker")?;
            ready_rx
                .recv()
                .context("worker died during startup")?
                .with_context(|| format!("worker {w} failed to load engines"))?;
            workers.push(handle);
        }

        Ok(Self {
            router,
            batcher,
            metrics,
            kv,
            replies,
            workers,
            next_id: AtomicU64::new(1),
            seq_len,
        })
    }

    fn make_request(
        &self,
        prompt: Vec<crate::spec::types::Token>,
        max_new: usize,
        method: Method,
        task: Option<TaskKind>,
    ) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, max_new);
        req.method = method;
        req.task = task;
        if let Some(t) = task {
            req.sampling.temperature = t.temperature();
            req.sampling.seed = id;
        }
        req
    }

    fn route(&self, req: Request, sink: ReplySink) -> Result<(), RejectReason> {
        let id = req.id;
        self.replies.lock().unwrap().insert(id, sink);
        match self.router.route(None, req) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.replies.lock().unwrap().remove(&id);
                self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit a generation; returns a receiver that yields the final
    /// response once the decode completes.
    pub fn submit(
        &self,
        prompt: Vec<crate::spec::types::Token>,
        max_new: usize,
        method: Method,
        task: Option<TaskKind>,
    ) -> Result<mpsc::Receiver<Response>, RejectReason> {
        let req = self.make_request(prompt, max_new, method, task);
        let (tx, rx) = mpsc::channel();
        self.route(req, ReplySink::Final(tx))?;
        Ok(rx)
    }

    /// Submit a generation and stream it: the receiver yields a
    /// [`StreamItem::Delta`] for every decode step that commits tokens
    /// (first delta = time-to-first-token), then [`StreamItem::Done`] with
    /// the final response. A failed decode simply closes the channel.
    pub fn submit_stream(
        &self,
        prompt: Vec<crate::spec::types::Token>,
        max_new: usize,
        method: Method,
        task: Option<TaskKind>,
    ) -> Result<mpsc::Receiver<StreamItem>, RejectReason> {
        let req = self.make_request(prompt, max_new, method, task);
        let (tx, rx) = mpsc::channel();
        self.route(req, ReplySink::Stream(tx))?;
        Ok(rx)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.lock().unwrap().utilization()
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Drain the queue and stop all workers.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.clone()
    }

    /// Wait until the queue is empty and all in-flight work finished (poll).
    pub fn quiesce(&self, timeout: Duration) -> bool {
        let start = std::time::Instant::now();
        while start.elapsed() < timeout {
            if self.batcher.is_empty() && self.replies.lock().unwrap().is_empty() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }
}

/// Fan a scheduler event out to the request's sink. Delta events reach
/// stream sinks only; Done removes the sink and delivers the final
/// response (errors close the channel by dropping the sink).
fn deliver(replies: &SinkMap, event: BatchEvent<'_>) {
    match event {
        BatchEvent::Delta { id, tokens } => {
            let map = replies.lock().unwrap();
            if let Some(ReplySink::Stream(tx)) = map.get(&id) {
                let _ = tx.send(StreamItem::Delta(tokens.to_vec()));
            }
        }
        BatchEvent::Done { id, response } => {
            let sink = replies.lock().unwrap().remove(&id);
            if let (Some(sink), Ok(resp)) = (sink, response) {
                match sink {
                    ReplySink::Final(tx) => {
                        let _ = tx.send(resp);
                    }
                    ReplySink::Stream(tx) => {
                        let _ = tx.send(StreamItem::Done(resp));
                    }
                }
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
