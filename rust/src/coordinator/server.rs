//! The serving loop: router -> batcher -> worker threads -> responses.
//!
//! Each worker thread owns its own [`EngineHost`] (PJRT objects are
//! thread-bound), parks on the shared queue, and runs the
//! continuous-batching step scheduler ([`scheduler::run_batch`]) over a
//! chain of resumable decode tasks: new requests are admitted between
//! decode steps, committed tokens stream out per step, and a short
//! interactive request finishes while a long batch request is still
//! mid-decode. Clients receive either a single final
//! `Result<Response, DecodeError>` ([`Server::submit`]) or a live
//! [`StreamItem`] feed of per-step token deltas ([`Server::submit_stream`]);
//! decode failures arrive as typed [`DecodeError`] values, never as a bare
//! channel close. KV-pool saturation preempts and resumes decodes
//! transparently (see `coordinator::scheduler`) — clients never observe a
//! pool-pressure failure. Each worker registers its chain's per-model
//! health trackers with [`Metrics`], so snapshots expose engine-boundary
//! errors, retries, and breaker states. No Python anywhere near this path.

use std::collections::HashMap;
use std::time::Duration;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{mpsc, thread, Arc, Mutex};

use anyhow::{Context, Result};

use crate::runtime::EngineHost;
use crate::spec::types::LanguageModel;
use crate::workload::tasks::TaskKind;

use super::api::{DecodeError, Method, Request, Response, StreamItem};
use super::batcher::{BatchPolicy, DynamicBatcher};
use super::kv::{chain_bytes_per_token, KvConfig, KvManager};
use super::metrics::Metrics;
use super::router::{FamilyLane, RejectReason, Router};
use super::scheduler::{self, BatchEvent};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub family: String,
    /// Chain roles, target first.
    pub roles: Vec<String>,
    pub workers: usize,
    pub batch: BatchPolicy,
    /// KV pool size in blocks of 16 tokens.
    pub kv_blocks: usize,
    /// Suspend-to-swap tier size in blocks (0 disables swap: preemption
    /// victims discard their KV and re-score the prefix on resume).
    pub swap_blocks: usize,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>, family: &str) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            family: family.to_string(),
            roles: vec!["target".into(), "intermediate".into(), "draft".into()],
            workers: 1,
            batch: BatchPolicy::default(),
            kv_blocks: 512,
            swap_blocks: 256,
        }
    }
}

/// Where a request's output goes: one final `Result` (response or typed
/// failure), or a live stream of per-step deltas ending in
/// [`StreamItem::Done`] / [`StreamItem::Failed`]. Either way a decode
/// failure reaches the client as a value — never as a bare channel close.
enum ReplySink {
    Final(mpsc::Sender<Result<Response, DecodeError>>),
    Stream(mpsc::Sender<StreamItem>),
}

type SinkMap = Arc<Mutex<HashMap<u64, ReplySink>>>;

/// A running server instance.
pub struct Server {
    router: Router,
    batcher: Arc<DynamicBatcher>,
    metrics: Arc<Metrics>,
    kv: Arc<Mutex<KvManager>>,
    replies: SinkMap,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: AtomicU64,
    seq_len: usize,
}

impl Server {
    /// Start the server: load engines on every worker and begin serving.
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        let batcher = Arc::new(DynamicBatcher::new(cfg.batch));
        let metrics = Arc::new(Metrics::default());
        let replies: SinkMap = Arc::new(Mutex::new(HashMap::new()));

        // Probe the manifest once for chain geometry.
        let manifest = crate::runtime::Manifest::load(&cfg.artifacts_dir)?;
        let fam = manifest.family(&cfg.family)?;
        let metas: Vec<_> = cfg
            .roles
            .iter()
            .map(|r| fam.role(r).map(|s| s.meta.clone()))
            .collect::<Result<_>>()?;
        let seq_len = metas.iter().map(|m| m.seq_len).min().context("empty chain")?;
        let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
            block_size: 16,
            total_blocks: cfg.kv_blocks,
            bytes_per_token: chain_bytes_per_token(&metas),
            swap_blocks: cfg.swap_blocks,
        })));
        // Mirror the paged-KV meters (prefix hits, CoW splits, swap
        // traffic) into the server-wide snapshot.
        kv.lock().attach_metrics(metrics.clone());

        let mut router = Router::new(cfg.family.clone());
        router.add_lane(
            cfg.family.clone(),
            FamilyLane {
                batcher: batcher.clone(),
                kv: kv.clone(),
                seq_len,
                n_models: cfg.roles.len(),
            },
        );

        let mut workers = Vec::with_capacity(cfg.workers);
        let roles: Vec<String> = cfg.roles.clone();
        let max_live = cfg.batch.max_batch;
        for w in 0..cfg.workers {
            let batcher = batcher.clone();
            let metrics = metrics.clone();
            let kv = kv.clone();
            let replies = replies.clone();
            let artifacts = cfg.artifacts_dir.clone();
            let family = cfg.family.clone();
            let roles = roles.clone();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let handle = thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    let role_refs: Vec<&str> = roles.iter().map(|s| s.as_str()).collect();
                    let host = match EngineHost::load(artifacts, &family, &role_refs) {
                        Ok(h) => {
                            let _ = ready_tx.send(Ok(()));
                            h
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    let chain = host.chain();
                    // Expose per-model engine health (error/retry/timeout
                    // counters + breaker state) in metrics snapshots.
                    for m in chain.iter() {
                        if let Some(h) = m.health_handle() {
                            metrics.register_model_health(m.name(), h);
                        }
                    }
                    // Park until work arrives, then continuously batch: the
                    // step scheduler keeps admitting from the queue between
                    // steps and returns only once it drains.
                    while let Some(batch) = batcher.pop_batch() {
                        scheduler::run_batch(
                            &chain,
                            batch,
                            Some(&batcher),
                            max_live,
                            &kv,
                            &metrics,
                            |event| deliver(&replies, event),
                        );
                    }
                })
                .context("spawning worker")?;
            ready_rx
                .recv()
                .context("worker died during startup")?
                .with_context(|| format!("worker {w} failed to load engines"))?;
            workers.push(handle);
        }

        Ok(Self {
            router,
            batcher,
            metrics,
            kv,
            replies,
            workers,
            next_id: AtomicU64::new(1),
            seq_len,
        })
    }

    fn make_request(
        &self,
        prompt: Vec<crate::spec::types::Token>,
        max_new: usize,
        method: Method,
        task: Option<TaskKind>,
    ) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, max_new);
        req.method = method;
        req.task = task;
        if let Some(t) = task {
            req.sampling.temperature = t.temperature();
            req.sampling.seed = id;
        }
        req
    }

    fn route(&self, req: Request, sink: ReplySink) -> Result<(), RejectReason> {
        let id = req.id;
        self.replies.lock().insert(id, sink);
        match self.router.route(None, req) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.replies.lock().remove(&id);
                self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit a generation; returns a receiver that yields the final
    /// result once the decode completes — `Ok(Response)` on success,
    /// `Err(DecodeError)` if the decode failed, so a failure is observable
    /// (and classifiable) rather than an unexplained channel close.
    pub fn submit(
        &self,
        prompt: Vec<crate::spec::types::Token>,
        max_new: usize,
        method: Method,
        task: Option<TaskKind>,
    ) -> Result<mpsc::Receiver<Result<Response, DecodeError>>, RejectReason> {
        let req = self.make_request(prompt, max_new, method, task);
        let (tx, rx) = mpsc::channel();
        self.route(req, ReplySink::Final(tx))?;
        Ok(rx)
    }

    /// Submit a generation and stream it: the receiver yields a
    /// [`StreamItem::Delta`] for every decode step that commits tokens
    /// (first delta = time-to-first-token), then [`StreamItem::Done`] with
    /// the final response — or [`StreamItem::Failed`] with the reason if
    /// the decode errored.
    pub fn submit_stream(
        &self,
        prompt: Vec<crate::spec::types::Token>,
        max_new: usize,
        method: Method,
        task: Option<TaskKind>,
    ) -> Result<mpsc::Receiver<StreamItem>, RejectReason> {
        let req = self.make_request(prompt, max_new, method, task);
        let (tx, rx) = mpsc::channel();
        self.route(req, ReplySink::Stream(tx))?;
        Ok(rx)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.lock().utilization()
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Drain the queue and stop all workers.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.clone()
    }

    /// Wait until the queue is empty and all in-flight work finished (poll).
    pub fn quiesce(&self, timeout: Duration) -> bool {
        let start = crate::sync::time::Instant::now();
        while start.elapsed() < timeout {
            if self.batcher.is_empty() && self.replies.lock().is_empty() {
                return true;
            }
            thread::sleep(Duration::from_millis(10));
        }
        false
    }
}

/// Fan a scheduler event out to the request's sink. Delta events reach
/// stream sinks only; Done removes the sink and delivers the outcome —
/// including failures, which used to be dropped on the floor here (the
/// old code destructured `(Some(sink), Ok(resp))`, so an `Err` response
/// left the client staring at a bare channel close with no reason).
fn deliver(replies: &SinkMap, event: BatchEvent<'_>) {
    match event {
        BatchEvent::Delta { id, tokens } => {
            let map = replies.lock();
            if let Some(ReplySink::Stream(tx)) = map.get(&id) {
                let _ = tx.send(StreamItem::Delta(tokens.to_vec()));
            }
        }
        BatchEvent::Done { id, response } => {
            let sink = replies.lock().remove(&id);
            match (sink, response) {
                (Some(ReplySink::Final(tx)), outcome) => {
                    let _ = tx.send(outcome);
                }
                (Some(ReplySink::Stream(tx)), Ok(resp)) => {
                    let _ = tx.send(StreamItem::Done(resp));
                }
                (Some(ReplySink::Stream(tx)), Err(e)) => {
                    let _ = tx.send(StreamItem::Failed(e));
                }
                (None, _) => {}
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_response(id: u64) -> Response {
        Response {
            id,
            tokens: vec![1, 2, 3],
            queue_time: Duration::from_millis(1),
            service_time: Duration::from_millis(2),
            ttft: Some(Duration::from_millis(1)),
            preemptions: 0,
            mean_accept: 0.0,
            forward_passes: vec![3],
            degraded: 0,
            task: None,
            method: Method::Autoregressive,
        }
    }

    #[test]
    fn deliver_surfaces_errors_to_final_sink() {
        let replies: SinkMap = Arc::new(Mutex::new(HashMap::new()));
        let (tx, rx) = mpsc::channel();
        replies.lock().insert(7, ReplySink::Final(tx));
        deliver(
            &replies,
            BatchEvent::Done { id: 7, response: Err(DecodeError::Internal("boom".into())) },
        );
        let got = rx.recv().expect("failure must be delivered, not dropped");
        assert_eq!(got.unwrap_err(), DecodeError::Internal("boom".into()));
        assert!(replies.lock().is_empty(), "sink must be removed");
    }

    #[test]
    fn deliver_surfaces_errors_to_stream_sink() {
        let replies: SinkMap = Arc::new(Mutex::new(HashMap::new()));
        let (tx, rx) = mpsc::channel();
        replies.lock().insert(8, ReplySink::Stream(tx));
        deliver(&replies, BatchEvent::Delta { id: 8, tokens: &[4, 5] });
        deliver(&replies, BatchEvent::Done { id: 8, response: Err(DecodeError::EngineLost) });
        assert!(matches!(rx.recv().unwrap(), StreamItem::Delta(t) if t == vec![4, 5]));
        match rx.recv().unwrap() {
            StreamItem::Failed(err) => assert_eq!(err, DecodeError::EngineLost),
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn deliver_success_paths_still_work() {
        let replies: SinkMap = Arc::new(Mutex::new(HashMap::new()));
        let (ftx, frx) = mpsc::channel();
        let (stx, srx) = mpsc::channel();
        replies.lock().insert(1, ReplySink::Final(ftx));
        replies.lock().insert(2, ReplySink::Stream(stx));
        deliver(&replies, BatchEvent::Done { id: 1, response: Ok(mk_response(1)) });
        deliver(&replies, BatchEvent::Done { id: 2, response: Ok(mk_response(2)) });
        assert_eq!(frx.recv().unwrap().unwrap().id, 1);
        assert!(matches!(srx.recv().unwrap(), StreamItem::Done(r) if r.id == 2));
    }
}
