//! L3 serving coordinator: router -> admission queue -> continuous-batching
//! step scheduler, with live-length KV accounting and serving metrics.
//!
//! The decode algorithms live in [`crate::spec`] as resumable
//! [`DecodeTask`](crate::spec::task::DecodeTask)s; this layer turns them
//! into a server. Scheduling is **step-level**: each worker round-robins
//! one draft→verify round per live task, admits newly queued requests
//! between steps ([`batcher`]), streams committed tokens as they land
//! ([`api::StreamItem`]), grows KV allocations with live sequence lengths
//! ([`kv`]), and reports time-to-first-token + in-flight concurrency
//! ([`metrics`]). Short interactive requests therefore finish while long
//! batch requests are still mid-decode — no head-of-line blocking — while
//! a starvation guard keeps sustained interactive load from parking batch
//! traffic forever. When the overcommitted KV pool saturates mid-decode,
//! the scheduler preempts a victim task (suspend + release + re-queue
//! ahead of fresh same-class arrivals) and resumes it byte-identically
//! once space frees — pool pressure delays requests, it never fails them.

pub mod api;
pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use api::{Method, Request, Response, ResumeCarry, StreamItem};
pub use scheduler::BatchEvent;
pub use server::{Server, ServerConfig};
