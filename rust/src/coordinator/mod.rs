//! L3 serving coordinator: router -> admission queue -> continuous-batching
//! step scheduler, with live-length KV accounting and serving metrics.
//!
//! The decode algorithms live in [`crate::spec`] as resumable
//! [`DecodeTask`](crate::spec::task::DecodeTask)s; this layer turns them
//! into a server. Scheduling is **step-level**: each worker round-robins
//! one draft→verify round per live task, admits newly queued requests
//! between steps ([`batcher`]), streams committed tokens as they land
//! ([`api::StreamItem`]), grows KV allocations with live sequence lengths
//! ([`kv`]), and reports time-to-first-token + in-flight concurrency
//! ([`metrics`]). Short interactive requests therefore finish while long
//! batch requests are still mid-decode — no head-of-line blocking — while
//! a starvation guard keeps sustained interactive load from parking batch
//! traffic forever. When the overcommitted KV pool saturates mid-decode,
//! the scheduler preempts a victim task (suspend + release + re-queue
//! ahead of fresh same-class arrivals) and resumes it byte-identically
//! once space frees — pool pressure delays requests, it never fails them.
//!
//! # Failure semantics
//!
//! Every fault has exactly one of three outcomes, and clients can tell
//! them apart:
//!
//! * **Degrade** — a drafter (any chain member except the target) that
//!   fails a scoring call, or whose engine health breaker is open at a
//!   step boundary, is dropped from the chain mid-decode. The request
//!   keeps running on the surviving chain — polybasic shrinks toward
//!   dualistic and ultimately plain autoregressive decode on the target.
//!   Because only the target's verification decides what commits,
//!   degradation **preserves the output distribution**, and under
//!   deterministic verify rules (greedy / top-1) the committed tokens are
//!   **byte-identical** to a healthy run. The response reports the drop
//!   count ([`Response::degraded`]); `chains_degraded` counts drops
//!   server-wide.
//! * **Fail** — a target failure (after the engine host's bounded
//!   retries), a KV pool smaller than one request's footprint, or an
//!   exceeded [`Request::deadline`](api::Request::deadline) fails the
//!   request with a typed [`DecodeError`] (`EngineLost` / `Saturated` /
//!   `Timeout` / `Internal`). On every failure path the task's scoring
//!   sessions are dropped and its KV allocation released — debug
//!   assertions in `scheduler` enforce the exactly-once release.
//! * **Delay** — KV-pool pressure preempts and later resumes a victim
//!   byte-identically; it is never an error.
//!
//! Engine-boundary hardening (deadlines on every engine round-trip,
//! bounded retries, per-model circuit breakers) lives in
//! [`crate::runtime::host`]; the deterministic fault-injection harness
//! used to test these paths is [`crate::spec::chaos`].
//!
//! KV capacity itself is a real paged subsystem ([`paged`]): refcounted
//! block tables, a radix prefix cache that maps shared prompt prefixes
//! copy-on-write, and a bounded swap tier that lets preemption suspend a
//! victim's KV instead of discarding it. [`kv`] is the policy layer over
//! it.

pub mod api;
pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod paged;
pub mod router;
pub mod scheduler;
pub mod server;

pub use api::{DecodeError, Method, Request, Response, ResumeCarry, StreamItem};
pub use scheduler::BatchEvent;
pub use server::{Server, ServerConfig};
