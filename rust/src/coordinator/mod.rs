//! L3 serving coordinator: router -> dynamic batcher -> worker scheduler,
//! with paged KV accounting and serving metrics. The decode algorithms live
//! in [`crate::spec`]; this layer turns them into a server.

pub mod api;
pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use api::{Method, Request, Response};
pub use server::{Server, ServerConfig};
