//! Byte-level tokenizer over the synthetic vocabulary.
//!
//! The model families use V = 256, so UTF-8 bytes map 1:1 onto token ids —
//! prompts can be real text while staying entirely within the synthetic
//! vocabulary.  (Token semantics are irrelevant to the system under test;
//! every layer treats ids as opaque.  See DESIGN.md §3.)

use crate::spec::types::Token;

/// Encode text as byte tokens, clamped to the model vocabulary.
pub fn encode(text: &str, vocab: usize) -> Vec<Token> {
    text.bytes().map(|b| (b as usize % vocab) as Token).collect()
}

/// Decode byte tokens back to a lossy string (non-UTF8 bytes become '.').
pub fn decode(tokens: &[Token]) -> String {
    tokens
        .iter()
        .map(|&t| {
            let b = t.clamp(0, 255) as u8;
            if b.is_ascii_graphic() || b == b' ' {
                b as char
            } else {
                '.'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let text = "Solve: 12 + 35 = ?";
        let toks = encode(text, 256);
        assert_eq!(decode(&toks), text);
    }

    #[test]
    fn clamps_to_vocab() {
        let toks = encode("é", 100); // multi-byte utf-8, bytes >= 100
        assert!(toks.iter().all(|&t| (t as usize) < 100));
    }
}
