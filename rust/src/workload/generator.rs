//! Request-stream generation: SpecBench sweeps (batch-1 latency, the
//! paper's protocol) and Poisson arrival streams for the serving example.

use crate::spec::rng::Pcg32;

use super::tasks::{make_query, Query, TaskKind, ALL_TASKS};

/// A fixed benchmark suite: `queries_per_task` queries for each category,
/// deterministic in (task, index).
pub fn specbench_suite(queries_per_task: usize, vocab: usize) -> Vec<Query> {
    let mut out = Vec::with_capacity(queries_per_task * ALL_TASKS.len());
    for task in ALL_TASKS {
        for i in 0..queries_per_task {
            out.push(make_query(task, i as u64, vocab));
        }
    }
    out
}

/// Queries for one task only.
pub fn task_queries(task: TaskKind, n: usize, vocab: usize) -> Vec<Query> {
    (0..n).map(|i| make_query(task, i as u64, vocab)).collect()
}

/// A timed arrival: offset from stream start plus the query.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at: std::time::Duration,
    pub query: Query,
}

/// Poisson arrival stream with task mix drawn uniformly from all six
/// categories — drives the end-to-end serving example.
pub struct ArrivalStream {
    rng: Pcg32,
    rate_per_s: f64,
    vocab: usize,
    t: f64,
    idx: u64,
}

impl ArrivalStream {
    pub fn new(rate_per_s: f64, vocab: usize, seed: u64) -> Self {
        assert!(rate_per_s > 0.0);
        Self { rng: Pcg32::seeded(seed), rate_per_s, vocab, t: 0.0, idx: 0 }
    }
}

impl Iterator for ArrivalStream {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        self.t += self.rng.next_exp(self.rate_per_s);
        let task = ALL_TASKS[self.rng.next_below(ALL_TASKS.len() as u32) as usize];
        let q = make_query(task, self.idx, self.vocab);
        self.idx += 1;
        Some(Arrival { at: std::time::Duration::from_secs_f64(self.t), query: q })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_covers_all_tasks() {
        let suite = specbench_suite(3, 256);
        assert_eq!(suite.len(), 18);
        for task in ALL_TASKS {
            assert_eq!(suite.iter().filter(|q| q.task == task).count(), 3);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let stream = ArrivalStream::new(10.0, 256, 1);
        let arrivals: Vec<_> = stream.take(200).collect();
        for w in arrivals.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        // 200 arrivals at 10/s should span roughly 20s.
        let span = arrivals.last().unwrap().at.as_secs_f64();
        assert!(span > 10.0 && span < 40.0, "{span}");
    }
}
