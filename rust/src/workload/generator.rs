//! Request-stream generation: SpecBench sweeps (batch-1 latency, the
//! paper's protocol), Poisson arrival streams for the serving example, and
//! multi-turn conversation streams whose successive turns share nested
//! prompt prefixes (the workload the paged-KV radix cache exists for).

use crate::spec::rng::Pcg32;
use crate::spec::types::Token;

use super::tasks::{make_query, Query, TaskKind, ALL_TASKS};

/// A fixed benchmark suite: `queries_per_task` queries for each category,
/// deterministic in (task, index).
pub fn specbench_suite(queries_per_task: usize, vocab: usize) -> Vec<Query> {
    let mut out = Vec::with_capacity(queries_per_task * ALL_TASKS.len());
    for task in ALL_TASKS {
        for i in 0..queries_per_task {
            out.push(make_query(task, i as u64, vocab));
        }
    }
    out
}

/// Queries for one task only.
pub fn task_queries(task: TaskKind, n: usize, vocab: usize) -> Vec<Query> {
    (0..n).map(|i| make_query(task, i as u64, vocab)).collect()
}

/// A timed arrival: offset from stream start plus the query.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at: std::time::Duration,
    pub query: Query,
}

/// Poisson arrival stream with task mix drawn uniformly from all six
/// categories — drives the end-to-end serving example.
pub struct ArrivalStream {
    rng: Pcg32,
    rate_per_s: f64,
    vocab: usize,
    t: f64,
    idx: u64,
}

impl ArrivalStream {
    pub fn new(rate_per_s: f64, vocab: usize, seed: u64) -> Self {
        assert!(rate_per_s > 0.0);
        Self { rng: Pcg32::seeded(seed), rate_per_s, vocab, t: 0.0, idx: 0 }
    }
}

impl Iterator for ArrivalStream {
    type Item = Arrival;

    fn next(&mut self) -> Option<Arrival> {
        self.t += self.rng.next_exp(self.rate_per_s);
        let task = ALL_TASKS[self.rng.next_below(ALL_TASKS.len() as u32) as usize];
        let q = make_query(task, self.idx, self.vocab);
        self.idx += 1;
        Some(Arrival { at: std::time::Duration::from_secs_f64(self.t), query: q })
    }
}

/// A timed arrival within a multi-turn conversation.
#[derive(Debug, Clone)]
pub struct ConvArrival {
    pub at: std::time::Duration,
    /// Conversation this turn belongs to.
    pub session: u64,
    /// 1-based turn number within the conversation.
    pub turn: usize,
    /// The request: its prompt embeds the conversation's full transcript
    /// so far, so turn `k+1`'s prompt has turn `k`'s prompt as a strict
    /// token prefix.
    pub query: Query,
}

struct ConvState {
    id: u64,
    turn: usize,
    /// Prompt + synthetic assistant reply of every turn so far. The reply
    /// stands in for the server's actual output (unknown at generation
    /// time); what matters for the KV layer is that turn prompts nest.
    transcript: Vec<Token>,
}

/// Poisson arrival stream of multi-turn conversations. Each arrival either
/// opens a new conversation (a fresh [`TaskKind::MultiTurn`] query) or
/// continues an open one: a continuation's prompt is the whole transcript
/// so far plus a fresh user chunk, so successive turns share strictly
/// nested prefixes — a serving stack with a prefix cache re-maps the prior
/// turn's blocks instead of re-allocating them. Conversations retire after
/// [`max_turns`](Self::with_caps) turns or when the transcript reaches
/// `max_prompt` tokens (so generated prompts stay inside a serving
/// context window). Deterministic in the seed.
pub struct ConversationStream {
    rng: Pcg32,
    rate_per_s: f64,
    vocab: usize,
    t: f64,
    max_prompt: usize,
    max_turns: usize,
    sessions: Vec<ConvState>,
    next_session: u64,
}

impl ConversationStream {
    pub fn new(rate_per_s: f64, vocab: usize, seed: u64) -> Self {
        assert!(rate_per_s > 0.0);
        Self {
            rng: Pcg32::seeded(seed),
            rate_per_s,
            vocab,
            t: 0.0,
            max_prompt: 96,
            max_turns: 4,
            sessions: Vec::new(),
            next_session: 0,
        }
    }

    /// Bound transcript growth: conversations retire once they hit
    /// `max_turns` turns or a `max_prompt`-token transcript. Size
    /// `max_prompt` below the serving context window minus one output
    /// budget, or continuations will be rejected at the router.
    pub fn with_caps(mut self, max_prompt: usize, max_turns: usize) -> Self {
        self.max_prompt = max_prompt.max(1);
        self.max_turns = max_turns.max(1);
        self
    }

    /// Synthetic MultiTurn-flavoured tokens (ascii-text alphabet).
    fn push_chat_tokens(&mut self, out: &mut Vec<Token>, n: usize) {
        let lo: Token = 32;
        let hi = 127usize.min(self.vocab - 1) as Token;
        for _ in 0..n {
            out.push(lo + self.rng.next_below((hi - lo + 1) as u32) as Token);
        }
    }
}

impl Iterator for ConversationStream {
    type Item = ConvArrival;

    fn next(&mut self) -> Option<ConvArrival> {
        self.t += self.rng.next_exp(self.rate_per_s);
        let at = std::time::Duration::from_secs_f64(self.t);
        // 2-in-3 continuation bias when conversations are open: multi-turn
        // traffic is mostly follow-ups, which is what makes prefix reuse
        // the dominant admission path.
        let continue_open =
            !self.sessions.is_empty() && self.rng.next_below(3) < 2;
        if !continue_open {
            let id = self.next_session;
            self.next_session += 1;
            let query = make_query(TaskKind::MultiTurn, id, self.vocab);
            let mut transcript = query.prompt.clone();
            let reply_len = query.max_new;
            self.push_chat_tokens(&mut transcript, reply_len);
            self.sessions.push(ConvState { id, turn: 1, transcript });
            return Some(ConvArrival { at, session: id, turn: 1, query });
        }
        let idx = self.rng.next_below(self.sessions.len() as u32) as usize;
        let chunk_len = 8 + self.rng.next_below(17) as usize; // 8..=24
        let (omin, omax) = TaskKind::MultiTurn.output_len_range();
        let max_new = omin + self.rng.next_below((omax - omin + 1) as u32) as usize;
        // Follow-up turn: prompt = the transcript so far + a fresh user
        // chunk, so this prompt strictly extends the previous turn's.
        let mut prompt = std::mem::take(&mut self.sessions[idx].transcript);
        self.push_chat_tokens(&mut prompt, chunk_len);
        // The stored transcript additionally carries a synthetic assistant
        // reply, so the *next* turn nests past this whole exchange.
        let mut transcript = prompt.clone();
        self.push_chat_tokens(&mut transcript, max_new);
        let s = &mut self.sessions[idx];
        s.transcript = transcript;
        s.turn += 1;
        let (id, turn) = (s.id, s.turn);
        if turn >= self.max_turns || s.transcript.len() >= self.max_prompt {
            self.sessions.swap_remove(idx);
        }
        let query = Query {
            task: TaskKind::MultiTurn,
            prompt,
            max_new,
            temperature: TaskKind::MultiTurn.temperature(),
        };
        Some(ConvArrival { at, session: id, turn, query })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_covers_all_tasks() {
        let suite = specbench_suite(3, 256);
        assert_eq!(suite.len(), 18);
        for task in ALL_TASKS {
            assert_eq!(suite.iter().filter(|q| q.task == task).count(), 3);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let stream = ArrivalStream::new(10.0, 256, 1);
        let arrivals: Vec<_> = stream.take(200).collect();
        for w in arrivals.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        // 200 arrivals at 10/s should span roughly 20s.
        let span = arrivals.last().unwrap().at.as_secs_f64();
        assert!(span > 10.0 && span < 40.0, "{span}");
    }

    #[test]
    fn conversation_turns_share_strictly_nested_prefixes() {
        let stream = ConversationStream::new(20.0, 256, 7).with_caps(160, 5);
        let arrivals: Vec<_> = stream.take(120).collect();
        let mut last: std::collections::BTreeMap<u64, (usize, Vec<i32>)> = Default::default();
        let mut followups = 0usize;
        for a in &arrivals {
            assert!(a.turn >= 1 && a.turn <= 5);
            assert!(a.query.task == TaskKind::MultiTurn);
            if let Some((prev_turn, prev_prompt)) = last.get(&a.session) {
                followups += 1;
                assert_eq!(a.turn, prev_turn + 1, "turns must be sequential");
                assert!(
                    a.query.prompt.len() > prev_prompt.len()
                        && a.query.prompt[..prev_prompt.len()] == prev_prompt[..],
                    "session {}: turn {} prompt must strictly extend turn {}",
                    a.session,
                    a.turn,
                    prev_turn
                );
            } else {
                assert_eq!(a.turn, 1, "a session's first observed turn is turn 1");
            }
            last.insert(a.session, (a.turn, a.query.prompt.clone()));
        }
        assert!(followups > 20, "most multi-turn traffic should be follow-ups: {followups}");
        // Transcript caps bound prompt growth (transcript < 160 when the
        // turn was generated, plus one user chunk of at most 24 tokens).
        for a in &arrivals {
            assert!(a.query.prompt.len() < 160 + 24, "{}", a.query.prompt.len());
        }
    }

    #[test]
    fn conversation_stream_is_deterministic() {
        let a: Vec<_> = ConversationStream::new(5.0, 256, 42).take(60).collect();
        let b: Vec<_> = ConversationStream::new(5.0, 256, 42).take(60).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.session, y.session);
            assert_eq!(x.turn, y.turn);
            assert_eq!(x.query.prompt, y.query.prompt);
            assert_eq!(x.query.max_new, y.query.max_new);
        }
    }
}
