//! SpecBench-style task profiles.
//!
//! The paper evaluates on SpecBench's six categories (MT-bench multi-turn,
//! WMT14 translation, CNN/DM summarization, NQ question answering, GSM8K
//! math, DPR RAG).  We cannot ship those datasets; what drives the paper's
//! per-task numbers is the *shape* of each task — prompt length, output
//! length, and decoding temperature (math/MT run sharp and predictable,
//! summarization/RAG run long-context) — so each profile reproduces those
//! axes plus a distinctive prompt token distribution (see DESIGN.md §3).

use crate::spec::rng::Pcg32;
use crate::spec::types::Token;

use super::tokenizer;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    MultiTurn,
    Translation,
    Summarization,
    Qa,
    Math,
    Rag,
}

pub const ALL_TASKS: [TaskKind; 6] = [
    TaskKind::MultiTurn,
    TaskKind::Translation,
    TaskKind::Summarization,
    TaskKind::Qa,
    TaskKind::Math,
    TaskKind::Rag,
];

impl TaskKind {
    /// Short label matching the paper's Table 2 column heads.
    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::MultiTurn => "MT",
            TaskKind::Translation => "Trans.",
            TaskKind::Summarization => "Sum.",
            TaskKind::Qa => "QA",
            TaskKind::Math => "Math",
            TaskKind::Rag => "RAG",
        }
    }

    pub fn from_label(s: &str) -> Option<TaskKind> {
        match s.to_ascii_lowercase().as_str() {
            "mt" | "multiturn" => Some(TaskKind::MultiTurn),
            "trans" | "trans." | "translation" => Some(TaskKind::Translation),
            "sum" | "sum." | "summarization" => Some(TaskKind::Summarization),
            "qa" => Some(TaskKind::Qa),
            "math" => Some(TaskKind::Math),
            "rag" => Some(TaskKind::Rag),
            _ => None,
        }
    }

    /// (min, max) prompt length in tokens.
    pub fn prompt_len_range(&self) -> (usize, usize) {
        match self {
            TaskKind::MultiTurn => (16, 40),
            TaskKind::Translation => (20, 44),
            TaskKind::Summarization => (40, 64), // long source documents
            TaskKind::Qa => (12, 32),
            TaskKind::Math => (16, 36),
            TaskKind::Rag => (44, 64), // retrieved passages dominate
        }
    }

    /// Output budget in tokens.
    pub fn output_len_range(&self) -> (usize, usize) {
        match self {
            TaskKind::MultiTurn => (32, 48),
            TaskKind::Translation => (24, 44),
            TaskKind::Summarization => (32, 48),
            TaskKind::Qa => (20, 40),
            TaskKind::Math => (32, 48),
            TaskKind::Rag => (24, 44),
        }
    }

    /// Decoding temperature: math / multi-turn chat decode sharply
    /// (deterministic reasoning / instruction following), summarization and
    /// RAG sample more freely — this is the lever behind the paper's
    /// per-task acceptance spread.
    pub fn temperature(&self) -> f32 {
        match self {
            TaskKind::MultiTurn => 0.72,
            TaskKind::Translation => 0.85,
            TaskKind::Summarization => 1.0,
            TaskKind::Qa => 0.9,
            TaskKind::Math => 0.65,
            TaskKind::Rag => 1.0,
        }
    }

    /// A seed prompt text characteristic of the task (encoded, then padded
    /// with task-flavoured synthetic tokens to the sampled length).
    fn seed_text(&self) -> &'static str {
        match self {
            TaskKind::MultiTurn => "User: thanks! one more thing - Assistant:",
            TaskKind::Translation => "Translate DE->EN: der schnelle braune Fuchs",
            TaskKind::Summarization => "Summarize the following article in two sentences:",
            TaskKind::Qa => "Q: who wrote the paper? A:",
            TaskKind::Math => "Q: 17 * 24 + 8 = ? Let's think step by step.",
            TaskKind::Rag => "Context: [doc 1] ... [doc 2] ... Answer using the context:",
        }
    }

    /// Token sub-alphabet the synthetic padding draws from — different tasks
    /// exercise different regions of the embedding table, which is what
    /// produces genuine per-task acceptance variation with derived drafters.
    fn alphabet(&self) -> (Token, Token) {
        match self {
            TaskKind::MultiTurn => (32, 127),   // ascii text
            TaskKind::Translation => (64, 192), // mixed scripts
            TaskKind::Summarization => (32, 160),
            TaskKind::Qa => (48, 122),
            TaskKind::Math => (40, 70),         // digits + operators region
            TaskKind::Rag => (32, 224),         // widest spread
        }
    }
}

/// One benchmark query.
#[derive(Debug, Clone)]
pub struct Query {
    pub task: TaskKind,
    pub prompt: Vec<Token>,
    pub max_new: usize,
    pub temperature: f32,
}

/// Deterministically generate the `idx`-th query of a task for a given
/// vocabulary.
pub fn make_query(task: TaskKind, idx: u64, vocab: usize) -> Query {
    let mut rng = Pcg32::new(idx.wrapping_mul(0x9e37) ^ task.label().len() as u64, 77);
    let (pmin, pmax) = task.prompt_len_range();
    let (omin, omax) = task.output_len_range();
    let plen = pmin + rng.next_below((pmax - pmin + 1) as u32) as usize;
    let olen = omin + rng.next_below((omax - omin + 1) as u32) as usize;

    let mut prompt = tokenizer::encode(task.seed_text(), vocab);
    let (lo, hi) = task.alphabet();
    let hi = (hi as usize).min(vocab - 1) as Token;
    while prompt.len() < plen {
        let span = (hi - lo + 1) as u32;
        prompt.push(lo + rng.next_below(span) as Token);
    }
    prompt.truncate(plen);

    Query { task, prompt, max_new: olen, temperature: task.temperature() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queries_are_deterministic() {
        let a = make_query(TaskKind::Math, 3, 256);
        let b = make_query(TaskKind::Math, 3, 256);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.max_new, b.max_new);
    }

    #[test]
    fn queries_vary_by_index() {
        let a = make_query(TaskKind::Qa, 0, 256);
        let b = make_query(TaskKind::Qa, 1, 256);
        assert!(a.prompt != b.prompt || a.max_new != b.max_new);
    }

    #[test]
    fn lengths_respect_ranges() {
        for task in ALL_TASKS {
            for i in 0..20 {
                let q = make_query(task, i, 256);
                let (pmin, pmax) = task.prompt_len_range();
                let (omin, omax) = task.output_len_range();
                assert!(q.prompt.len() >= pmin && q.prompt.len() <= pmax);
                assert!(q.max_new >= omin && q.max_new <= omax);
            }
        }
    }

    #[test]
    fn tokens_in_vocab() {
        for task in ALL_TASKS {
            let q = make_query(task, 5, 200);
            assert!(q.prompt.iter().all(|&t| (t as usize) < 200), "{task:?}");
        }
    }

    #[test]
    fn labels_roundtrip() {
        for task in ALL_TASKS {
            assert_eq!(TaskKind::from_label(task.label()), Some(task));
        }
    }
}
