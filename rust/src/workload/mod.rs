//! SpecBench-style workload suite: task profiles matching the paper's six
//! evaluation categories, a byte-level tokenizer, and request generators
//! (fixed suites, Poisson arrival streams, and multi-turn conversation
//! streams with nested prompt prefixes for prefix-cache workloads).

pub mod generator;
pub mod tasks;
pub mod tokenizer;

pub use generator::{specbench_suite, task_queries, ArrivalStream, ConvArrival, ConversationStream};
pub use tasks::{Query, TaskKind, ALL_TASKS};
