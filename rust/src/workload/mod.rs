//! SpecBench-style workload suite: task profiles matching the paper's six
//! evaluation categories, a byte-level tokenizer, and request generators
//! (fixed suites + Poisson arrival streams).

pub mod generator;
pub mod tasks;
pub mod tokenizer;

pub use generator::{specbench_suite, task_queries, ArrivalStream};
pub use tasks::{Query, TaskKind, ALL_TASKS};
