//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;

/// Element type of one weight argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgDtype {
    F32,
    S8,
    S32,
}

impl ArgDtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => ArgDtype::F32,
            "s8" => ArgDtype::S8,
            "s32" => ArgDtype::S32,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            ArgDtype::F32 | ArgDtype::S32 => 4,
            ArgDtype::S8 => 1,
        }
    }
}

/// One weight argument of a lowered executable: a slice of the params blob.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: ArgDtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// Architecture metadata of one chain member.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub param_count: usize,
    pub flops_per_forward: u64,
}

/// Legacy stacked-batch entry: `f(tokens [B, S]) -> (logits [B, S, V],)`.
///
/// Still O(prefix) per row (a vmap over the full-prefix forward); the engine
/// uses it to run stateless `forward_batch` as one submission instead of a
/// per-row `execute` loop. Cached sessions use [`IncrementalSpec`] instead.
#[derive(Debug, Clone)]
pub struct BatchedSpec {
    pub hlo_path: PathBuf,
    pub batch: usize,
}

/// Shape of one pool slot's K/V cache: `[n_layers, blocks, block_size,
/// n_heads, d_head]` f32, block-sized to match `coordinator::paged`.
#[derive(Debug, Clone)]
pub struct CacheSpec {
    pub block_size: usize,
    pub blocks: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
}

impl CacheSpec {
    /// f32 elements in one slot's K (or V) cache.
    pub fn slot_elems(&self) -> usize {
        self.n_layers * self.blocks * self.block_size * self.n_heads * self.d_head
    }
}

/// KV-cached incremental pair over a `batch`-slot device cache pool:
///
///   prefill: `f(tokens [S], slot [] s32, k_pool, v_pool, *w)
///             -> (logits [S, V], k_pool', v_pool')`
///   decode:  `f(suffixes [B, W], prefix_lens [B] s32, k_pool, v_pool, *w)
///             -> (logits [B, W, V], k_pool', v_pool')`
///
/// Pools are `[B, <CacheSpec>]`; the decode entry scores `window` suffix
/// tokens per slot per call in O(window · seq_len) — flat in prefix length.
#[derive(Debug, Clone)]
pub struct IncrementalSpec {
    pub prefill_path: PathBuf,
    pub decode_path: PathBuf,
    pub batch: usize,
    pub window: usize,
    pub cache: CacheSpec,
}

/// One chain member: where its HLO + weights live and what it looks like.
#[derive(Debug, Clone)]
pub struct RoleSpec {
    pub role: String,
    pub hlo_path: PathBuf,
    pub params_path: PathBuf,
    pub args: Vec<ArgSpec>,
    pub meta: ModelMeta,
    /// `--batched N` legacy stacked entry, when exported.
    pub batched: Option<BatchedSpec>,
    /// `--batched N` KV-cached prefill/decode pair, when exported.
    pub incremental: Option<IncrementalSpec>,
}

/// One model family (target + derived drafters).
#[derive(Debug, Clone)]
pub struct FamilySpec {
    pub family: String,
    pub roles: BTreeMap<String, RoleSpec>,
}

/// The whole artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub families: BTreeMap<String, FamilySpec>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, root)
    }

    pub fn parse(text: &str, root: PathBuf) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let mut families = BTreeMap::new();
        let fams = v.req("families")?.as_obj().context("families not an object")?;
        for (fam_name, fam) in fams {
            let mut roles = BTreeMap::new();
            let robj = fam.req("roles")?.as_obj().context("roles not an object")?;
            for (role_name, r) in robj {
                roles.insert(role_name.clone(), parse_role(role_name, r, &root)?);
            }
            families.insert(
                fam_name.clone(),
                FamilySpec { family: fam_name.clone(), roles },
            );
        }
        Ok(Manifest { root, families })
    }

    pub fn family(&self, name: &str) -> Result<&FamilySpec> {
        self.families.get(name).with_context(|| {
            format!(
                "family {name:?} not in manifest (have: {:?}); run `make artifacts ARTIFACT_SET=all`",
                self.families.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl FamilySpec {
    pub fn role(&self, name: &str) -> Result<&RoleSpec> {
        self.roles.get(name).with_context(|| {
            format!("role {name:?} not in family {} (have: {:?})", self.family,
                    self.roles.keys().collect::<Vec<_>>())
        })
    }
}

fn parse_role(role_name: &str, r: &Json, root: &Path) -> Result<RoleSpec> {
    let cfg = r.req("config")?;
    let meta = ModelMeta {
        name: cfg.req("name")?.as_str().context("name")?.to_string(),
        n_layers: cfg.req("n_layers")?.as_usize().context("n_layers")?,
        d_model: cfg.req("d_model")?.as_usize().context("d_model")?,
        n_heads: cfg.req("n_heads")?.as_usize().context("n_heads")?,
        d_ff: cfg.req("d_ff")?.as_usize().context("d_ff")?,
        vocab: cfg.req("vocab")?.as_usize().context("vocab")?,
        seq_len: cfg.req("seq_len")?.as_usize().context("seq_len")?,
        param_count: r.req("param_count")?.as_usize().context("param_count")?,
        flops_per_forward: r.req("flops_per_forward")?.as_f64().context("flops")? as u64,
    };
    let mut args = Vec::new();
    for a in r.req("args")?.as_arr().context("args not an array")? {
        args.push(ArgSpec {
            name: a.req("name")?.as_str().context("arg name")?.to_string(),
            dtype: ArgDtype::parse(a.req("dtype")?.as_str().context("arg dtype")?)?,
            shape: a
                .req("shape")?
                .as_arr()
                .context("arg shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?,
            offset: a.req("offset")?.as_usize().context("offset")?,
            nbytes: a.req("nbytes")?.as_usize().context("nbytes")?,
        });
    }
    let batched = match r.get("batched") {
        Some(b) => Some(BatchedSpec {
            hlo_path: root.join(b.req("hlo")?.as_str().context("batched hlo")?),
            batch: b.req("batch")?.as_usize().context("batched batch")?,
        }),
        None => None,
    };
    let incremental = match r.get("incremental") {
        Some(inc) => {
            let c = inc.req("cache")?;
            Some(IncrementalSpec {
                prefill_path: root
                    .join(inc.req("prefill_hlo")?.as_str().context("prefill_hlo")?),
                decode_path: root
                    .join(inc.req("decode_hlo")?.as_str().context("decode_hlo")?),
                batch: inc.req("batch")?.as_usize().context("incremental batch")?,
                window: inc.req("window")?.as_usize().context("window")?,
                cache: CacheSpec {
                    block_size: c.req("block_size")?.as_usize().context("block_size")?,
                    blocks: c.req("blocks")?.as_usize().context("blocks")?,
                    n_layers: c.req("n_layers")?.as_usize().context("cache n_layers")?,
                    n_heads: c.req("n_heads")?.as_usize().context("cache n_heads")?,
                    d_head: c.req("d_head")?.as_usize().context("cache d_head")?,
                },
            })
        }
        None => None,
    };
    Ok(RoleSpec {
        role: role_name.to_string(),
        hlo_path: root.join(r.req("hlo")?.as_str().context("hlo")?),
        params_path: root.join(r.req("params_bin")?.as_str().context("params_bin")?),
        args,
        meta,
        batched,
        incremental,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "families": {
        "fam": {
          "roles": {
            "target": {
              "hlo": "fam/target.hlo.txt",
              "params_bin": "fam/target.params.bin",
              "args": [
                {"name": "tok_emb", "dtype": "f32", "shape": [4, 2], "offset": 0, "nbytes": 32}
              ],
              "config": {"name": "t", "n_layers": 1, "d_model": 2, "n_heads": 1,
                         "d_ff": 4, "vocab": 4, "seq_len": 8, "seed": 0,
                         "residual_gain": 0.4},
              "param_count": 8,
              "flops_per_forward": 128,
              "batched": {"hlo": "fam/target.b4.hlo.txt", "batch": 4,
                          "params_bin": "fam/target.params.bin"},
              "incremental": {
                "prefill_hlo": "fam/target.prefill.hlo.txt",
                "decode_hlo": "fam/target.decode.b4.hlo.txt",
                "batch": 4, "window": 16,
                "cache": {"block_size": 16, "blocks": 2, "n_layers": 1,
                          "n_heads": 1, "d_head": 2},
                "params_bin": "fam/target.params.bin"
              }
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let fam = m.family("fam").unwrap();
        let role = fam.role("target").unwrap();
        assert_eq!(role.meta.vocab, 4);
        assert_eq!(role.args[0].dtype, ArgDtype::F32);
        assert_eq!(role.args[0].shape, vec![4, 2]);
        assert!(role.hlo_path.ends_with("fam/target.hlo.txt"));
    }

    #[test]
    fn parses_batched_and_incremental() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let role = m.family("fam").unwrap().role("target").unwrap();
        let b = role.batched.as_ref().unwrap();
        assert_eq!(b.batch, 4);
        assert!(b.hlo_path.ends_with("fam/target.b4.hlo.txt"));
        let inc = role.incremental.as_ref().unwrap();
        assert_eq!((inc.batch, inc.window), (4, 16));
        assert!(inc.prefill_path.ends_with("fam/target.prefill.hlo.txt"));
        assert!(inc.decode_path.ends_with("fam/target.decode.b4.hlo.txt"));
        assert_eq!(inc.cache.block_size * inc.cache.blocks, 32);
        assert_eq!(inc.cache.slot_elems(), 1 * 2 * 16 * 1 * 2);
    }

    #[test]
    fn batched_entries_are_optional() {
        // An older manifest (no --batched export) must still parse.
        let trimmed = {
            // Strip the two optional keys by reparsing a hand-built subset.
            let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
            assert!(m.family("fam").unwrap().role("target").unwrap().batched.is_some());
            SAMPLE
                .replace("\"batched\"", "\"batched_unused\"")
                .replace("\"incremental\"", "\"incremental_unused\"")
        };
        let m = Manifest::parse(&trimmed, PathBuf::from("/tmp/a")).unwrap();
        let role = m.family("fam").unwrap().role("target").unwrap();
        assert!(role.batched.is_none());
        assert!(role.incremental.is_none());
    }

    #[test]
    fn missing_family_is_helpful() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let err = m.family("nope").unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("fam"), "{err}");
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
