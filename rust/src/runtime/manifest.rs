//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;

/// Element type of one weight argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgDtype {
    F32,
    S8,
    S32,
}

impl ArgDtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => ArgDtype::F32,
            "s8" => ArgDtype::S8,
            "s32" => ArgDtype::S32,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            ArgDtype::F32 | ArgDtype::S32 => 4,
            ArgDtype::S8 => 1,
        }
    }
}

/// One weight argument of a lowered executable: a slice of the params blob.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: ArgDtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// Architecture metadata of one chain member.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub param_count: usize,
    pub flops_per_forward: u64,
}

/// One chain member: where its HLO + weights live and what it looks like.
#[derive(Debug, Clone)]
pub struct RoleSpec {
    pub role: String,
    pub hlo_path: PathBuf,
    pub params_path: PathBuf,
    pub args: Vec<ArgSpec>,
    pub meta: ModelMeta,
}

/// One model family (target + derived drafters).
#[derive(Debug, Clone)]
pub struct FamilySpec {
    pub family: String,
    pub roles: BTreeMap<String, RoleSpec>,
}

/// The whole artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub families: BTreeMap<String, FamilySpec>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, root)
    }

    pub fn parse(text: &str, root: PathBuf) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let mut families = BTreeMap::new();
        let fams = v.req("families")?.as_obj().context("families not an object")?;
        for (fam_name, fam) in fams {
            let mut roles = BTreeMap::new();
            let robj = fam.req("roles")?.as_obj().context("roles not an object")?;
            for (role_name, r) in robj {
                roles.insert(role_name.clone(), parse_role(role_name, r, &root)?);
            }
            families.insert(
                fam_name.clone(),
                FamilySpec { family: fam_name.clone(), roles },
            );
        }
        Ok(Manifest { root, families })
    }

    pub fn family(&self, name: &str) -> Result<&FamilySpec> {
        self.families.get(name).with_context(|| {
            format!(
                "family {name:?} not in manifest (have: {:?}); run `make artifacts ARTIFACT_SET=all`",
                self.families.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl FamilySpec {
    pub fn role(&self, name: &str) -> Result<&RoleSpec> {
        self.roles.get(name).with_context(|| {
            format!("role {name:?} not in family {} (have: {:?})", self.family,
                    self.roles.keys().collect::<Vec<_>>())
        })
    }
}

fn parse_role(role_name: &str, r: &Json, root: &Path) -> Result<RoleSpec> {
    let cfg = r.req("config")?;
    let meta = ModelMeta {
        name: cfg.req("name")?.as_str().context("name")?.to_string(),
        n_layers: cfg.req("n_layers")?.as_usize().context("n_layers")?,
        d_model: cfg.req("d_model")?.as_usize().context("d_model")?,
        n_heads: cfg.req("n_heads")?.as_usize().context("n_heads")?,
        d_ff: cfg.req("d_ff")?.as_usize().context("d_ff")?,
        vocab: cfg.req("vocab")?.as_usize().context("vocab")?,
        seq_len: cfg.req("seq_len")?.as_usize().context("seq_len")?,
        param_count: r.req("param_count")?.as_usize().context("param_count")?,
        flops_per_forward: r.req("flops_per_forward")?.as_f64().context("flops")? as u64,
    };
    let mut args = Vec::new();
    for a in r.req("args")?.as_arr().context("args not an array")? {
        args.push(ArgSpec {
            name: a.req("name")?.as_str().context("arg name")?.to_string(),
            dtype: ArgDtype::parse(a.req("dtype")?.as_str().context("arg dtype")?)?,
            shape: a
                .req("shape")?
                .as_arr()
                .context("arg shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?,
            offset: a.req("offset")?.as_usize().context("offset")?,
            nbytes: a.req("nbytes")?.as_usize().context("nbytes")?,
        });
    }
    Ok(RoleSpec {
        role: role_name.to_string(),
        hlo_path: root.join(r.req("hlo")?.as_str().context("hlo")?),
        params_path: root.join(r.req("params_bin")?.as_str().context("params_bin")?),
        args,
        meta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "families": {
        "fam": {
          "roles": {
            "target": {
              "hlo": "fam/target.hlo.txt",
              "params_bin": "fam/target.params.bin",
              "args": [
                {"name": "tok_emb", "dtype": "f32", "shape": [4, 2], "offset": 0, "nbytes": 32}
              ],
              "config": {"name": "t", "n_layers": 1, "d_model": 2, "n_heads": 1,
                         "d_ff": 4, "vocab": 4, "seq_len": 8, "seed": 0,
                         "residual_gain": 0.4},
              "param_count": 8,
              "flops_per_forward": 128
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let fam = m.family("fam").unwrap();
        let role = fam.role("target").unwrap();
        assert_eq!(role.meta.vocab, 4);
        assert_eq!(role.args[0].dtype, ArgDtype::F32);
        assert_eq!(role.args[0].shape, vec![4, 2]);
        assert!(role.hlo_path.ends_with("fam/target.hlo.txt"));
    }

    #[test]
    fn missing_family_is_helpful() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let err = m.family("nope").unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("fam"), "{err}");
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
