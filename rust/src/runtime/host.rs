//! EngineHost: a dedicated thread owning the PJRT client + every engine,
//! with `Send + Sync` proxy handles for the coordinator's worker threads.
//!
//! The `xla` crate's client is `Rc`-based, so all PJRT objects are pinned to
//! one thread. Each [`RemoteModel`] forwards `forward()` calls over an mpsc
//! channel and blocks on the reply; at our per-forward costs (hundreds of
//! microseconds to milliseconds of XLA compute) the channel round-trip is
//! noise (measured in benches/micro_hotpath.rs).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::spec::types::{LanguageModel, Logits, ModelCounters, Token};

use super::engine::{Client, ModelEngine};
use super::manifest::{Manifest, ModelMeta};

enum Req {
    Forward { model: usize, tokens: Vec<Token>, reply: mpsc::Sender<Result<Logits>> },
    CostProbe { model: usize, ctx_len: usize, iters: usize, reply: mpsc::Sender<Result<f64>> },
    Shutdown,
}

/// Owns the engine thread; dropping it shuts the thread down.
pub struct EngineHost {
    tx: mpsc::Sender<Req>,
    join: Option<std::thread::JoinHandle<()>>,
    metas: Vec<ModelMeta>,
    roles: Vec<String>,
}

impl EngineHost {
    /// Load `roles` of `family` from the artifacts at `root` on a fresh
    /// engine thread. Role order defines model indices (target first).
    pub fn load(root: impl Into<std::path::PathBuf>, family: &str, roles: &[&str]) -> Result<Self> {
        let root = root.into();
        let manifest = Manifest::load(&root)?;
        let fam = manifest.family(family)?;
        let specs: Vec<_> = roles
            .iter()
            .map(|r| fam.role(r).cloned())
            .collect::<Result<Vec<_>>>()?;
        let metas: Vec<ModelMeta> = specs.iter().map(|s| s.meta.clone()).collect();
        let role_names: Vec<String> = specs.iter().map(|s| s.role.clone()).collect();

        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("engine-{family}"))
            .spawn(move || engine_thread(specs, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")?
            .context("engine startup failed")?;
        Ok(Self { tx, join: Some(join), metas, roles: role_names })
    }

    /// A `Send + Sync` handle to model `idx` (index into the role order).
    pub fn model(&self, idx: usize) -> Arc<RemoteModel> {
        assert!(idx < self.metas.len(), "model index {idx} out of range");
        Arc::new(RemoteModel {
            idx,
            meta: self.metas[idx].clone(),
            tx: Mutex::new(self.tx.clone()),
            counters: ModelCounters::default(),
        })
    }

    /// Handles for the whole chain, role order preserved.
    pub fn chain(&self) -> Vec<Arc<dyn LanguageModel>> {
        (0..self.metas.len()).map(|i| self.model(i) as Arc<dyn LanguageModel>).collect()
    }

    pub fn metas(&self) -> &[ModelMeta] {
        &self.metas
    }

    pub fn roles(&self) -> &[String] {
        &self.roles
    }

    /// Measure per-forward cost (ms) of model `idx` on the engine thread
    /// itself — no channel overhead in the measurement.
    pub fn measure_cost_ms(&self, idx: usize, ctx_len: usize, iters: usize) -> Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::CostProbe { model: idx, ctx_len, iters, reply })
            .ok()
            .context("engine thread gone")?;
        rx.recv().context("engine thread gone")?
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_thread(
    specs: Vec<super::manifest::RoleSpec>,
    rx: mpsc::Receiver<Req>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<Vec<ModelEngine>> {
        let client = Client::cpu()?;
        specs.iter().map(|s| ModelEngine::load(&client, s)).collect()
    })();
    let engines = match setup {
        Ok(engines) => {
            let _ = ready.send(Ok(()));
            engines
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Req::Forward { model, tokens, reply } => {
                let _ = reply.send(engines[model].forward(&tokens));
            }
            Req::CostProbe { model, ctx_len, iters, reply } => {
                let engine = &engines[model];
                let ctx: Vec<Token> = (0..ctx_len.min(engine.seq_len()))
                    .map(|i| (i % engine.vocab()) as Token)
                    .collect();
                let r = (|| -> Result<f64> {
                    let _ = engine.forward(&ctx)?; // warmup
                    let start = Instant::now();
                    for _ in 0..iters.max(1) {
                        let _ = engine.forward(&ctx)?;
                    }
                    Ok(start.elapsed().as_secs_f64() * 1e3 / iters.max(1) as f64)
                })();
                let _ = reply.send(r);
            }
            Req::Shutdown => break,
        }
    }
}

/// `Send + Sync` proxy to one engine on the host thread.
pub struct RemoteModel {
    idx: usize,
    meta: ModelMeta,
    tx: Mutex<mpsc::Sender<Req>>,
    counters: ModelCounters,
}

impl LanguageModel for RemoteModel {
    fn name(&self) -> &str {
        &self.meta.name
    }

    fn seq_len(&self) -> usize {
        self.meta.seq_len
    }

    fn vocab(&self) -> usize {
        self.meta.vocab
    }

    fn forward(&self, tokens: &[Token]) -> Result<Logits> {
        let start = Instant::now();
        let (reply, rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("engine tx poisoned");
            tx.send(Req::Forward { model: self.idx, tokens: tokens.to_vec(), reply })
                .ok()
                .context("engine thread gone")?;
        }
        let out = rx.recv().context("engine thread gone")??;
        self.counters.record(start.elapsed());
        Ok(out)
    }

    fn calls(&self) -> u64 {
        self.counters.calls()
    }

    fn total_time(&self) -> Duration {
        self.counters.total_time()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }
}
