//! EngineHost: a dedicated thread owning the PJRT client + every engine,
//! with `Send + Sync` proxy handles for the coordinator's worker threads.
//!
//! The `xla` crate's client is `Rc`-based, so all PJRT objects are pinned to
//! one thread. Each [`RemoteModel`] forwards `forward()` calls over an mpsc
//! channel and blocks on the reply; at our per-forward costs (hundreds of
//! microseconds to milliseconds of XLA compute) the channel round-trip is
//! noise (measured in benches/micro_hotpath.rs).
//!
//! # Session protocol
//!
//! [`RemoteModel::open_session`] speaks an incremental-decode protocol with
//! the engine thread (`SessionOpen` / `SessionAppend` / `SessionRollback` /
//! `SessionClose`). The engine thread keeps the authoritative token prefix
//! per session; an append ships only the *new* tokens over the channel and
//! the reply carries only the *new* logits rows — O(suffix · vocab) on the
//! wire instead of O(prefix · vocab) both ways. The host side
//! ([`RemoteSession`]) caches every row it has received, so `rollback` and
//! row re-reads never touch the channel.
//!
//! With a `--batched` artifact set, the engine thread also keeps each
//! session's K/V rows *device-resident* in the engine's cache pool: at
//! `SessionOpen` the session claims a pool slot (falling back to stateless
//! scoring when the pool is exhausted or absent), and each append then
//! executes the **O(suffix)** decode-step executable over only the new
//! tokens ([`ModelEngine::decode_batch`]). A session whose cache went
//! stale (rollback past a window boundary, capacity invalidation, or a
//! stateless-scored stretch) is *repaired* by one O(prefix)
//! [`ModelEngine::prefill`] and decodes incrementally again after.
//! Rollback stays O(1) on the cache (a length decrement — stale device
//! rows are overwritten by the next decode), so the
//! decode/prefill/stateless choice is invisible in the bytes: all three
//! score the same prefix with the same weights.
//!
//! # Batched appends (plan → submit → absorb)
//!
//! The step scheduler coalesces the pending suffixes of every live task
//! that targets the same chain member into one [`SessionAppendBatch`]
//! request per (model, tick): each task *plans* its next pure-append
//! engine call (`DecodeTask::plan_append`), the scheduler groups the plans
//! by model and *submits* one batched request per member
//! ([`LanguageModel::append_batch`]), and each task *absorbs* its
//! per-entry rows before `step()` runs — whose first reconcile is then a
//! free no-op. The engine thread splits each model's batch into
//! cache-resident sessions — **one** O(suffix) batched decode submission
//! over the pool ([`ModelEngine::decode_batch`]) — and stateless ones,
//! one stacked `[B, S]` submission when the legacy batched executable is
//! loaded ([`ModelEngine::forward_batch`]); each session's new rows are
//! sliced out of its group's result. The reply carries per-entry `Result`s, so
//! one poisoned session fails alone: failed entries are retried as a
//! *subset* batch under the same [`CallPolicy`] backoff, and every entry's
//! outcome feeds the per-model health tracker individually.
//!
//! [`SessionAppendBatch`]: Req::SessionAppendBatch
//!
//! # Deadlines, retries, health
//!
//! Every channel round-trip is bounded by a [`CallPolicy`] deadline
//! (`recv_timeout`) so a hung engine surfaces as a typed
//! [`ModelFault`]`::Timeout` instead of blocking a worker thread forever.
//! Clean engine *error replies* are retried with exponential backoff —
//! they are safe to retry because the engine rolls its session state back
//! before replying — but timeouts and disconnects are never retried: the
//! engine may still be executing the call, so its state is unknown. Every
//! outcome is recorded in a per-model [`HealthTracker`] (a
//! consecutive-failure circuit breaker) that the decode tasks consult via
//! [`LanguageModel::healthy`] to drop failing drafters.

use std::collections::HashMap;
use std::time::Duration;

use crate::sync::time::Instant;
use crate::sync::{mpsc, thread, Arc, Mutex};

use anyhow::{Context, Result};

use crate::spec::types::{
    FaultKind, HealthTracker, LanguageModel, Logits, ModelCounters, ModelFault, ScoringSession,
    Token,
};

use super::engine::{Client, ModelEngine};
use super::manifest::{Manifest, ModelMeta};

enum Req {
    Forward { model: usize, tokens: Vec<Token>, reply: mpsc::Sender<Result<Logits>> },
    CostProbe { model: usize, ctx_len: usize, iters: usize, reply: mpsc::Sender<Result<f64>> },
    SessionOpen { model: usize, reply: mpsc::Sender<u64> },
    /// Extend session `session` by `tokens`; the reply holds logits rows for
    /// the appended suffix only. Tokens ride in an `Arc` so retry attempts
    /// clone a pointer, not the buffer.
    SessionAppend { session: u64, tokens: Arc<[Token]>, reply: mpsc::Sender<Result<Logits>> },
    /// Extend many sessions at once; executed as one stacked forward per
    /// distinct model. The reply holds one `Result` per entry, in order —
    /// a bad entry (unknown session, over capacity) fails alone.
    SessionAppendBatch {
        appends: Vec<(u64, Arc<[Token]>)>,
        reply: mpsc::Sender<Vec<Result<Logits>>>,
    },
    SessionRollback { session: u64, to_len: usize, reply: mpsc::Sender<Result<()>> },
    SessionClose { session: u64 },
    Shutdown,
}

/// Deadline and retry policy for engine channel round-trips.
#[derive(Debug, Clone, Copy)]
pub struct CallPolicy {
    /// Per-round-trip reply deadline. A miss is a [`FaultKind::Timeout`].
    pub deadline: Duration,
    /// How many times a clean engine *error reply* is retried. Timeouts
    /// and disconnects are never retried (engine state unknown).
    pub retries: u32,
    /// Initial retry backoff, doubled per attempt.
    pub backoff: Duration,
}

impl Default for CallPolicy {
    fn default() -> Self {
        Self {
            deadline: Duration::from_secs(30),
            retries: 2,
            backoff: Duration::from_millis(10),
        }
    }
}

/// Owns the engine thread; dropping it shuts the thread down.
pub struct EngineHost {
    tx: mpsc::Sender<Req>,
    join: Option<thread::JoinHandle<()>>,
    metas: Vec<ModelMeta>,
    roles: Vec<String>,
    policy: CallPolicy,
}

impl EngineHost {
    /// Load `roles` of `family` from the artifacts at `root` on a fresh
    /// engine thread. Role order defines model indices (target first).
    pub fn load(root: impl Into<std::path::PathBuf>, family: &str, roles: &[&str]) -> Result<Self> {
        Self::load_with_policy(root, family, roles, CallPolicy::default())
    }

    /// [`load`](Self::load) with an explicit deadline/retry policy for
    /// every model handle this host creates.
    pub fn load_with_policy(
        root: impl Into<std::path::PathBuf>,
        family: &str,
        roles: &[&str],
        policy: CallPolicy,
    ) -> Result<Self> {
        let root = root.into();
        let manifest = Manifest::load(&root)?;
        let fam = manifest.family(family)?;
        let specs: Vec<_> = roles
            .iter()
            .map(|r| fam.role(r).cloned())
            .collect::<Result<Vec<_>>>()?;
        let metas: Vec<ModelMeta> = specs.iter().map(|s| s.meta.clone()).collect();
        let role_names: Vec<String> = specs.iter().map(|s| s.role.clone()).collect();

        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name(format!("engine-{family}"))
            .spawn(move || engine_thread(specs, rx, ready_tx))
            .context("spawning engine thread")?;
        // Startup compiles/loads every engine, so it gets a much more
        // generous deadline than a single forward.
        ready_rx
            .recv_timeout(policy.deadline.saturating_mul(10))
            .context("engine thread died or hung during startup")?
            .context("engine startup failed")?;
        Ok(Self { tx, join: Some(join), metas, roles: role_names, policy })
    }

    /// A `Send + Sync` handle to model `idx` (index into the role order).
    pub fn model(&self, idx: usize) -> Arc<RemoteModel> {
        assert!(idx < self.metas.len(), "model index {idx} out of range");
        Arc::new(RemoteModel {
            idx,
            meta: self.metas[idx].clone(),
            tx: Mutex::new(self.tx.clone()),
            counters: ModelCounters::default(),
            policy: self.policy,
            health: Arc::new(HealthTracker::default()),
        })
    }

    /// Handles for the whole chain, role order preserved.
    pub fn chain(&self) -> Vec<Arc<dyn LanguageModel>> {
        (0..self.metas.len()).map(|i| self.model(i) as Arc<dyn LanguageModel>).collect()
    }

    pub fn metas(&self) -> &[ModelMeta] {
        &self.metas
    }

    pub fn roles(&self) -> &[String] {
        &self.roles
    }

    /// Measure per-forward cost (ms) of model `idx` on the engine thread
    /// itself — no channel overhead in the measurement.
    pub fn measure_cost_ms(&self, idx: usize, ctx_len: usize, iters: usize) -> Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::CostProbe { model: idx, ctx_len, iters, reply })
            .ok()
            .context("engine thread gone")?;
        // The probe runs `iters + 1` forwards back to back; scale the
        // per-call deadline accordingly.
        rx.recv_timeout(self.policy.deadline.saturating_mul(iters.max(1) as u32 + 1))
            .context("engine thread gone or cost probe hung")?
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Engine-thread-side session state: the authoritative token prefix plus
/// the engine cache-pool slot holding its device-resident K/V rows
/// (`None` = stateless session: pool exhausted, absent, or stub build).
struct SessionState {
    model: usize,
    tokens: Vec<Token>,
    slot: Option<usize>,
}

/// Score `st.tokens[from..]` after the prefix was already extended,
/// preferring the cheapest valid path: O(suffix) cached decode, O(prefix)
/// cache repair (prefill), O(prefix) stateless forward. All three produce
/// identical rows (same prefix, same weights); only cost differs. The
/// caller rolls the prefix back on `Err`.
fn session_score(engine: &ModelEngine, st: &SessionState, from: usize) -> Result<Logits> {
    if from == st.tokens.len() {
        // Empty appends are free (ScoringSession invariant); never reach
        // the device.
        return Ok(Logits::new(Vec::new(), 0, engine.vocab()));
    }
    if let Some(slot) = st.slot {
        if engine.can_decode(slot, from) {
            let mut rows = engine.decode_batch(&[(slot, st.tokens.as_slice(), from)])?;
            // xtask:allow(panic): decode_batch returns one row per entry.
            return Ok(rows.pop().expect("one entry in, one out"));
        }
        // Stale cache (rollback past a window boundary, capacity
        // invalidation, or a stateless stretch): one prefill repositions
        // it at the full prefix, and this append's rows come for free.
        let logits = engine.prefill(slot, &st.tokens)?;
        return slice_rows(&logits, from, st.tokens.len());
    }
    let logits = engine.forward(&st.tokens)?;
    slice_rows(&logits, from, st.tokens.len())
}

/// Copy rows `[from, to)` out of a full-context logits block.
fn slice_rows(logits: &Logits, from: usize, to: usize) -> Result<Logits> {
    let vocab = logits.vocab();
    let mut data = Vec::with_capacity((to - from) * vocab);
    for t in from..to {
        data.extend_from_slice(logits.row(t));
    }
    Ok(Logits::new(data, to - from, vocab))
}

fn engine_thread(
    specs: Vec<super::manifest::RoleSpec>,
    rx: mpsc::Receiver<Req>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<Vec<ModelEngine>> {
        let client = Client::cpu()?;
        specs.iter().map(|s| ModelEngine::load(&client, s)).collect()
    })();
    let engines = match setup {
        Ok(engines) => {
            let _ = ready.send(Ok(()));
            engines
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    let mut next_session: u64 = 1;

    while let Ok(req) = rx.recv() {
        match req {
            Req::Forward { model, tokens, reply } => {
                let _ = reply.send(engines[model].forward(&tokens));
            }
            Req::CostProbe { model, ctx_len, iters, reply } => {
                let engine = &engines[model];
                let ctx: Vec<Token> = (0..ctx_len.min(engine.seq_len()))
                    .map(|i| (i % engine.vocab()) as Token)
                    .collect();
                let r = (|| -> Result<f64> {
                    let _ = engine.forward(&ctx)?; // warmup
                    let start = Instant::now();
                    for _ in 0..iters.max(1) {
                        let _ = engine.forward(&ctx)?;
                    }
                    Ok(start.elapsed().as_secs_f64() * 1e3 / iters.max(1) as f64)
                })();
                let _ = reply.send(r);
            }
            Req::SessionOpen { model, reply } => {
                let id = next_session;
                next_session += 1;
                // Claim a cache-pool slot if the role has an incremental
                // export with free capacity; stateless otherwise.
                let slot = engines[model].cache_alloc();
                sessions.insert(id, SessionState { model, tokens: Vec::new(), slot });
                let _ = reply.send(id);
            }
            Req::SessionAppend { session, tokens, reply } => {
                let r = (|| -> Result<Logits> {
                    let st = sessions.get_mut(&session).context("unknown session")?;
                    let from = st.tokens.len();
                    st.tokens.extend_from_slice(&tokens);
                    // O(suffix) on the cached path; the engine rolls its
                    // own slot state back implicitly (slot.len only
                    // advances on success), the prefix rolls back here.
                    let r = session_score(&engines[st.model], st, from);
                    if r.is_err() {
                        st.tokens.truncate(from);
                    }
                    r
                })();
                let _ = reply.send(r);
            }
            Req::SessionAppendBatch { appends, reply } => {
                let _ = reply.send(run_append_batch(&engines, &mut sessions, &appends));
            }
            Req::SessionRollback { session, to_len, reply } => {
                let r = (|| -> Result<()> {
                    let st = sessions.get_mut(&session).context("unknown session")?;
                    anyhow::ensure!(
                        to_len <= st.tokens.len(),
                        "rollback to {to_len} past session length {}",
                        st.tokens.len()
                    );
                    st.tokens.truncate(to_len);
                    // O(1) cache sync: drop cached rows past the new
                    // length; device rows are overwritten by later decodes.
                    if let Some(slot) = st.slot {
                        engines[st.model].cache_rollback(slot, to_len);
                    }
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Req::SessionClose { session } => {
                if let Some(st) = sessions.remove(&session) {
                    if let Some(slot) = st.slot {
                        engines[st.model].cache_free(slot);
                    }
                }
            }
            Req::Shutdown => break,
        }
    }
}

/// Execute a batched append on the engine thread: extend every named
/// session, then per distinct model run **one** O(suffix) batched decode
/// submission over the cache-resident sessions ([`ModelEngine::decode_batch`])
/// plus one stacked stateless forward over the rest
/// ([`ModelEngine::forward_batch`]), and slice each entry's new rows out
/// of its group's result. Entries fail individually (unknown session); a
/// group-level failure fails — and rolls back — every entry of that
/// group, leaving other groups' entries untouched.
fn run_append_batch(
    engines: &[ModelEngine],
    sessions: &mut HashMap<u64, SessionState>,
    appends: &[(u64, Arc<[Token]>)],
) -> Vec<Result<Logits>> {
    struct Staged {
        model: usize,
        session: u64,
        from: usize,
        len: usize,
    }
    let mut results: Vec<Option<Result<Logits>>> = appends.iter().map(|_| None).collect();
    // Stage 1: extend each entry's session in batch order, remembering
    // where its suffix starts. Two entries against the same session stack
    // (causal rows depend only on the prefix before them, so one
    // full-context forward scores both suffixes bit-identically to
    // sequential solo appends).
    let mut staged: Vec<Option<Staged>> = Vec::with_capacity(appends.len());
    for (i, (sid, tokens)) in appends.iter().enumerate() {
        match sessions.get_mut(sid) {
            None => {
                results[i] = Some(Err(anyhow::anyhow!("unknown session {sid}")));
                staged.push(None);
            }
            Some(st) => {
                let from = st.tokens.len();
                st.tokens.extend_from_slice(tokens);
                staged.push(Some(Staged { model: st.model, session: *sid, from, len: tokens.len() }));
            }
        }
    }
    // Stage 2: per distinct model, split its distinct sessions
    // (first-appearance order keeps this deterministic) into
    // cache-resident ones — scored by **one** batched decode submission
    // over only their suffixes — and stateless ones, scored by one
    // stacked full-prefix forward. `from0` is the pre-batch length (the
    // first staged entry per session carries it), so a cached session's
    // decode covers every stacked entry of this batch at once.
    let mut order: Vec<(usize, u64, usize)> = Vec::new();
    for s in staged.iter().flatten() {
        if !order.iter().any(|&(_, sid, _)| sid == s.session) {
            order.push((s.model, s.session, s.from));
        }
    }
    let mut distinct_models: Vec<usize> = order.iter().map(|&(m, _, _)| m).collect();
    distinct_models.sort_unstable();
    distinct_models.dedup();
    // Per-session scored rows + the absolute position of their first row
    // (0 for full-context results, `from0` for suffix-only decode results).
    let mut ok_rows: HashMap<u64, (usize, Logits)> = HashMap::new();
    let mut failed: HashMap<u64, String> = HashMap::new();
    for model in distinct_models {
        let engine = &engines[model];
        let mut cached: Vec<(u64, usize)> = Vec::new();
        let mut stateless: Vec<u64> = Vec::new();
        for &(m, sid, from0) in &order {
            if m != model {
                continue;
            }
            let st = &sessions[&sid];
            let on_cache = st
                .slot
                .is_some_and(|slot| engine.can_decode(slot, from0) && from0 < st.tokens.len());
            if on_cache {
                cached.push((sid, from0));
            } else {
                stateless.push(sid);
            }
        }
        if !cached.is_empty() {
            let rows: Vec<(usize, &[Token], usize)> = cached
                .iter()
                .map(|&(sid, from0)| {
                    let st = &sessions[&sid];
                    // xtask:allow(panic): `cached` holds slot-bearing sessions only.
                    (st.slot.expect("cached session has a slot"), st.tokens.as_slice(), from0)
                })
                .collect();
            match engine.decode_batch(&rows) {
                Ok(all) => {
                    for (&(sid, from0), logits) in cached.iter().zip(all) {
                        ok_rows.insert(sid, (from0, logits));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for &(sid, _) in &cached {
                        failed.insert(sid, msg.clone());
                    }
                }
            }
        }
        if !stateless.is_empty() {
            let prefixes: Vec<&[Token]> =
                stateless.iter().map(|sid| sessions[sid].tokens.as_slice()).collect();
            match engine.forward_batch(&prefixes) {
                Ok(all) => {
                    for (sid, logits) in stateless.iter().zip(all) {
                        ok_rows.insert(*sid, (0, logits));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for sid in &stateless {
                        failed.insert(*sid, msg.clone());
                    }
                }
            }
        }
    }
    // Stage 3: roll failed sessions back to their pre-batch length (the
    // first entry per session carries the smallest `from`), then slice
    // each successful entry's suffix rows.
    for s in staged.iter().flatten() {
        if failed.contains_key(&s.session) {
            if let Some(st) = sessions.get_mut(&s.session) {
                if st.tokens.len() > s.from {
                    st.tokens.truncate(s.from);
                }
            }
        }
    }
    for (i, s) in staged.iter().enumerate() {
        let Some(s) = s else { continue };
        if let Some(msg) = failed.get(&s.session) {
            results[i] = Some(Err(anyhow::anyhow!("batched forward failed: {msg}")));
        } else {
            let (base, logits) = &ok_rows[&s.session];
            let vocab = logits.vocab();
            let mut data = Vec::with_capacity(s.len * vocab);
            for t in s.from..s.from + s.len {
                data.extend_from_slice(logits.row(t - base));
            }
            results[i] = Some(Ok(Logits::new(data, s.len, vocab)));
        }
    }
    // xtask:allow(panic): both arms above filled every batch entry.
    results.into_iter().map(|r| r.expect("every batch entry resolved")).collect()
}

/// `Send + Sync` proxy to one engine on the host thread.
pub struct RemoteModel {
    idx: usize,
    meta: ModelMeta,
    tx: Mutex<mpsc::Sender<Req>>,
    counters: ModelCounters,
    policy: CallPolicy,
    health: Arc<HealthTracker>,
}

impl RemoteModel {
    fn fault(&self, kind: FaultKind) -> anyhow::Error {
        anyhow::Error::new(ModelFault { kind, model: self.meta.name.clone() })
    }

    fn send(&self, req: Req) -> Result<()> {
        // The facade lock recovers from a sibling thread panicking
        // mid-send (no poisoning); a genuinely dead engine still surfaces
        // below as a closed channel, i.e. a typed `Lost` fault.
        self.tx
            .lock()
            .send(req)
            .map_err(|_| self.fault(FaultKind::Lost).context("engine thread gone"))
    }

    /// Deadline-bounded reply wait. Timeout and disconnect both become
    /// typed [`ModelFault`]s.
    fn recv<T>(&self, rx: &mpsc::Receiver<T>) -> Result<T> {
        match rx.recv_timeout(self.policy.deadline) {
            Ok(v) => Ok(v),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(self.fault(FaultKind::Timeout)),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(self.fault(FaultKind::Lost)),
        }
    }

    /// One engine round-trip with the full policy applied. `attempt` runs
    /// send + recv: its outer `Result` is the transport (never retried —
    /// after a timeout the engine may still be executing the call, so its
    /// session state is unknown), the inner one is the engine's reply
    /// (retried with backoff — the engine rolls back before replying, so
    /// the call is idempotent). Outcomes feed the health tracker.
    fn call<T>(&self, mut attempt: impl FnMut() -> Result<Result<T>>) -> Result<T> {
        let mut backoff = self.policy.backoff;
        let mut tries_left = self.policy.retries;
        loop {
            match attempt() {
                Err(transport) => {
                    let kind = transport
                        .downcast_ref::<ModelFault>()
                        .map(|f| f.kind)
                        .unwrap_or(FaultKind::Lost);
                    self.health.record_failure(kind);
                    return Err(transport);
                }
                Ok(Ok(v)) => {
                    self.health.record_success();
                    return Ok(v);
                }
                Ok(Err(e)) => {
                    if tries_left == 0 {
                        self.health.record_failure(FaultKind::Transient);
                        return Err(e.context(ModelFault {
                            kind: FaultKind::Transient,
                            model: self.meta.name.clone(),
                        }));
                    }
                    tries_left -= 1;
                    self.health.record_retry();
                    thread::sleep(backoff);
                    backoff = backoff.saturating_mul(2);
                }
            }
        }
    }
}

impl LanguageModel for RemoteModel {
    fn name(&self) -> &str {
        &self.meta.name
    }

    fn seq_len(&self) -> usize {
        self.meta.seq_len
    }

    fn vocab(&self) -> usize {
        self.meta.vocab
    }

    fn forward(&self, tokens: &[Token]) -> Result<Logits> {
        let start = Instant::now();
        let out = self.call(|| {
            let (reply, rx) = mpsc::channel();
            self.send(Req::Forward { model: self.idx, tokens: tokens.to_vec(), reply })?;
            self.recv(&rx)
        })?;
        self.counters.record(start.elapsed());
        Ok(out)
    }

    fn calls(&self) -> u64 {
        self.counters.calls()
    }

    fn total_time(&self) -> Duration {
        self.counters.total_time()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }

    fn open_session(&self) -> Result<Box<dyn ScoringSession + '_>> {
        // The open reply is infallible engine-side, so wrap it as an
        // always-Ok inner result to reuse the policy path.
        let id = self.call(|| {
            let (reply, rx) = mpsc::channel();
            self.send(Req::SessionOpen { model: self.idx, reply })?;
            self.recv(&rx).map(Ok)
        })?;
        Ok(Box::new(RemoteSession {
            model: self,
            id,
            tokens: Vec::new(),
            rows: Vec::new(),
        }))
    }

    fn healthy(&self) -> bool {
        self.health.healthy()
    }

    fn health_handle(&self) -> Option<Arc<HealthTracker>> {
        Some(self.health.clone())
    }

    fn append_batch(&self, appends: &[(u64, Arc<[Token]>)]) -> Option<Vec<Result<Option<Logits>>>> {
        if appends.is_empty() {
            return Some(Vec::new());
        }
        let start = Instant::now();
        let mut out: Vec<Option<Result<Option<Logits>>>> =
            appends.iter().map(|_| None).collect();
        let mut pending: Vec<usize> = (0..appends.len()).collect();
        let mut backoff = self.policy.backoff;
        let mut tries_left = self.policy.retries;
        loop {
            let batch: Vec<(u64, Arc<[Token]>)> =
                pending.iter().map(|&i| appends[i].clone()).collect();
            let round = {
                let (reply, rx) = mpsc::channel();
                self.send(Req::SessionAppendBatch { appends: batch, reply })
                    .and_then(|()| self.recv(&rx))
            };
            let replies = match round {
                Err(transport) => {
                    // Transport faults are never retried (the engine may
                    // still be executing the batch, so session state is
                    // unknown): every still-pending entry fails with the
                    // same typed fault.
                    let kind = transport
                        .downcast_ref::<ModelFault>()
                        .map(|f| f.kind)
                        .unwrap_or(FaultKind::Lost);
                    for &i in &pending {
                        self.health.record_failure(kind);
                        out[i] = Some(Err(self.fault(kind)));
                    }
                    break;
                }
                Ok(replies) => replies,
            };
            // Clean error replies are retried as a *subset* batch: the
            // engine rolled those sessions back before replying, so the
            // retry re-applies cleanly while settled entries keep their
            // rows. Each entry's outcome feeds the health tracker alone.
            let mut replies = replies.into_iter();
            let mut still = Vec::new();
            for &slot in &pending {
                match replies.next() {
                    Some(Ok(logits)) => {
                        self.health.record_success();
                        out[slot] = Some(Ok(Some(logits)));
                    }
                    Some(Err(e)) => {
                        if tries_left == 0 {
                            self.health.record_failure(FaultKind::Transient);
                            out[slot] = Some(Err(e.context(ModelFault {
                                kind: FaultKind::Transient,
                                model: self.meta.name.clone(),
                            })));
                        } else {
                            still.push(slot);
                        }
                    }
                    None => {
                        // Short reply: an engine bug, treated as lost.
                        self.health.record_failure(FaultKind::Lost);
                        out[slot] = Some(Err(self.fault(FaultKind::Lost)));
                    }
                }
            }
            if still.is_empty() {
                break;
            }
            tries_left -= 1;
            for _ in &still {
                self.health.record_retry();
            }
            thread::sleep(backoff);
            backoff = backoff.saturating_mul(2);
            pending = still;
        }
        self.counters.record(start.elapsed());
        // xtask:allow(panic): the retry loop exits only with every entry filled.
        Some(out.into_iter().map(|o| o.expect("every batch entry resolved")).collect())
    }
}

/// Host-side handle to an engine-thread scoring session. Tracks the prefix
/// and caches every logits row received, so `rollback` and row re-reads are
/// channel-free; appends ship only the token suffix and receive only the
/// new rows.
pub struct RemoteSession<'m> {
    model: &'m RemoteModel,
    id: u64,
    tokens: Vec<Token>,
    /// Host-side flat `[len, vocab]` logits cache.
    rows: Vec<f32>,
}

impl ScoringSession for RemoteSession<'_> {
    fn vocab(&self) -> usize {
        self.model.meta.vocab
    }

    fn len(&self) -> usize {
        self.tokens.len()
    }

    fn tokens(&self) -> &[Token] {
        &self.tokens
    }

    fn append(&mut self, suffix: &[Token]) -> Result<()> {
        if suffix.is_empty() {
            return Ok(());
        }
        let start = Instant::now();
        // One buffer allocation up front; retry attempts clone the Arc,
        // not the tokens. Retry-safe: the engine truncates its prefix back
        // before sending an error reply, so a retried append re-applies
        // cleanly.
        let tokens: Arc<[Token]> = Arc::from(suffix);
        let logits = self.model.call(|| {
            let (reply, rx) = mpsc::channel();
            self.model.send(Req::SessionAppend {
                session: self.id,
                tokens: tokens.clone(),
                reply,
            })?;
            self.model.recv(&rx)
        })?;
        self.rows.extend_from_slice(logits.data());
        self.tokens.extend_from_slice(suffix);
        self.model.counters.record(start.elapsed());
        Ok(())
    }

    fn rollback(&mut self, to_len: usize) -> Result<()> {
        anyhow::ensure!(
            to_len <= self.tokens.len(),
            "rollback to {to_len} past session length {}",
            self.tokens.len()
        );
        if to_len == self.tokens.len() {
            return Ok(());
        }
        self.model.call(|| {
            let (reply, rx) = mpsc::channel();
            self.model.send(Req::SessionRollback { session: self.id, to_len, reply })?;
            self.model.recv(&rx)
        })?;
        self.tokens.truncate(to_len);
        self.rows.truncate(to_len * self.model.meta.vocab);
        Ok(())
    }

    fn row(&self, pos: usize) -> &[f32] {
        let vocab = self.model.meta.vocab;
        assert!(pos < self.tokens.len(), "row {pos} out of range {}", self.tokens.len());
        &self.rows[pos * vocab..(pos + 1) * vocab]
    }

    fn batch_handle(&self) -> Option<u64> {
        Some(self.id)
    }

    fn absorb_batched(&mut self, suffix: &[Token], rows: Option<Logits>) -> Result<()> {
        if suffix.is_empty() {
            return Ok(());
        }
        // The engine ships the suffix rows in the batch reply; absorb them
        // with one bulk copy of the flat buffer.
        let logits = rows.context("remote session needs shipped logits rows")?;
        anyhow::ensure!(
            logits.seq() == suffix.len() && logits.vocab() == self.model.meta.vocab,
            "batched reply shape mismatch: got [{}, {}], want [{}, {}]",
            logits.seq(),
            logits.vocab(),
            suffix.len(),
            self.model.meta.vocab,
        );
        self.rows.extend_from_slice(logits.data());
        self.tokens.extend_from_slice(suffix);
        Ok(())
    }
}

impl Drop for RemoteSession<'_> {
    fn drop(&mut self) {
        let _ = self.model.send(Req::SessionClose { session: self.id });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "fake".into(),
            n_layers: 1,
            d_model: 8,
            n_heads: 1,
            d_ff: 16,
            vocab: 4,
            seq_len: 32,
            param_count: 100,
            flops_per_forward: 1000,
        }
    }

    fn remote(tx: mpsc::Sender<Req>, policy: CallPolicy) -> RemoteModel {
        RemoteModel {
            idx: 0,
            meta: meta(),
            tx: Mutex::new(tx),
            counters: ModelCounters::default(),
            policy,
            health: Arc::new(HealthTracker::default()),
        }
    }

    #[test]
    fn hung_engine_call_hits_deadline() {
        let (tx, rx) = mpsc::channel::<Req>();
        // A fake engine that accepts requests but never replies — holding
        // the reply senders alive so the receiver sees a hang, not a
        // disconnect.
        let hold = std::thread::spawn(move || {
            let mut held = Vec::new();
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Forward { reply, .. } => held.push(reply),
                    Req::Shutdown => break,
                    _ => {}
                }
            }
        });
        let m = remote(
            tx.clone(),
            CallPolicy {
                deadline: Duration::from_millis(25),
                retries: 0,
                backoff: Duration::from_millis(1),
            },
        );
        let start = Instant::now();
        let err = m.forward(&[1, 2]).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(5), "must not block forever");
        let fault = err.downcast_ref::<ModelFault>().expect("typed fault");
        assert_eq!(fault.kind, FaultKind::Timeout);
        assert_eq!(m.health.timeouts(), 1);
        let _ = tx.send(Req::Shutdown);
        let _ = hold.join();
    }

    #[test]
    fn dead_engine_reports_lost() {
        let (tx, rx) = mpsc::channel::<Req>();
        drop(rx); // engine thread gone before the first call
        let m = remote(tx, CallPolicy::default());
        let err = m.forward(&[1]).unwrap_err();
        assert_eq!(err.downcast_ref::<ModelFault>().unwrap().kind, FaultKind::Lost);
        assert_eq!(m.health.errors(), 1);
    }

    #[test]
    fn transient_error_replies_are_retried() {
        let (tx, rx) = mpsc::channel::<Req>();
        let engine = std::thread::spawn(move || {
            let mut n = 0u32;
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Forward { tokens, reply, .. } => {
                        n += 1;
                        let _ = if n <= 2 {
                            reply.send(Err(anyhow::anyhow!("flaky")))
                        } else {
                            let vocab = 4;
                            reply.send(Ok(Logits::new(
                                vec![0.0; tokens.len() * vocab],
                                tokens.len(),
                                vocab,
                            )))
                        };
                    }
                    Req::Shutdown => break,
                    _ => {}
                }
            }
        });
        let m = remote(
            tx.clone(),
            CallPolicy {
                deadline: Duration::from_secs(5),
                retries: 2,
                backoff: Duration::from_millis(1),
            },
        );
        let out = m.forward(&[1, 2]).expect("third attempt succeeds");
        assert_eq!(out.seq(), 2);
        assert_eq!(m.health.retries(), 2);
        assert_eq!(m.health.errors(), 0, "a retried success is not a failure");
        assert!(m.healthy());
        let _ = tx.send(Req::Shutdown);
        let _ = engine.join();
    }

    #[test]
    fn retries_exhausted_is_transient_failure() {
        let (tx, rx) = mpsc::channel::<Req>();
        let engine = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Forward { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("always broken")));
                    }
                    Req::Shutdown => break,
                    _ => {}
                }
            }
        });
        let m = remote(
            tx.clone(),
            CallPolicy {
                deadline: Duration::from_secs(5),
                retries: 1,
                backoff: Duration::from_millis(1),
            },
        );
        let err = m.forward(&[1]).unwrap_err();
        assert_eq!(err.downcast_ref::<ModelFault>().unwrap().kind, FaultKind::Transient);
        assert_eq!(m.health.retries(), 1);
        assert_eq!(m.health.errors(), 1);
        let _ = tx.send(Req::Shutdown);
        let _ = engine.join();
    }
}
