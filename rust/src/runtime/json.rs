//! Minimal JSON parser for the artifact manifest.
//!
//! The offline crate set has no `serde_json`, and the manifest schema is
//! small and fully under our control (written by `python/compile/aot.py`),
//! so a ~200-line recursive-descent parser is the right tool.  Supports the
//! full JSON grammar except `\uXXXX` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { pos: 0, msg: format!("missing key {key:?}") })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    /// Compact JSON serialization (used by metrics dumps and tests).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble multi-byte UTF-8 (input is valid &str).
                    let start = self.pos - 1;
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..start + len]).map_err(
                        |_| self.err("invalid utf-8"),
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn roundtrips_display() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }
}
