//! ModelEngine: one AOT-compiled chain member, executing on the PJRT CPU
//! client with device-resident weights.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** -> `HloModuleProto`
//! -> compile -> `execute_b`. Weights are uploaded once per engine as
//! `PjRtBuffer`s (never per call); the only per-call host->device transfer
//! is the token vector, and the only device->host transfer is the logits.
//!
//! NOTE: `xla::PjRtClient` is `Rc`-based (not `Send`); engines must stay on
//! the thread that created them. [`super::host::EngineHost`] provides a
//! `Send + Sync` proxy for the multi-threaded coordinator.
//!
//! The whole PJRT path is gated behind the `pjrt` cargo feature (the `xla`
//! crate is not in the offline crate set — see Cargo.toml). Without it a
//! stub with the same API is compiled whose loader returns a descriptive
//! error, so `EngineHost::load` fails gracefully and every artifact-free
//! code path (mocks, coordinator, theory) works identically.

#[cfg(feature = "pjrt")]
mod real {
    use std::time::{Duration, Instant};

    use anyhow::{Context, Result};

    use crate::runtime::manifest::{ArgDtype, ModelMeta, RoleSpec};
    use crate::spec::types::{LanguageModel, Logits, ModelCounters, Token};

    /// A PJRT client shared by every engine on this thread.
    pub struct Client {
        inner: xla::PjRtClient,
    }

    impl Client {
        pub fn cpu() -> Result<Self> {
            Ok(Self { inner: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
        }

        pub fn platform(&self) -> String {
            self.inner.platform_name()
        }
    }

    /// One compiled chain member with device-resident weights.
    pub struct ModelEngine {
        meta: ModelMeta,
        role: String,
        exe: xla::PjRtLoadedExecutable,
        /// Weight buffers in executable-argument order (tokens arg excluded).
        weights: Vec<xla::PjRtBuffer>,
        client: xla::PjRtClient,
        counters: ModelCounters,
    }

    impl ModelEngine {
        /// Load + compile one role from the artifacts directory.
        pub fn load(client: &Client, role: &RoleSpec) -> Result<Self> {
            let proto = xla::HloModuleProto::from_text_file(
                role.hlo_path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", role.hlo_path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .inner
                .compile(&comp)
                .with_context(|| {
                    format!("compiling {}/{}", role.hlo_path.display(), role.role)
                })?;

            let blob = std::fs::read(&role.params_path)
                .with_context(|| format!("reading weights {:?}", role.params_path))?;
            let mut weights = Vec::with_capacity(role.args.len());
            for arg in &role.args {
                let end = arg.offset + arg.nbytes;
                anyhow::ensure!(end <= blob.len(), "weights blob truncated at {}", arg.name);
                let bytes = &blob[arg.offset..end];
                let expected: usize = arg.shape.iter().product::<usize>() * arg.dtype.size();
                anyhow::ensure!(
                    expected == arg.nbytes,
                    "arg {}: shape {:?} x {} != {} bytes",
                    arg.name,
                    arg.shape,
                    arg.dtype.size(),
                    arg.nbytes
                );
                // NOTE: not `buffer_from_host_raw_bytes` — xla 0.1.6 passes the
                // *ElementType* discriminant where the C API expects
                // *PrimitiveType* (off by one for F32), silently mistyping the
                // buffer. The typed `buffer_from_host_buffer` uses the correct
                // mapping.
                let buf = match arg.dtype {
                    ArgDtype::F32 => {
                        let data: Vec<f32> = bytes
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        client.inner.buffer_from_host_buffer::<f32>(&data, &arg.shape, None)
                    }
                    ArgDtype::S32 => {
                        let data: Vec<i32> = bytes
                            .chunks_exact(4)
                            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        client.inner.buffer_from_host_buffer::<i32>(&data, &arg.shape, None)
                    }
                    ArgDtype::S8 => {
                        let data: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
                        client.inner.buffer_from_host_buffer::<i8>(&data, &arg.shape, None)
                    }
                }
                .with_context(|| format!("uploading {}", arg.name))?;
                weights.push(buf);
            }

            Ok(Self {
                meta: role.meta.clone(),
                role: role.role.clone(),
                exe,
                weights,
                client: client.inner.clone(),
                counters: ModelCounters::default(),
            })
        }

        pub fn meta(&self) -> &ModelMeta {
            &self.meta
        }

        pub fn role(&self) -> &str {
            &self.role
        }

        /// Execute one forward pass: tokens (padded to seq_len) -> [S, V] logits.
        fn execute(&self, tokens: &[Token]) -> Result<Vec<f32>> {
            let s = self.meta.seq_len;
            anyhow::ensure!(tokens.len() <= s, "context {} exceeds seq_len {s}", tokens.len());
            // Causal masking makes rows < tokens.len() independent of padding.
            let mut padded = vec![0i32; s];
            padded[..tokens.len()].copy_from_slice(tokens);
            let tok_buf = self
                .client
                .buffer_from_host_buffer::<i32>(&padded, &[s], None)
                .context("uploading tokens")?;

            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
            args.push(&tok_buf);
            args.extend(self.weights.iter());

            let result = self.exe.execute_b(&args).context("execute")?;
            let lit = result[0][0].to_literal_sync().context("fetching logits")?;
            let out = lit.to_tuple1().context("unwrapping 1-tuple")?;
            let data = out.to_vec::<f32>().context("logits to host")?;
            anyhow::ensure!(
                data.len() == s * self.meta.vocab,
                "unexpected logits size {} != {}x{}",
                data.len(),
                s,
                self.meta.vocab
            );
            Ok(data)
        }

        /// Score a whole batch of session prefixes in one engine visit —
        /// the device half of `SessionAppendBatch`. The compiled HLO still
        /// has no batch dimension (a `[B, S]` entry point is tracked on
        /// the ROADMAP next to device-side KV caching; see the batched
        /// stub in `python/compile/aot.py`), so the stacked prefixes
        /// execute back-to-back under **one** counters bracket: today's
        /// win is one channel round-trip and one timed call per
        /// (model, tick) instead of per request.
        pub fn forward_batch(&self, prefixes: &[&[Token]]) -> Result<Vec<Logits>> {
            let start = Instant::now();
            let vocab = self.meta.vocab;
            let mut out = Vec::with_capacity(prefixes.len());
            for tokens in prefixes {
                let data = self.execute(tokens)?;
                out.push(Logits::new(data[..tokens.len() * vocab].to_vec(), tokens.len(), vocab));
            }
            self.counters.record(start.elapsed());
            Ok(out)
        }
    }

    impl LanguageModel for ModelEngine {
        fn name(&self) -> &str {
            &self.meta.name
        }

        fn seq_len(&self) -> usize {
            self.meta.seq_len
        }

        fn vocab(&self) -> usize {
            self.meta.vocab
        }

        fn forward(&self, tokens: &[Token]) -> Result<Logits> {
            let start = Instant::now();
            let data = self.execute(tokens)?;
            self.counters.record(start.elapsed());
            // Only rows < tokens.len() are meaningful; expose exactly those.
            let vocab = self.meta.vocab;
            let rows = tokens.len();
            Ok(Logits::new(data[..rows * vocab].to_vec(), rows, vocab))
        }

        fn calls(&self) -> u64 {
            self.counters.calls()
        }

        fn total_time(&self) -> Duration {
            self.counters.total_time()
        }

        fn reset_counters(&self) {
            self.counters.reset();
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::time::Duration;

    use anyhow::Result;

    use crate::runtime::manifest::{ModelMeta, RoleSpec};
    use crate::spec::types::{LanguageModel, Logits, Token};

    const DISABLED: &str = "polyspec was built without the `pjrt` feature; \
        rebuild with `--features pjrt` (and the vendored `xla` crate, see \
        Cargo.toml) to execute AOT artifacts";

    /// Placeholder PJRT client; [`Client::cpu`] always fails, so
    /// `EngineHost::load` reports a clear error instead of linking PJRT.
    pub struct Client {
        _priv: (),
    }

    impl Client {
        pub fn cpu() -> Result<Self> {
            anyhow::bail!(DISABLED)
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }
    }

    /// API-compatible stand-in for the PJRT engine; never constructible.
    pub struct ModelEngine {
        meta: ModelMeta,
        role: String,
    }

    impl ModelEngine {
        pub fn load(_client: &Client, _role: &RoleSpec) -> Result<Self> {
            anyhow::bail!(DISABLED)
        }

        pub fn meta(&self) -> &ModelMeta {
            &self.meta
        }

        pub fn role(&self) -> &str {
            &self.role
        }

        pub fn forward_batch(&self, _prefixes: &[&[Token]]) -> Result<Vec<Logits>> {
            anyhow::bail!(DISABLED)
        }
    }

    impl LanguageModel for ModelEngine {
        fn name(&self) -> &str {
            &self.meta.name
        }

        fn seq_len(&self) -> usize {
            self.meta.seq_len
        }

        fn vocab(&self) -> usize {
            self.meta.vocab
        }

        fn forward(&self, _tokens: &[Token]) -> Result<Logits> {
            anyhow::bail!(DISABLED)
        }

        fn calls(&self) -> u64 {
            0
        }

        fn total_time(&self) -> Duration {
            Duration::ZERO
        }

        fn reset_counters(&self) {}
    }
}

#[cfg(feature = "pjrt")]
pub use real::{Client, ModelEngine};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Client, ModelEngine};
