//! ModelEngine: one AOT-compiled chain member, executing on the PJRT CPU
//! client with device-resident weights and a device-resident KV cache pool.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** -> `HloModuleProto`
//! -> compile -> `execute_b`. Weights are uploaded once per engine as
//! `PjRtBuffer`s (never per call); the only per-call host->device transfer
//! is the token vector, and the only device->host transfer is the logits.
//!
//! # Executable triplet (prefill / decode-step / stacked)
//!
//! A role exported with `--batched N` loads up to four executables:
//!
//! * `exe` — stateless `f(tokens [S]) -> (logits [S, V],)`; the fallback
//!   that always exists.
//! * `batched` — legacy stacked `f(tokens [B, S]) -> (logits [B, S, V],)`;
//!   still O(prefix) per row, used so a stateless `forward_batch` is one
//!   submission instead of a per-row `execute` loop.
//! * `prefill` — `f(tokens [S], slot, k_pool, v_pool, *w) -> (logits,
//!   k_pool', v_pool')`: full-context score that also writes the session's
//!   K/V rows into one slot of the device cache pool.
//! * `decode` — `f(suffixes [B, W], prefix_lens [B], k_pool, v_pool, *w)
//!   -> (logits [B, W, V], k_pool', v_pool')`: one **O(suffix)** decode
//!   step over every pool slot at once. This is what makes
//!   `SessionAppend` cost scale with the suffix, not the prefix — the
//!   `T_i` Lemma 3.1's cost model prices chains by — and it is the device
//!   half of the scheduler's coalesced `SessionAppendBatch`: the batch
//!   dimension rides on *cache pages* (pool slots shaped on the paged-KV
//!   block size), not re-stacked token prefixes.
//!
//! # Cache pool contract
//!
//! The pool holds `B` slots of `[L, NB, BS, H, dh]` K/V rows (one
//! `coordinator::paged` block per `BS` tokens). Per slot the engine tracks
//! `(used, len, valid)`: rows `< len` are authoritative, rows `>= len` are
//! garbage-but-finite and never attended (the decode HLO masks position
//! `j` for suffix row `d` unless `j <= prefix_len + d`). Rollback is an
//! O(1) host-side length decrement; the stale device rows are overwritten
//! by the next decode at that position. Appends longer than the window
//! loop window-sized chunks; near `seq_len` a chunk is *end-aligned*
//! (re-feeding a few already-cached tokens, whose recomputed K/V rows are
//! bit-identical because the computation is deterministic) so
//! `dynamic_update_slice`'s start-index clamping can never corrupt valid
//! rows. Idle slots ride every batched call as dummies writing into their
//! own garbage region; an idle slot whose garbage region is narrower than
//! the window is invalidated instead and repaired by re-prefill on its
//! next append.
//!
//! Updated pool buffers replace the engine's handles after every call
//! (no donation/aliasing yet — xla 0.1.6 exposes none; and when the
//! result arrives as one tuple literal rather than untupled leaf buffers,
//! the pools take a host round-trip per call — both are loader
//! limitations, not contract changes).
//!
//! NOTE: `xla::PjRtClient` is `Rc`-based (not `Send`); engines must stay on
//! the thread that created them. [`super::host::EngineHost`] provides a
//! `Send + Sync` proxy for the multi-threaded coordinator.
//!
//! The whole PJRT path is gated behind the `pjrt` cargo feature (the `xla`
//! crate is not in the offline crate set — see Cargo.toml). Without it a
//! stub with the same API is compiled whose loader returns a descriptive
//! error, so `EngineHost::load` fails gracefully and every artifact-free
//! code path (mocks, coordinator, theory) works identically.

#[cfg(feature = "pjrt")]
mod real {
    use std::cell::RefCell;
    use std::time::{Duration, Instant};

    use anyhow::{Context, Result};

    use crate::runtime::manifest::{ArgDtype, ModelMeta, RoleSpec};
    use crate::spec::types::{LanguageModel, Logits, ModelCounters, Token};

    /// A PJRT client shared by every engine on this thread.
    pub struct Client {
        inner: xla::PjRtClient,
    }

    impl Client {
        pub fn cpu() -> Result<Self> {
            Ok(Self { inner: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
        }

        pub fn platform(&self) -> String {
            self.inner.platform_name()
        }
    }

    /// Host-side view of one pool slot (see module doc, "Cache pool
    /// contract"). Device rows `< len` are authoritative iff `valid`.
    #[derive(Clone, Copy, Default)]
    struct Slot {
        used: bool,
        len: usize,
        valid: bool,
    }

    /// Device-resident K/V cache pool + the prefill/decode executables
    /// that read and write it.
    struct CachePool {
        prefill_exe: xla::PjRtLoadedExecutable,
        decode_exe: xla::PjRtLoadedExecutable,
        k: xla::PjRtBuffer,
        v: xla::PjRtBuffer,
        /// `[B, L, NB, BS, H, dh]` — kept for the tuple-literal re-upload
        /// fallback in `split_cached_result`.
        shape: Vec<usize>,
        batch: usize,
        window: usize,
        slots: Vec<Slot>,
    }

    /// One compiled chain member with device-resident weights.
    pub struct ModelEngine {
        meta: ModelMeta,
        role: String,
        exe: xla::PjRtLoadedExecutable,
        /// Legacy stacked `[B, S]` entry (batch size, executable).
        batched: Option<(usize, xla::PjRtLoadedExecutable)>,
        /// KV-cached incremental state; `RefCell` because the engine is
        /// thread-pinned (see module NOTE) and `LanguageModel` takes `&self`.
        pool: Option<RefCell<CachePool>>,
        /// Weight buffers in executable-argument order (tokens arg excluded).
        weights: Vec<xla::PjRtBuffer>,
        client: xla::PjRtClient,
        counters: ModelCounters,
    }

    fn compile_hlo_text(
        client: &xla::PjRtClient,
        path: &std::path::Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {}", path.display()))
    }

    impl ModelEngine {
        /// Load + compile one role from the artifacts directory, including
        /// the batched / incremental executables when the manifest has them.
        pub fn load(client: &Client, role: &RoleSpec) -> Result<Self> {
            let exe = compile_hlo_text(&client.inner, &role.hlo_path)
                .with_context(|| format!("role {}", role.role))?;

            let batched = match &role.batched {
                Some(b) => Some((b.batch, compile_hlo_text(&client.inner, &b.hlo_path)?)),
                None => None,
            };
            let pool = match &role.incremental {
                Some(inc) => {
                    let c = &inc.cache;
                    anyhow::ensure!(
                        c.blocks * c.block_size == role.meta.seq_len,
                        "cache {}x{} blocks != seq_len {}",
                        c.blocks,
                        c.block_size,
                        role.meta.seq_len
                    );
                    anyhow::ensure!(
                        inc.window >= 1 && inc.window <= role.meta.seq_len,
                        "decode window {} outside [1, seq_len {}]",
                        inc.window,
                        role.meta.seq_len
                    );
                    let shape =
                        vec![inc.batch, c.n_layers, c.blocks, c.block_size, c.n_heads, c.d_head];
                    // Zero-filled pools: every slot starts all-garbage
                    // (len 0), which the validity contract already covers.
                    let zeros = vec![0f32; inc.batch * c.slot_elems()];
                    let k = client
                        .inner
                        .buffer_from_host_buffer::<f32>(&zeros, &shape, None)
                        .context("allocating K pool")?;
                    let v = client
                        .inner
                        .buffer_from_host_buffer::<f32>(&zeros, &shape, None)
                        .context("allocating V pool")?;
                    Some(RefCell::new(CachePool {
                        prefill_exe: compile_hlo_text(&client.inner, &inc.prefill_path)?,
                        decode_exe: compile_hlo_text(&client.inner, &inc.decode_path)?,
                        k,
                        v,
                        shape,
                        batch: inc.batch,
                        window: inc.window,
                        slots: vec![Slot::default(); inc.batch],
                    }))
                }
                None => None,
            };

            let blob = std::fs::read(&role.params_path)
                .with_context(|| format!("reading weights {:?}", role.params_path))?;
            let mut weights = Vec::with_capacity(role.args.len());
            for arg in &role.args {
                let end = arg.offset + arg.nbytes;
                anyhow::ensure!(end <= blob.len(), "weights blob truncated at {}", arg.name);
                let bytes = &blob[arg.offset..end];
                let expected: usize = arg.shape.iter().product::<usize>() * arg.dtype.size();
                anyhow::ensure!(
                    expected == arg.nbytes,
                    "arg {}: shape {:?} x {} != {} bytes",
                    arg.name,
                    arg.shape,
                    arg.dtype.size(),
                    arg.nbytes
                );
                // NOTE: not `buffer_from_host_raw_bytes` — xla 0.1.6 passes the
                // *ElementType* discriminant where the C API expects
                // *PrimitiveType* (off by one for F32), silently mistyping the
                // buffer. The typed `buffer_from_host_buffer` uses the correct
                // mapping.
                let buf = match arg.dtype {
                    ArgDtype::F32 => {
                        let data: Vec<f32> = bytes
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        client.inner.buffer_from_host_buffer::<f32>(&data, &arg.shape, None)
                    }
                    ArgDtype::S32 => {
                        let data: Vec<i32> = bytes
                            .chunks_exact(4)
                            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        client.inner.buffer_from_host_buffer::<i32>(&data, &arg.shape, None)
                    }
                    ArgDtype::S8 => {
                        let data: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
                        client.inner.buffer_from_host_buffer::<i8>(&data, &arg.shape, None)
                    }
                }
                .with_context(|| format!("uploading {}", arg.name))?;
                weights.push(buf);
            }

            Ok(Self {
                meta: role.meta.clone(),
                role: role.role.clone(),
                exe,
                batched,
                pool,
                weights,
                client: client.inner.clone(),
                counters: ModelCounters::default(),
            })
        }

        pub fn meta(&self) -> &ModelMeta {
            &self.meta
        }

        pub fn role(&self) -> &str {
            &self.role
        }

        /// Execute one forward pass: tokens (padded to seq_len) -> [S, V] logits.
        fn execute(&self, tokens: &[Token]) -> Result<Vec<f32>> {
            let s = self.meta.seq_len;
            anyhow::ensure!(tokens.len() <= s, "context {} exceeds seq_len {s}", tokens.len());
            // Causal masking makes rows < tokens.len() independent of padding.
            let mut padded = vec![0i32; s];
            padded[..tokens.len()].copy_from_slice(tokens);
            let tok_buf = self
                .client
                .buffer_from_host_buffer::<i32>(&padded, &[s], None)
                .context("uploading tokens")?;

            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
            args.push(&tok_buf);
            args.extend(self.weights.iter());

            let result = self.exe.execute_b(&args).context("execute")?;
            let lit = result[0][0].to_literal_sync().context("fetching logits")?;
            let out = lit.to_tuple1().context("unwrapping 1-tuple")?;
            let data = out.to_vec::<f32>().context("logits to host")?;
            anyhow::ensure!(
                data.len() == s * self.meta.vocab,
                "unexpected logits size {} != {}x{}",
                data.len(),
                s,
                self.meta.vocab
            );
            Ok(data)
        }

        /// Score a whole batch of *stateless* session prefixes in one
        /// engine visit. With a `--batched N` manifest entry the stacked
        /// `[B, S]` executable runs each N-row chunk as **one** device
        /// submission (unused trailing rows stay zero-padded and their
        /// logits are discarded); without it, the rows execute
        /// back-to-back under one counters bracket. Cached sessions take
        /// [`Self::decode_batch`] instead.
        pub fn forward_batch(&self, prefixes: &[&[Token]]) -> Result<Vec<Logits>> {
            let start = Instant::now();
            let vocab = self.meta.vocab;
            let s = self.meta.seq_len;
            let mut out = Vec::with_capacity(prefixes.len());
            match &self.batched {
                Some((b, exe)) => {
                    for chunk in prefixes.chunks(*b) {
                        let mut stacked = vec![0i32; b * s];
                        for (i, tokens) in chunk.iter().enumerate() {
                            anyhow::ensure!(
                                tokens.len() <= s,
                                "context {} exceeds seq_len {s}",
                                tokens.len()
                            );
                            stacked[i * s..i * s + tokens.len()].copy_from_slice(tokens);
                        }
                        let buf = self
                            .client
                            .buffer_from_host_buffer::<i32>(&stacked, &[*b, s], None)
                            .context("uploading stacked tokens")?;
                        let mut args: Vec<&xla::PjRtBuffer> =
                            Vec::with_capacity(1 + self.weights.len());
                        args.push(&buf);
                        args.extend(self.weights.iter());
                        let result = exe.execute_b(&args).context("batched execute")?;
                        let lit = result[0][0].to_literal_sync().context("fetching logits")?;
                        let data = lit
                            .to_tuple1()
                            .context("unwrapping 1-tuple")?
                            .to_vec::<f32>()
                            .context("logits to host")?;
                        anyhow::ensure!(
                            data.len() == b * s * vocab,
                            "unexpected batched logits size {} != {}x{}x{}",
                            data.len(),
                            b,
                            s,
                            vocab
                        );
                        for (i, tokens) in chunk.iter().enumerate() {
                            let row0 = i * s * vocab;
                            out.push(Logits::new(
                                data[row0..row0 + tokens.len() * vocab].to_vec(),
                                tokens.len(),
                                vocab,
                            ));
                        }
                    }
                }
                None => {
                    for tokens in prefixes {
                        let data = self.execute(tokens)?;
                        out.push(Logits::new(
                            data[..tokens.len() * vocab].to_vec(),
                            tokens.len(),
                            vocab,
                        ));
                    }
                }
            }
            self.counters.record(start.elapsed());
            Ok(out)
        }

        // ---- KV-cached incremental path ---------------------------------

        /// Claim a free pool slot for a new session. `None` when the role
        /// has no incremental export or every slot is taken — the caller
        /// falls back to stateless scoring.
        pub fn cache_alloc(&self) -> Option<usize> {
            let pool = self.pool.as_ref()?;
            let mut p = pool.borrow_mut();
            let idx = p.slots.iter().position(|s| !s.used)?;
            p.slots[idx] = Slot { used: true, len: 0, valid: false };
            Some(idx)
        }

        /// Return a slot to the pool. Device rows are left as-is: a freed
        /// slot is all-garbage by contract (len 0).
        pub fn cache_free(&self, slot: usize) {
            if let Some(pool) = &self.pool {
                let mut p = pool.borrow_mut();
                if slot < p.slots.len() {
                    p.slots[slot] = Slot::default();
                }
            }
        }

        /// O(1) rollback: drop cached rows past `to_len`. The stale device
        /// rows are overwritten by the next decode at that position.
        pub fn cache_rollback(&self, slot: usize, to_len: usize) {
            if let Some(pool) = &self.pool {
                let mut p = pool.borrow_mut();
                if let Some(s) = p.slots.get_mut(slot) {
                    s.len = s.len.min(to_len);
                }
            }
        }

        /// True iff `decode_batch` may serve an append starting at `from`
        /// on this slot: the cache is valid and positioned exactly there.
        pub fn can_decode(&self, slot: usize, from: usize) -> bool {
            match &self.pool {
                Some(pool) => {
                    let p = pool.borrow();
                    p.slots.get(slot).is_some_and(|s| s.used && s.valid && s.len == from)
                }
                None => false,
            }
        }

        /// Split a 3-output `(logits, k_pool', v_pool')` execute result.
        ///
        /// xla 0.1.6 API note: with `return_tuple=True` modules, PJRT
        /// clients either *untuple* the result into one `PjRtBuffer` per
        /// leaf (preferred — the pools never leave the device) or hand
        /// back a single buffer holding the tuple literal. Handle both;
        /// the latter costs a pool host round-trip per call (module doc).
        fn split_cached_result(
            &self,
            result: Vec<Vec<xla::PjRtBuffer>>,
            pool_shape: &[usize],
        ) -> Result<(Vec<f32>, xla::PjRtBuffer, xla::PjRtBuffer)> {
            let mut bufs = result.into_iter().next().context("empty execute result")?;
            match bufs.len() {
                3 => {
                    let v = bufs.pop().expect("v pool");
                    let k = bufs.pop().expect("k pool");
                    let logits = bufs[0]
                        .to_literal_sync()
                        .context("fetching logits")?
                        .to_vec::<f32>()
                        .context("logits to host")?;
                    Ok((logits, k, v))
                }
                1 => {
                    let lit = bufs[0].to_literal_sync().context("fetching result tuple")?;
                    let parts = lit.to_tuple().context("decomposing 3-tuple")?;
                    anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
                    let logits = parts[0].to_vec::<f32>().context("logits to host")?;
                    let k_host = parts[1].to_vec::<f32>().context("k pool to host")?;
                    let v_host = parts[2].to_vec::<f32>().context("v pool to host")?;
                    let k = self
                        .client
                        .buffer_from_host_buffer::<f32>(&k_host, pool_shape, None)
                        .context("re-uploading K pool")?;
                    let v = self
                        .client
                        .buffer_from_host_buffer::<f32>(&v_host, pool_shape, None)
                        .context("re-uploading V pool")?;
                    Ok((logits, k, v))
                }
                n => anyhow::bail!("unexpected execute output arity {n}"),
            }
        }

        /// Full-context score + cache write: positions the slot's cache at
        /// `tokens.len()`. Used at first append and as *repair* after the
        /// cache went stale (rollback past a window boundary, capacity
        /// invalidation). O(prefix), like the stateless forward.
        pub fn prefill(&self, slot: usize, tokens: &[Token]) -> Result<Logits> {
            let pool = self.pool.as_ref().context("no incremental cache pool loaded")?;
            let start = Instant::now();
            let s = self.meta.seq_len;
            let vocab = self.meta.vocab;
            anyhow::ensure!(tokens.len() <= s, "context {} exceeds seq_len {s}", tokens.len());
            let mut p = pool.borrow_mut();
            anyhow::ensure!(
                p.slots.get(slot).is_some_and(|sl| sl.used),
                "prefill into unallocated slot {slot}"
            );
            let mut padded = vec![0i32; s];
            padded[..tokens.len()].copy_from_slice(tokens);
            let tok_buf = self
                .client
                .buffer_from_host_buffer::<i32>(&padded, &[s], None)
                .context("uploading tokens")?;
            let slot_buf = self
                .client
                .buffer_from_host_buffer::<i32>(&[slot as i32], &[], None)
                .context("uploading slot index")?;
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 + self.weights.len());
            args.push(&tok_buf);
            args.push(&slot_buf);
            args.push(&p.k);
            args.push(&p.v);
            args.extend(self.weights.iter());
            let result = p.prefill_exe.execute_b(&args).context("prefill execute")?;
            let (data, k, v) = self.split_cached_result(result, &p.shape.clone())?;
            anyhow::ensure!(
                data.len() == s * vocab,
                "unexpected prefill logits size {} != {s}x{vocab}",
                data.len()
            );
            p.k = k;
            p.v = v;
            p.slots[slot] = Slot { used: true, len: tokens.len(), valid: true };
            self.counters.record(start.elapsed());
            Ok(Logits::new(data[..tokens.len() * vocab].to_vec(), tokens.len(), vocab))
        }

        /// One **O(suffix)** batched decode: score each row's suffix
        /// (`tokens[from..]`, with `tokens` the full prefix for end-aligned
        /// re-feeds) against its slot's cache, all rows in one device
        /// submission per window chunk. Every row must satisfy
        /// [`Self::can_decode`]`(slot, from)`; on success each slot's
        /// cache is positioned at `tokens.len()`.
        ///
        /// Per-call cost is O(chunks · batch · window · seq_len) attention
        /// — independent of prefix length (a solo append pays the dummy
        /// rows of idle slots: the padding tradeoff for one fixed-shape
        /// executable).
        pub fn decode_batch(&self, rows: &[(usize, &[Token], usize)]) -> Result<Vec<Logits>> {
            let pool = self.pool.as_ref().context("no incremental cache pool loaded")?;
            let start = Instant::now();
            let s = self.meta.seq_len;
            let vocab = self.meta.vocab;
            let mut p = pool.borrow_mut();
            let (b, w) = (p.batch, p.window);
            anyhow::ensure!(!rows.is_empty(), "empty decode batch");
            let mut part: Vec<Option<usize>> = vec![None; b];
            for (i, &(slot, tokens, from)) in rows.iter().enumerate() {
                anyhow::ensure!(slot < b, "slot {slot} out of pool range {b}");
                anyhow::ensure!(part[slot].is_none(), "slot {slot} appears twice in batch");
                let sl = &p.slots[slot];
                anyhow::ensure!(
                    sl.used && sl.valid && sl.len == from,
                    "slot {slot} not positioned for decode at {from} \
                     (used={} valid={} len={})",
                    sl.used,
                    sl.valid,
                    sl.len
                );
                anyhow::ensure!(from < tokens.len(), "empty suffix for slot {slot}");
                anyhow::ensure!(
                    tokens.len() <= s,
                    "context {} exceeds seq_len {s}",
                    tokens.len()
                );
                part[slot] = Some(i);
            }
            let max_suffix = rows.iter().map(|&(_, t, f)| t.len() - f).max().unwrap_or(0);
            let chunks = max_suffix.div_ceil(w);
            let mut out: Vec<Vec<f32>> =
                rows.iter().map(|&(_, t, f)| Vec::with_capacity((t.len() - f) * vocab)).collect();

            for c in 0..chunks {
                let mut suffixes = vec![0i32; b * w];
                let mut lens = vec![0i32; b];
                for slot in 0..b {
                    match part[slot] {
                        Some(i) => {
                            let (_, tokens, from) = rows[i];
                            // This chunk wants rows [pos, pos + w) ∩
                            // [from, total); end-align near capacity so the
                            // write window always fits (re-fed rows
                            // recompute bit-identical K/V).
                            let total = tokens.len();
                            let pos = (from + c * w).min(total);
                            let chunk_start = pos.min(s - w);
                            for (j, tok) in
                                tokens[chunk_start..total.min(chunk_start + w)].iter().enumerate()
                            {
                                suffixes[slot * w + j] = *tok;
                            }
                            lens[slot] = chunk_start as i32;
                        }
                        None if p.slots[slot].used && p.slots[slot].valid => {
                            // Idle slot: dummy rows must land in its own
                            // garbage region. If that region is narrower
                            // than the window, the write would clobber
                            // valid rows — invalidate and let the next
                            // append repair by re-prefill.
                            let len = p.slots[slot].len;
                            if len + w <= s {
                                lens[slot] = len as i32;
                            } else {
                                lens[slot] = (s - w) as i32;
                                p.slots[slot].valid = false;
                            }
                        }
                        None => {
                            // Unused/invalid slot: the whole cache is
                            // garbage, any write position is fine.
                            lens[slot] = 0;
                        }
                    }
                }
                let suf_buf = self
                    .client
                    .buffer_from_host_buffer::<i32>(&suffixes, &[b, w], None)
                    .context("uploading suffixes")?;
                let len_buf = self
                    .client
                    .buffer_from_host_buffer::<i32>(&lens, &[b], None)
                    .context("uploading prefix lens")?;
                let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 + self.weights.len());
                args.push(&suf_buf);
                args.push(&len_buf);
                args.push(&p.k);
                args.push(&p.v);
                args.extend(self.weights.iter());
                let result = p.decode_exe.execute_b(&args).context("decode execute")?;
                let (data, k, v) = self.split_cached_result(result, &p.shape.clone())?;
                anyhow::ensure!(
                    data.len() == b * w * vocab,
                    "unexpected decode logits size {} != {b}x{w}x{vocab}",
                    data.len()
                );
                p.k = k;
                p.v = v;
                for (i, &(slot, tokens, from)) in rows.iter().enumerate() {
                    let total = tokens.len();
                    let pos = from + c * w;
                    if pos >= total {
                        continue; // this row finished in an earlier chunk
                    }
                    let chunk_start = pos.min(s - w);
                    let take = w.min(total - pos);
                    let off = pos - chunk_start;
                    let base = (slot * w + off) * vocab;
                    out[i].extend_from_slice(&data[base..base + take * vocab]);
                }
            }
            for &(slot, tokens, _) in rows {
                p.slots[slot].len = tokens.len();
            }
            self.counters.record(start.elapsed());
            Ok(rows
                .iter()
                .zip(out)
                .map(|(&(_, t, f), data)| Logits::new(data, t.len() - f, vocab))
                .collect())
        }
    }

    impl LanguageModel for ModelEngine {
        fn name(&self) -> &str {
            &self.meta.name
        }

        fn seq_len(&self) -> usize {
            self.meta.seq_len
        }

        fn vocab(&self) -> usize {
            self.meta.vocab
        }

        fn forward(&self, tokens: &[Token]) -> Result<Logits> {
            let start = Instant::now();
            let data = self.execute(tokens)?;
            self.counters.record(start.elapsed());
            // Only rows < tokens.len() are meaningful; expose exactly those.
            let vocab = self.meta.vocab;
            let rows = tokens.len();
            Ok(Logits::new(data[..rows * vocab].to_vec(), rows, vocab))
        }

        fn calls(&self) -> u64 {
            self.counters.calls()
        }

        fn total_time(&self) -> Duration {
            self.counters.total_time()
        }

        fn reset_counters(&self) {
            self.counters.reset();
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::time::Duration;

    use anyhow::Result;

    use crate::runtime::manifest::{ModelMeta, RoleSpec};
    use crate::spec::types::{LanguageModel, Logits, Token};

    const DISABLED: &str = "polyspec was built without the `pjrt` feature; \
        rebuild with `--features pjrt` (and the vendored `xla` crate, see \
        Cargo.toml) to execute AOT artifacts";

    /// Placeholder PJRT client; [`Client::cpu`] always fails, so
    /// `EngineHost::load` reports a clear error instead of linking PJRT.
    pub struct Client {
        _priv: (),
    }

    impl Client {
        pub fn cpu() -> Result<Self> {
            anyhow::bail!(DISABLED)
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }
    }

    /// API-compatible stand-in for the PJRT engine; never constructible.
    pub struct ModelEngine {
        meta: ModelMeta,
        role: String,
    }

    impl ModelEngine {
        pub fn load(_client: &Client, _role: &RoleSpec) -> Result<Self> {
            anyhow::bail!(DISABLED)
        }

        pub fn meta(&self) -> &ModelMeta {
            &self.meta
        }

        pub fn role(&self) -> &str {
            &self.role
        }

        pub fn forward_batch(&self, _prefixes: &[&[Token]]) -> Result<Vec<Logits>> {
            anyhow::bail!(DISABLED)
        }

        // KV-cached incremental API, mirrored so `runtime::host` compiles
        // identically without the `pjrt` feature. `cache_alloc` reporting
        // "no pool" routes every session to the stateless path, which then
        // fails with the same DISABLED error as everything else here.
        pub fn cache_alloc(&self) -> Option<usize> {
            None
        }

        pub fn cache_free(&self, _slot: usize) {}

        pub fn cache_rollback(&self, _slot: usize, _to_len: usize) {}

        pub fn can_decode(&self, _slot: usize, _from: usize) -> bool {
            false
        }

        pub fn prefill(&self, _slot: usize, _tokens: &[Token]) -> Result<Logits> {
            anyhow::bail!(DISABLED)
        }

        pub fn decode_batch(&self, _rows: &[(usize, &[Token], usize)]) -> Result<Vec<Logits>> {
            anyhow::bail!(DISABLED)
        }
    }

    impl LanguageModel for ModelEngine {
        fn name(&self) -> &str {
            &self.meta.name
        }

        fn seq_len(&self) -> usize {
            self.meta.seq_len
        }

        fn vocab(&self) -> usize {
            self.meta.vocab
        }

        fn forward(&self, _tokens: &[Token]) -> Result<Logits> {
            anyhow::bail!(DISABLED)
        }

        fn calls(&self) -> u64 {
            0
        }

        fn total_time(&self) -> Duration {
            Duration::ZERO
        }

        fn reset_counters(&self) {}
    }
}

#[cfg(feature = "pjrt")]
pub use real::{Client, ModelEngine};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Client, ModelEngine};
