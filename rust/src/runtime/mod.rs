//! Runtime: load AOT artifacts (HLO text + weights) and execute them on the
//! PJRT CPU client. See `python/compile/aot.py` for the interchange format.

pub mod engine;
pub mod host;
pub mod json;
pub mod manifest;

pub use engine::{Client, ModelEngine};
pub use host::{CallPolicy, EngineHost, RemoteModel, RemoteSession};
pub use manifest::Manifest;
