//! Shared benchmark harness: runs (family x task x method) cells and prints
//! paper-style tables. Used by `benches/*` (one per paper table/figure) and
//! by the `polyspec bench` CLI subcommand.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::EngineHost;
use crate::spec::stats::Welford;
use crate::spec::types::{LanguageModel, SamplingParams, VerifyRule};
use crate::spec::{autoregressive, dualistic, polybasic, PolyConfig};
use crate::workload::tasks::Query;

/// Decoding method under benchmark.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BenchMethod {
    Vanilla,
    /// Dualistic with the early-exit drafter (the EAGLE2-like baseline).
    Eagle { draft_k: usize },
    /// The paper's three-model system.
    Polybasic { draft_k: usize, mu: usize },
}

impl BenchMethod {
    pub fn label(&self) -> &'static str {
        match self {
            BenchMethod::Vanilla => "vanilla",
            BenchMethod::Eagle { .. } => "EAGLE2*",
            BenchMethod::Polybasic { .. } => "Ours",
        }
    }
}

/// Defaults chosen by the perf pass (EXPERIMENTS.md §Perf).
pub const DEFAULT_POLY: BenchMethod = BenchMethod::Polybasic { draft_k: 6, mu: 8 };
pub const DEFAULT_EAGLE: BenchMethod = BenchMethod::Eagle { draft_k: 4 };

/// One benchmark cell result.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub wall_s: f64,
    pub tokens: u64,
    pub target_forwards: u64,
    pub accept: Welford,
    /// Per-query acceptance-length samples (fig4 needs the raw values).
    pub accept_samples: Vec<u32>,
}

impl Cell {
    pub fn mu(&self) -> f64 {
        self.accept.mean()
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-12)
    }
}

/// Run one suite of queries under a method against a chain (target first).
pub fn run_cell(
    chain: &[Arc<dyn LanguageModel>],
    queries: &[Query],
    method: BenchMethod,
    rule: VerifyRule,
) -> Result<Cell> {
    let mut cell = Cell::default();
    for (i, q) in queries.iter().enumerate() {
        let sampling = SamplingParams {
            temperature: if rule == VerifyRule::Greedy { 0.0 } else { q.temperature },
            seed: 1000 + i as u64,
            ..Default::default()
        };
        let start = Instant::now();
        let out = match method {
            BenchMethod::Vanilla => {
                autoregressive::generate(chain[0].as_ref(), &q.prompt, q.max_new, &sampling)?
            }
            BenchMethod::Eagle { draft_k } => {
                // xtask:allow(panic): bench chains are fixed, non-empty fixtures.
                let draft = chain.last().unwrap();
                dualistic::generate(
                    chain[0].as_ref(),
                    draft.as_ref(),
                    &q.prompt,
                    &dualistic::DualisticConfig { draft_k, rule, sampling, max_new: q.max_new },
                )?
            }
            BenchMethod::Polybasic { draft_k, mu } => {
                let mut cfg = PolyConfig::for_chain(chain.len(), draft_k, mu, q.max_new);
                cfg.rule = rule;
                cfg.sampling = sampling;
                polybasic::generate(chain, &q.prompt, &cfg)?
            }
        };
        cell.wall_s += start.elapsed().as_secs_f64();
        cell.tokens += out.tokens.len() as u64;
        cell.target_forwards += out.forward_passes[0];
        for &a in &out.accept_lengths {
            cell.accept.push(a as f64);
            cell.accept_samples.push(a);
        }
    }
    Ok(cell)
}

/// Load the standard chain of a family (target / intermediate / draft).
pub fn load_chain(artifacts: &str, family: &str) -> Result<EngineHost> {
    EngineHost::load(artifacts, family, &["target", "intermediate", "draft"])
}

/// Environment-tunable suite sizing (POLYSPEC_QPT / POLYSPEC_QUICK).
pub fn queries_per_task() -> usize {
    if let Ok(v) = std::env::var("POLYSPEC_QPT") {
        return v.parse().unwrap_or(2);
    }
    if std::env::var("POLYSPEC_QUICK").is_ok() {
        1
    } else {
        2
    }
}

pub fn artifacts_dir() -> String {
    std::env::var("POLYSPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Families to bench (env POLYSPEC_FAMILIES=comma list), filtered to those
/// present in the manifest.
pub fn bench_families(default: &[&str]) -> Vec<String> {
    let requested: Vec<String> = std::env::var("POLYSPEC_FAMILIES")
        .map(|v| v.split(',').map(str::to_string).collect())
        .unwrap_or_else(|_| default.iter().map(|s| s.to_string()).collect());
    match crate::runtime::Manifest::load(artifacts_dir()) {
        Ok(m) => requested
            .into_iter()
            .filter(|f| {
                let ok = m.families.contains_key(f);
                if !ok {
                    eprintln!("[bench] skipping {f}: not in manifest (make artifacts ARTIFACT_SET=all)");
                }
                ok
            })
            .collect(),
        Err(e) => {
            eprintln!("[bench] cannot load manifest: {e}");
            vec![]
        }
    }
}

/// Pretty horizontal rule for table output.
pub fn hr(width: usize) -> String {
    "-".repeat(width)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::mock_chain;
    use crate::workload::tasks::{make_query, TaskKind};

    #[test]
    fn cells_run_all_methods_on_mocks() {
        let chain = mock_chain(512, 32, 3);
        let queries: Vec<Query> =
            (0..2).map(|i| make_query(TaskKind::Qa, i, 32)).collect();
        for m in [BenchMethod::Vanilla, DEFAULT_EAGLE, DEFAULT_POLY] {
            let cell = run_cell(&chain, &queries, m, VerifyRule::Speculative).unwrap();
            assert!(cell.tokens > 0, "{m:?}");
            assert!(cell.wall_s > 0.0);
            if m != BenchMethod::Vanilla {
                assert!(cell.mu() >= 1.0);
            }
        }
    }

    #[test]
    fn speculative_beats_vanilla_in_target_forwards() {
        let chain = mock_chain(512, 32, 3);
        let queries: Vec<Query> =
            (0..2).map(|i| make_query(TaskKind::Math, i, 32)).collect();
        let van = run_cell(&chain, &queries, BenchMethod::Vanilla, VerifyRule::Speculative)
            .unwrap();
        let poly = run_cell(&chain, &queries, DEFAULT_POLY, VerifyRule::Speculative).unwrap();
        assert!(poly.target_forwards < van.target_forwards);
    }
}
