//! # polyspec — Polybasic Speculative Decoding
//!
//! A three-layer reproduction of *"Polybasic Speculative Decoding Through a
//! Theoretical Perspective"* (ICML 2025): a rust serving coordinator
//! ([`coordinator`]) driving AOT-compiled JAX/Pallas models ([`runtime`])
//! with the paper's multi-model speculative decoding algorithms and theory
//! ([`spec`]), evaluated on a SpecBench-style workload suite ([`workload`]).

pub mod coordinator;
pub mod harness;
pub mod runtime;
pub mod spec;
pub mod workload;
