//! # polyspec — Polybasic Speculative Decoding
//!
//! A three-layer reproduction of *"Polybasic Speculative Decoding Through a
//! Theoretical Perspective"* (ICML 2025): a rust serving coordinator
//! ([`coordinator`]) driving AOT-compiled JAX/Pallas models ([`runtime`])
//! with the paper's multi-model speculative decoding algorithms and theory
//! ([`spec`]), evaluated on a SpecBench-style workload suite ([`workload`]).
//!
//! The crate is `forbid(unsafe_code)`: the accounting substrate the
//! paper's cost model runs on (`coordinator`) must stay trivially free of
//! memory-safety caveats, and the pjrt path goes through safe wrappers.

#![forbid(unsafe_code)]

pub mod coordinator;
pub mod harness;
pub mod runtime;
pub mod spec;
pub mod sync;
pub mod workload;
