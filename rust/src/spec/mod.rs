//! The paper's contribution: polybasic speculative decoding.
//!
//! * [`types`]   — `LanguageModel` trait, `ScoringSession` incremental
//!   decode API (cached-prefix suffix scoring + rollback), logits,
//!   sampling/verify configs.
//! * [`rng`], [`sampler`], [`verify`] — sampling + verification primitives.
//! * [`task`]    — `DecodeTask`: every decode loop as a resumable state
//!   machine (`step()` = one draft→verify round), the unit the serving
//!   coordinator schedules for continuous batching.
//! * [`autoregressive`], [`dualistic`], [`polybasic`], [`csdraft`] — the
//!   decoding algorithms (vanilla baseline, Leviathan baseline, the paper's
//!   Algorithm 1 generalized to n models, and the CS-Drafting baseline),
//!   each a `DecodeTask` with `generate` as the drive-to-completion wrapper.
//! * [`theory`]  — Lemma 3.1 / Theorem 3.2 / Theorem 3.3 as code.
//! * [`planner`] — theory-driven chain construction from measurements.
//! * [`stats`]   — acceptance/latency aggregation.
//! * [`mock`], [`ngram`] — PJRT-free models for tests and the CS cascade.
//! * [`chaos`]   — deterministic fault injection (`ChaosModel`) for the
//!   fault-tolerance layer's tests.

pub mod autoregressive;
pub mod chaos;
pub mod csdraft;
pub mod dualistic;
pub mod mock;
pub mod ngram;
pub mod planner;
pub mod polybasic;
pub mod rng;
pub mod sampler;
pub mod stats;
pub mod task;
pub mod theory;
pub mod types;
pub mod verify;

pub use chaos::{ChaosModel, Fault};
pub use polybasic::{generate as polybasic_generate, PolyConfig};
pub use task::{model_key, DecodeTask, InflightState, PlannedAppend, ResumeState, StepOutcome};
pub use types::{
    FaultKind, GenerationOutput, HealthConfig, HealthTracker, LanguageModel, ModelFault,
    SamplingParams, ScoringSession, Token, VerifyRule,
};
