//! Core types shared by the speculative-decoding algorithms.
//!
//! The algorithm layer (`spec::*`) depends only on the [`LanguageModel`]
//! trait — never on PJRT — so every algorithm is unit-testable against
//! [`crate::spec::mock::MockModel`] and runs unchanged against the real
//! AOT-compiled engines in `runtime::`.
//!
//! # Incremental scoring sessions
//!
//! [`LanguageModel::forward`] is stateless full-context scoring: every call
//! pays for the whole prefix, so an L-token decode loop is O(L²) in model
//! work. [`ScoringSession`] is the incremental alternative the decode loops
//! use: a session owns a scored prefix, `append` scores only the new
//! suffix, and `rollback` rewinds a speculative rejection instead of
//! recomputing — the cost model Lemma 3.1 assumes (per-call cost `T_i`
//! independent of how the prefix was built).
//!
//! Invariants every session backend must uphold:
//!
//! * **Prefix determinism** — `row(t)` depends only on `tokens()[0..=t]`.
//!   It equals `forward(tokens[..=t]).row(t)` bit-for-bit, however the
//!   prefix was assembled (one append, many appends, or appends interleaved
//!   with rollbacks).
//! * **Rollback exactness** — `rollback(to_len)` restores exactly the state
//!   after the first `to_len` tokens; cached rows for the surviving prefix
//!   are preserved bit-identically, never recomputed.
//! * **Row availability** — after `append`, every position `< len()` is
//!   readable through `row`, not just the freshly appended suffix.
//!
//! Backends: [`StatelessSession`] adapts any `LanguageModel` (full-context
//! re-forward per append, rows cached host-side), `spec::mock` keeps a
//! rolling prefix hash making appends O(suffix · vocab), and
//! `runtime::host` speaks a session protocol to the engine thread with a
//! host-side logits cache.

use std::time::Duration;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::time::Instant;
use crate::sync::{Arc, Mutex};

pub type Token = i32;

/// How a model call failed, as far as the caller can classify it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The call exceeded its deadline. The engine may still be executing
    /// it, so the session state is unknown — never retried.
    Timeout,
    /// The backing engine is gone (thread dead, channel disconnected).
    Lost,
    /// The call failed but the model reported the error cleanly and its
    /// session state is intact — safe to retry.
    Transient,
}

/// A classified model-call failure. Carried in the `anyhow` error chain so
/// the coordinator can map engine faults onto typed client errors without
/// string matching.
#[derive(Debug, Clone)]
pub struct ModelFault {
    pub kind: FaultKind,
    /// Name of the model the call was against.
    pub model: String,
}

impl std::fmt::Display for ModelFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            FaultKind::Timeout => write!(f, "model {} call timed out", self.model),
            FaultKind::Lost => write!(f, "model {} engine lost", self.model),
            FaultKind::Transient => write!(f, "model {} transient failure", self.model),
        }
    }
}

impl std::error::Error for ModelFault {}

/// Circuit-breaker tuning for a [`HealthTracker`].
#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// Consecutive failures that open the breaker.
    pub failure_threshold: u32,
    /// How long an open breaker waits before granting a probe call.
    pub cooldown: Duration,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self { failure_threshold: 3, cooldown: Duration::from_secs(5) }
    }
}

/// Observable circuit-breaker state (for metrics snapshots).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Calls flow normally.
    Closed,
    /// Too many consecutive failures; calls should be skipped.
    Open,
    /// Cooldown elapsed; one probe call is allowed through.
    HalfOpen,
}

impl BreakerState {
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Per-model health: cumulative error/retry/timeout counters plus a
/// consecutive-failure circuit breaker with cooldown-probe reopening.
/// Shared (`Arc`) between the model wrapper that records outcomes and the
/// metrics layer that snapshots them.
#[derive(Debug, Default)]
pub struct HealthTracker {
    errors: AtomicU64,
    retries: AtomicU64,
    timeouts: AtomicU64,
    consecutive: AtomicU64,
    config: HealthConfigCell,
    /// `Some(when)` while the breaker is open; cleared on success.
    open_since: Mutex<Option<Instant>>,
}

/// Interior holder so `HealthTracker` can derive `Default` with a
/// non-zero default config.
#[derive(Debug)]
struct HealthConfigCell(HealthConfig);

impl Default for HealthConfigCell {
    fn default() -> Self {
        Self(HealthConfig::default())
    }
}

impl HealthTracker {
    pub fn new(config: HealthConfig) -> Self {
        Self { config: HealthConfigCell(config), ..Default::default() }
    }

    /// Record a successful call: closes the breaker and clears the
    /// consecutive-failure streak.
    pub fn record_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
        *self.open_since.lock() = None;
    }

    /// Record a failed call (after any retries were exhausted).
    pub fn record_failure(&self, kind: FaultKind) {
        self.record_failure_at(kind, Instant::now());
    }

    /// [`record_failure`](Self::record_failure) with an injected clock:
    /// the breaker opens *as of* `now`. Deterministic boundary tests and
    /// the loom models drive this directly; production code uses the
    /// `Instant::now()` wrapper.
    pub fn record_failure_at(&self, kind: FaultKind, now: Instant) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        if kind == FaultKind::Timeout {
            self.timeouts.fetch_add(1, Ordering::Relaxed);
        }
        let streak = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if streak >= self.config.0.failure_threshold as u64 {
            let mut open = self.open_since.lock();
            if open.is_none() {
                *open = Some(now);
            }
        }
    }

    /// Record one retry attempt (the eventual outcome is recorded
    /// separately via success/failure).
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether callers should route work to this model right now. An open
    /// breaker whose cooldown has elapsed grants exactly one probe call
    /// (and re-arms the cooldown so a failed probe waits again).
    pub fn healthy(&self) -> bool {
        self.healthy_at(Instant::now())
    }

    /// [`healthy`](Self::healthy) with an injected clock. The cooldown
    /// check is inclusive: a probe is granted when exactly `cooldown` has
    /// elapsed since the breaker opened. Granting the probe re-arms the
    /// timer *at `now`* under the same lock acquisition, so of any number
    /// of concurrent callers at the same instant, exactly one wins it.
    pub fn healthy_at(&self, now: Instant) -> bool {
        let mut open = self.open_since.lock();
        match *open {
            None => true,
            Some(when) => {
                if now.saturating_duration_since(when) >= self.config.0.cooldown {
                    // Half-open: let one probe through, re-arm the timer.
                    *open = Some(now);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Breaker state without side effects (does not consume the probe).
    pub fn breaker_state(&self) -> BreakerState {
        self.breaker_state_at(Instant::now())
    }

    /// [`breaker_state`](Self::breaker_state) with an injected clock.
    pub fn breaker_state_at(&self, now: Instant) -> BreakerState {
        let open = self.open_since.lock();
        match *open {
            None => BreakerState::Closed,
            Some(when) if now.saturating_duration_since(when) >= self.config.0.cooldown => {
                BreakerState::HalfOpen
            }
            Some(_) => BreakerState::Open,
        }
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed)
    }

    pub fn consecutive_failures(&self) -> u64 {
        self.consecutive.load(Ordering::Relaxed)
    }
}

/// Dense `[seq, vocab]` logits returned by one forward pass.
#[derive(Debug, Clone)]
pub struct Logits {
    data: Vec<f32>,
    seq: usize,
    vocab: usize,
}

impl Logits {
    pub fn new(data: Vec<f32>, seq: usize, vocab: usize) -> Self {
        assert_eq!(data.len(), seq * vocab, "logits size mismatch");
        Self { data, seq, vocab }
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Logits row for position `t` (the distribution over the *next* token
    /// after consuming `tokens[0..=t]`).
    pub fn row(&self, t: usize) -> &[f32] {
        assert!(t < self.seq, "position {t} out of range {}", self.seq);
        &self.data[t * self.vocab..(t + 1) * self.vocab]
    }

    /// The flat `[seq * vocab]` row-major buffer. Lets consumers absorb a
    /// whole reply with one bulk copy instead of a row-by-row loop.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Softmax of row `t` at the given temperature.
    pub fn probs(&self, t: usize, temperature: f32) -> Vec<f32> {
        softmax(self.row(t), temperature)
    }
}

/// Numerically-stable softmax with temperature.
pub fn softmax(logits: &[f32], temperature: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(logits.len());
    softmax_into(logits, temperature, &mut out);
    out
}

/// [`softmax`] into a caller-owned buffer (cleared and refilled) — the
/// decode hot paths reuse one buffer per loop instead of allocating a
/// vocab-sized `Vec` per token. Produces bit-identical values to `softmax`.
pub fn softmax_into(logits: &[f32], temperature: f32, out: &mut Vec<f32>) {
    let temp = temperature.max(1e-4);
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    out.clear();
    out.extend(logits.iter().map(|&l| ((l - m) / temp).exp()));
    let sum: f32 = out.iter().sum();
    let inv = 1.0 / sum;
    for p in out.iter_mut() {
        *p *= inv;
    }
}

/// A causal full-context scorer: `tokens[0..len] -> logits[len, vocab]`.
///
/// Deliberately NOT `Send + Sync`: the PJRT-backed engine is thread-bound
/// (`Rc` internals). Cross-thread use goes through
/// [`crate::runtime::host::RemoteModel`], which IS `Send + Sync` and proxies
/// to the engine thread. Per-model call/time counters feed the theory layer
/// (`F_i`, `T_i` in Lemma 3.1).
pub trait LanguageModel {
    fn name(&self) -> &str;

    /// Maximum context length the scorer accepts.
    fn seq_len(&self) -> usize;

    fn vocab(&self) -> usize;

    /// Score `tokens` (len <= seq_len). `logits.row(t)` is the next-token
    /// distribution after `tokens[0..=t]`; rows at `t >= tokens.len()` are
    /// unspecified.
    fn forward(&self, tokens: &[Token]) -> anyhow::Result<Logits>;

    /// Forward passes since the last [`reset_counters`](Self::reset_counters).
    fn calls(&self) -> u64;

    /// Wall time spent inside `forward` since the last reset.
    fn total_time(&self) -> Duration;

    fn reset_counters(&self);

    /// Best-known per-forward cost in ms (measured if available). This is
    /// `T_i` in the paper's cost model.
    fn cost_ms(&self) -> f64 {
        let calls = self.calls();
        if calls == 0 {
            0.0
        } else {
            self.total_time().as_secs_f64() * 1e3 / calls as f64
        }
    }

    /// Open an incremental [`ScoringSession`] on this model. The default is
    /// a [`StatelessSession`] (full-context re-forward per append), so every
    /// implementation gets the session API for free; backends with native
    /// prefix caching override this.
    fn open_session(&self) -> anyhow::Result<Box<dyn ScoringSession + '_>> {
        Ok(Box::new(StatelessSession::new(self)))
    }

    /// Whether this model should receive new work right now. Models with a
    /// circuit breaker ([`HealthTracker`]) override this; the default says
    /// always healthy. Decode tasks consult it at step boundaries to drop
    /// unhealthy drafters before wasting calls on them.
    fn healthy(&self) -> bool {
        true
    }

    /// The model's [`HealthTracker`], if it keeps one (engine-backed and
    /// chaos-wrapped models do). Lets the metrics layer expose breaker
    /// state without knowing concrete model types.
    fn health_handle(&self) -> Option<Arc<HealthTracker>> {
        None
    }

    /// Score many sessions' pending suffixes in **one** engine round-trip.
    /// `appends[i]` is `(batch_handle, suffix)` for one session of this
    /// model (handles come from [`ScoringSession::batch_handle`]).
    ///
    /// Returns `None` when the backend has no batched path (callers fall
    /// back to per-session [`ScoringSession::append`]). Otherwise the vec
    /// holds one `Result` per entry, in order — a poisoned session fails
    /// only its own entry, never the batch. Per entry, `Ok(Some(logits))`
    /// carries the suffix rows for the session to absorb; `Ok(None)` means
    /// the rows are recoverable session-side (e.g. the mock's hash oracle)
    /// and [`ScoringSession::absorb_batched`] recomputes them. Either way
    /// the entry's rows must be bit-identical to what a solo `append` of
    /// the same suffix would have produced.
    fn append_batch(
        &self,
        appends: &[(u64, Arc<[Token]>)],
    ) -> Option<Vec<anyhow::Result<Option<Logits>>>> {
        let _ = appends;
        None
    }
}

/// An incremental decode handle: a scored token prefix whose logits rows
/// stay cached, extended by [`append`](Self::append) and rewound by
/// [`rollback`](Self::rollback). See the module docs for the invariants.
pub trait ScoringSession {
    fn vocab(&self) -> usize;

    /// Number of tokens currently scored.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The scored prefix itself.
    fn tokens(&self) -> &[Token];

    /// Extend the prefix with `suffix`, scoring (at most) the new tokens.
    /// On error the session is left unchanged. An empty suffix is a no-op
    /// and must not count as a forward pass.
    fn append(&mut self, suffix: &[Token]) -> anyhow::Result<()>;

    /// Rewind the prefix to its first `to_len` tokens. Cached rows for the
    /// surviving prefix are preserved bit-identically. Errors if
    /// `to_len > len()`.
    fn rollback(&mut self, to_len: usize) -> anyhow::Result<()>;

    /// Cached next-token logits after `tokens()[0..=pos]` (`pos < len()`);
    /// bit-identical to `forward(tokens[..=pos]).row(pos)`.
    fn row(&self, pos: usize) -> &[f32];

    /// Copy of rows `[from, len())` as a [`Logits`] value (convenience for
    /// callers that want the suffix of the last append; allocates).
    fn suffix_logits(&self, from: usize) -> Logits {
        let vocab = self.vocab();
        let rows = self.len() - from;
        let mut data = Vec::with_capacity(rows * vocab);
        for t in from..self.len() {
            data.extend_from_slice(self.row(t));
        }
        Logits::new(data, rows, vocab)
    }

    /// Identifier for [`LanguageModel::append_batch`] entries, or `None`
    /// when this session cannot join a batched append (the default — e.g.
    /// [`StatelessSession`], whose appends re-score the whole prefix).
    fn batch_handle(&self) -> Option<u64> {
        None
    }

    /// Complete a batched append this session's model executed via
    /// [`LanguageModel::append_batch`]: extend the local prefix by
    /// `suffix` and install its rows — from `rows` when the engine shipped
    /// them, recomputed locally when it returned `Ok(None)`. Must leave
    /// the session bit-identical to a solo `append(suffix)`.
    fn absorb_batched(&mut self, suffix: &[Token], rows: Option<Logits>) -> anyhow::Result<()> {
        let _ = (suffix, rows);
        anyhow::bail!("session has no batched-append support")
    }
}

/// Sync a session to `target`: roll back to the longest common prefix, then
/// append the divergent suffix (one forward at most). This is the only
/// primitive the decode loops need — drafting appends at the tail, a
/// speculative rejection diverges at the rejected position, and both reduce
/// to rollback-then-append.
pub fn reconcile<S: ScoringSession + ?Sized>(
    session: &mut S,
    target: &[Token],
) -> anyhow::Result<()> {
    let lcp = session
        .tokens()
        .iter()
        .zip(target)
        .take_while(|(a, b)| a == b)
        .count();
    if lcp < session.len() {
        session.rollback(lcp)?;
    }
    if lcp < target.len() {
        session.append(&target[lcp..])?;
    }
    Ok(())
}

/// The universal [`ScoringSession`] fallback: re-runs `forward` over the
/// whole prefix on every append (the model itself stays stateless) and
/// keeps all rows cached host-side, so `rollback` and re-reads are free.
pub struct StatelessSession<'m, M: LanguageModel + ?Sized> {
    model: &'m M,
    tokens: Vec<Token>,
    /// Flat `[len, vocab]` row cache.
    rows: Vec<f32>,
}

impl<'m, M: LanguageModel + ?Sized> StatelessSession<'m, M> {
    pub fn new(model: &'m M) -> Self {
        Self { model, tokens: Vec::new(), rows: Vec::new() }
    }
}

impl<M: LanguageModel + ?Sized> ScoringSession for StatelessSession<'_, M> {
    fn vocab(&self) -> usize {
        self.model.vocab()
    }

    fn len(&self) -> usize {
        self.tokens.len()
    }

    fn tokens(&self) -> &[Token] {
        &self.tokens
    }

    fn append(&mut self, suffix: &[Token]) -> anyhow::Result<()> {
        if suffix.is_empty() {
            return Ok(());
        }
        let old = self.tokens.len();
        self.tokens.extend_from_slice(suffix);
        match self.model.forward(&self.tokens) {
            Ok(logits) => {
                // Keep previously cached rows (rollback exactness); copy
                // only the rows for the new suffix.
                for t in old..self.tokens.len() {
                    self.rows.extend_from_slice(logits.row(t));
                }
                Ok(())
            }
            Err(e) => {
                self.tokens.truncate(old);
                Err(e)
            }
        }
    }

    fn rollback(&mut self, to_len: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            to_len <= self.tokens.len(),
            "rollback to {to_len} past session length {}",
            self.tokens.len()
        );
        self.tokens.truncate(to_len);
        self.rows.truncate(to_len * self.model.vocab());
        Ok(())
    }

    fn row(&self, pos: usize) -> &[f32] {
        let vocab = self.model.vocab();
        assert!(pos < self.tokens.len(), "row {pos} out of range {}", self.tokens.len());
        &self.rows[pos * vocab..(pos + 1) * vocab]
    }
}

/// Delegating wrapper that hides a model's native session support, forcing
/// the [`StatelessSession`] fallback. Lets tests and benches A/B the cached
/// incremental path against full-context rescoring on identical weights.
pub struct ForceStateless<M: LanguageModel>(pub M);

impl<M: LanguageModel> LanguageModel for ForceStateless<M> {
    fn name(&self) -> &str {
        self.0.name()
    }

    fn seq_len(&self) -> usize {
        self.0.seq_len()
    }

    fn vocab(&self) -> usize {
        self.0.vocab()
    }

    fn forward(&self, tokens: &[Token]) -> anyhow::Result<Logits> {
        self.0.forward(tokens)
    }

    fn calls(&self) -> u64 {
        self.0.calls()
    }

    fn total_time(&self) -> Duration {
        self.0.total_time()
    }

    fn reset_counters(&self) {
        self.0.reset_counters()
    }

    fn cost_ms(&self) -> f64 {
        self.0.cost_ms()
    }

    fn healthy(&self) -> bool {
        self.0.healthy()
    }

    fn health_handle(&self) -> Option<Arc<HealthTracker>> {
        self.0.health_handle()
    }
    // `open_session` deliberately NOT overridden: the default
    // StatelessSession is the point of this wrapper.
}

/// Shared instrumentation for `LanguageModel` implementations.
#[derive(Debug, Default)]
pub struct ModelCounters {
    calls: AtomicU64,
    nanos: AtomicU64,
}

impl ModelCounters {
    pub fn record(&self, elapsed: Duration) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn total_time(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
    }
}

/// How proposed tokens are checked against a verifier's distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VerifyRule {
    /// Accept iff the token equals the verifier's argmax. Deterministic;
    /// output equals the verifier's greedy decode.
    Greedy,
    /// Leviathan-style rejection sampling: accept with `min(1, p/q)`,
    /// resample from `norm(max(p-q, 0))` on rejection. Lossless.
    Speculative,
    /// Typical acceptance (Medusa-style): accept if `p[x] >= eps * max(p)`.
    /// NOT distribution-preserving; included as the paper discusses it.
    Typical { eps: f32 },
}

/// Sampling configuration for a generation.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    pub temperature: f32,
    pub top_k: usize, // 0 = disabled
    pub top_p: f32,   // 1.0 = disabled
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

/// Outcome of one generation, with the measurements the paper reports.
#[derive(Debug, Clone)]
pub struct GenerationOutput {
    pub tokens: Vec<Token>,
    /// Wall-clock for the whole decode.
    pub wall: Duration,
    /// Per-model forward-pass counts, chain order (target first) — `F_i`.
    pub forward_passes: Vec<u64>,
    /// Per-model cumulative forward time, chain order.
    pub forward_time: Vec<Duration>,
    /// Acceptance lengths observed at the *target* per target forward — the
    /// paper's `μ` is `accept_lengths.mean()`.
    pub accept_lengths: Vec<u32>,
    /// Acceptance lengths at each intermediate verifier (chain order,
    /// excluding target), for the theory layer's `L_i` estimates.
    pub stage_accept_lengths: Vec<Vec<u32>>,
    /// How many chain members were dropped mid-decode (graceful
    /// degradation). Zero for a fault-free run.
    pub degraded: u32,
}

impl GenerationOutput {
    pub fn mean_accept(&self) -> f64 {
        mean_u32(&self.accept_lengths)
    }
}

pub(crate) fn mean_u32(xs: &[u32]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_rows() {
        let l = Logits::new(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 2, 3);
        assert_eq!(l.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(l.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let hot = softmax(&[1.0, 2.0], 2.0);
        let cold = softmax(&[1.0, 2.0], 0.5);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let p = softmax(&[-1e30, 0.0, 1e3], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[0] >= 0.0 && p[2] <= 1.0);
    }

    #[test]
    fn softmax_into_matches_softmax() {
        let logits = [1.5f32, -2.0, 0.25, 7.0];
        let mut buf = vec![9.0f32; 2]; // stale contents must be discarded
        softmax_into(&logits, 0.7, &mut buf);
        assert_eq!(buf, softmax(&logits, 0.7));
    }

    #[test]
    fn stateless_session_matches_forward() {
        use crate::spec::mock::MockModel;
        let m = MockModel::new("m", 64, 8, 3, 0.4);
        let mut sess = StatelessSession::new(&m);
        sess.append(&[1, 2]).unwrap();
        sess.append(&[3]).unwrap();
        let full = m.forward(&[1, 2, 3]).unwrap();
        for t in 0..3 {
            assert_eq!(sess.row(t), full.row(t), "row {t}");
        }
        assert_eq!(sess.tokens(), &[1, 2, 3]);
        assert_eq!(sess.len(), 3);
        assert_eq!(sess.suffix_logits(1).row(1), full.row(2));
    }

    #[test]
    fn stateless_session_rollback_and_reconcile() {
        use crate::spec::mock::MockModel;
        let m = MockModel::new("m", 64, 8, 3, 0.4);
        let mut sess = StatelessSession::new(&m);
        sess.append(&[5, 6, 7, 8]).unwrap();
        let row1 = sess.row(1).to_vec();
        sess.rollback(2).unwrap();
        assert_eq!(sess.len(), 2);
        assert_eq!(sess.row(1), &row1[..], "rollback must keep surviving rows");
        assert!(sess.rollback(3).is_err(), "rollback past end must fail");
        // Reconcile to a diverging target: rollback + single append.
        reconcile(&mut sess, &[5, 9, 1]).unwrap();
        assert_eq!(sess.tokens(), &[5, 9, 1]);
        let full = m.forward(&[5, 9, 1]).unwrap();
        for t in 0..3 {
            assert_eq!(sess.row(t), full.row(t), "row {t}");
        }
        // Reconcile to a strict prefix: rollback only, no forward.
        let calls = m.calls();
        reconcile(&mut sess, &[5, 9]).unwrap();
        assert_eq!(sess.tokens(), &[5, 9]);
        assert_eq!(m.calls(), calls, "prefix reconcile must not forward");
    }

    #[test]
    fn default_open_session_works_on_trait_objects() {
        use crate::spec::mock::MockModel;
        let m = ForceStateless(MockModel::new("m", 32, 8, 1, 0.0));
        let as_dyn: &dyn LanguageModel = &m;
        let mut sess = as_dyn.open_session().unwrap();
        sess.append(&[1, 2, 3]).unwrap();
        assert_eq!(sess.len(), 3);
        assert_eq!(sess.vocab(), 8);
        assert!(!sess.is_empty());
    }

    #[test]
    fn counters_accumulate() {
        let c = ModelCounters::default();
        c.record(Duration::from_millis(2));
        c.record(Duration::from_millis(4));
        assert_eq!(c.calls(), 2);
        assert_eq!(c.total_time(), Duration::from_millis(6));
        c.reset();
        assert_eq!(c.calls(), 0);
    }

    #[test]
    fn breaker_opens_on_consecutive_failures() {
        let h = HealthTracker::new(HealthConfig {
            failure_threshold: 3,
            cooldown: Duration::from_secs(60),
        });
        assert!(h.healthy());
        h.record_failure(FaultKind::Transient);
        h.record_failure(FaultKind::Transient);
        assert!(h.healthy(), "below threshold the breaker stays closed");
        h.record_failure(FaultKind::Timeout);
        assert!(!h.healthy(), "threshold reached: breaker open");
        assert_eq!(h.breaker_state(), BreakerState::Open);
        assert_eq!(h.errors(), 3);
        assert_eq!(h.timeouts(), 1);
        assert_eq!(h.consecutive_failures(), 3);
    }

    #[test]
    fn breaker_success_resets_streak() {
        let h = HealthTracker::new(HealthConfig {
            failure_threshold: 2,
            cooldown: Duration::from_secs(60),
        });
        h.record_failure(FaultKind::Transient);
        h.record_success();
        h.record_failure(FaultKind::Transient);
        assert!(h.healthy(), "success in between must clear the streak");
        assert_eq!(h.consecutive_failures(), 1);
        assert_eq!(h.errors(), 2, "cumulative error count is never reset");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-time sleep; the _at tests cover this deterministically
    fn breaker_cooldown_grants_single_probe() {
        let h = HealthTracker::new(HealthConfig {
            failure_threshold: 1,
            cooldown: Duration::from_millis(10),
        });
        h.record_failure(FaultKind::Lost);
        assert!(!h.healthy());
        std::thread::sleep(Duration::from_millis(15));
        assert_eq!(h.breaker_state(), BreakerState::HalfOpen);
        assert!(h.healthy(), "cooldown elapsed: one probe allowed");
        assert!(!h.healthy(), "probe consumed: cooldown re-armed");
        // A successful probe closes the breaker for good.
        h.record_success();
        assert!(h.healthy());
        assert!(h.healthy());
        assert_eq!(h.breaker_state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_cooldown_boundary_is_inclusive() {
        let cooldown = Duration::from_secs(5);
        let h = HealthTracker::new(HealthConfig { failure_threshold: 1, cooldown });
        let t0 = Instant::now();
        h.record_failure_at(FaultKind::Transient, t0);
        let just_before = t0 + (cooldown - Duration::from_nanos(1));
        assert_eq!(h.breaker_state_at(just_before), BreakerState::Open);
        assert!(!h.healthy_at(just_before), "1ns short of the cooldown: still open");
        let boundary = t0 + cooldown;
        assert_eq!(h.breaker_state_at(boundary), BreakerState::HalfOpen);
        assert!(h.healthy_at(boundary), "probe granted exactly at the boundary tick");
        assert!(!h.healthy_at(boundary), "probe consumed; cooldown re-armed at the boundary");
    }

    #[test]
    fn breaker_probe_failure_reopens_with_reset_cooldown() {
        let cooldown = Duration::from_secs(5);
        let h = HealthTracker::new(HealthConfig { failure_threshold: 1, cooldown });
        let t0 = Instant::now();
        h.record_failure_at(FaultKind::Transient, t0);
        let t1 = t0 + cooldown;
        assert!(h.healthy_at(t1), "half-open probe granted");
        // The probe fails: the breaker must stay open and wait out a full
        // cooldown from the *probe* (the timer re-armed at t1), not grant
        // another probe off the original t0 timestamp.
        h.record_failure_at(FaultKind::Transient, t1);
        assert_eq!(h.breaker_state_at(t1), BreakerState::Open);
        assert!(!h.healthy_at(t1 + cooldown - Duration::from_nanos(1)));
        assert!(h.healthy_at(t1 + cooldown), "next probe a full cooldown after the failed one");
    }

    #[test]
    fn concurrent_failures_never_lose_streak_counts() {
        use crate::sync::Arc;
        let h = Arc::new(HealthTracker::new(HealthConfig {
            failure_threshold: 1000,
            cooldown: Duration::from_secs(60),
        }));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        h.record_failure(FaultKind::Transient);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.consecutive_failures(), 100, "every increment must survive the race");
        assert_eq!(h.errors(), 100);
        assert!(h.healthy(), "threshold 1000 never reached: breaker closed");
    }
}
