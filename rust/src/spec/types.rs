//! Core types shared by the speculative-decoding algorithms.
//!
//! The algorithm layer (`spec::*`) depends only on the [`LanguageModel`]
//! trait — never on PJRT — so every algorithm is unit-testable against
//! [`crate::spec::mock::MockModel`] and runs unchanged against the real
//! AOT-compiled engines in `runtime::`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

pub type Token = i32;

/// Dense `[seq, vocab]` logits returned by one forward pass.
#[derive(Debug, Clone)]
pub struct Logits {
    data: Vec<f32>,
    seq: usize,
    vocab: usize,
}

impl Logits {
    pub fn new(data: Vec<f32>, seq: usize, vocab: usize) -> Self {
        assert_eq!(data.len(), seq * vocab, "logits size mismatch");
        Self { data, seq, vocab }
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Logits row for position `t` (the distribution over the *next* token
    /// after consuming `tokens[0..=t]`).
    pub fn row(&self, t: usize) -> &[f32] {
        assert!(t < self.seq, "position {t} out of range {}", self.seq);
        &self.data[t * self.vocab..(t + 1) * self.vocab]
    }

    /// Softmax of row `t` at the given temperature.
    pub fn probs(&self, t: usize, temperature: f32) -> Vec<f32> {
        softmax(self.row(t), temperature)
    }
}

/// Numerically-stable softmax with temperature.
pub fn softmax(logits: &[f32], temperature: f32) -> Vec<f32> {
    let temp = temperature.max(1e-4);
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut out: Vec<f32> = logits.iter().map(|&l| ((l - m) / temp).exp()).collect();
    let sum: f32 = out.iter().sum();
    let inv = 1.0 / sum;
    for p in &mut out {
        *p *= inv;
    }
    out
}

/// A causal full-context scorer: `tokens[0..len] -> logits[len, vocab]`.
///
/// Deliberately NOT `Send + Sync`: the PJRT-backed engine is thread-bound
/// (`Rc` internals). Cross-thread use goes through
/// [`crate::runtime::host::RemoteModel`], which IS `Send + Sync` and proxies
/// to the engine thread. Per-model call/time counters feed the theory layer
/// (`F_i`, `T_i` in Lemma 3.1).
pub trait LanguageModel {
    fn name(&self) -> &str;

    /// Maximum context length the scorer accepts.
    fn seq_len(&self) -> usize;

    fn vocab(&self) -> usize;

    /// Score `tokens` (len <= seq_len). `logits.row(t)` is the next-token
    /// distribution after `tokens[0..=t]`; rows at `t >= tokens.len()` are
    /// unspecified.
    fn forward(&self, tokens: &[Token]) -> anyhow::Result<Logits>;

    /// Forward passes since the last [`reset_counters`](Self::reset_counters).
    fn calls(&self) -> u64;

    /// Wall time spent inside `forward` since the last reset.
    fn total_time(&self) -> Duration;

    fn reset_counters(&self);

    /// Best-known per-forward cost in ms (measured if available). This is
    /// `T_i` in the paper's cost model.
    fn cost_ms(&self) -> f64 {
        let calls = self.calls();
        if calls == 0 {
            0.0
        } else {
            self.total_time().as_secs_f64() * 1e3 / calls as f64
        }
    }
}

/// Shared instrumentation for `LanguageModel` implementations.
#[derive(Debug, Default)]
pub struct ModelCounters {
    calls: AtomicU64,
    nanos: AtomicU64,
}

impl ModelCounters {
    pub fn record(&self, elapsed: Duration) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn total_time(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
    }
}

/// How proposed tokens are checked against a verifier's distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VerifyRule {
    /// Accept iff the token equals the verifier's argmax. Deterministic;
    /// output equals the verifier's greedy decode.
    Greedy,
    /// Leviathan-style rejection sampling: accept with `min(1, p/q)`,
    /// resample from `norm(max(p-q, 0))` on rejection. Lossless.
    Speculative,
    /// Typical acceptance (Medusa-style): accept if `p[x] >= eps * max(p)`.
    /// NOT distribution-preserving; included as the paper discusses it.
    Typical { eps: f32 },
}

/// Sampling configuration for a generation.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    pub temperature: f32,
    pub top_k: usize, // 0 = disabled
    pub top_p: f32,   // 1.0 = disabled
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

/// Outcome of one generation, with the measurements the paper reports.
#[derive(Debug, Clone)]
pub struct GenerationOutput {
    pub tokens: Vec<Token>,
    /// Wall-clock for the whole decode.
    pub wall: Duration,
    /// Per-model forward-pass counts, chain order (target first) — `F_i`.
    pub forward_passes: Vec<u64>,
    /// Per-model cumulative forward time, chain order.
    pub forward_time: Vec<Duration>,
    /// Acceptance lengths observed at the *target* per target forward — the
    /// paper's `μ` is `accept_lengths.mean()`.
    pub accept_lengths: Vec<u32>,
    /// Acceptance lengths at each intermediate verifier (chain order,
    /// excluding target), for the theory layer's `L_i` estimates.
    pub stage_accept_lengths: Vec<Vec<u32>>,
}

impl GenerationOutput {
    pub fn mean_accept(&self) -> f64 {
        mean_u32(&self.accept_lengths)
    }
}

pub(crate) fn mean_u32(xs: &[u32]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_rows() {
        let l = Logits::new(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 2, 3);
        assert_eq!(l.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(l.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let hot = softmax(&[1.0, 2.0], 2.0);
        let cold = softmax(&[1.0, 2.0], 0.5);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let p = softmax(&[-1e30, 0.0, 1e3], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[0] >= 0.0 && p[2] <= 1.0);
    }

    #[test]
    fn counters_accumulate() {
        let c = ModelCounters::default();
        c.record(Duration::from_millis(2));
        c.record(Duration::from_millis(4));
        assert_eq!(c.calls(), 2);
        assert_eq!(c.total_time(), Duration::from_millis(6));
        c.reset();
        assert_eq!(c.calls(), 0);
    }
}
