//! Polybasic speculative decoding — the paper's Algorithm 1, generalized
//! from three models to an arbitrary chain `M_1 (target) … M_n (drafter)`.
//!
//! Pipeline model: tokens are drafted by `M_n` and flow *up* the chain.
//! `pending[j]` holds tokens awaiting verification by `models[j]`, each
//! carrying the distribution it was proposed from.  Position order in the
//! logical sequence is
//!
//! ```text
//! committed ctx | pending[0] | pending[1] | … | pending[n-2] | (new drafts)
//! ```
//!
//! Stage `j` fires once `pending[j]` reaches its threshold `μ_j` (Algorithm
//! 1's `cnt >= μ` check): one forward of `models[j]` scores the whole prefix
//! and verifies its queue sequentially.  Accepted tokens (plus the
//! replacement emitted on a rejection, whose marginal is exactly `p_j` by
//! the speculative-sampling theorem) move to `pending[j-1]` with proposal
//! distribution `p_j`; a full acceptance yields a bonus token.  A rejection
//! at stage `j` invalidates everything at later positions (the rest of
//! `pending[j]` and all `pending[k]`, `k > j`).
//!
//! Stage 0 commits to the output.  With `VerifyRule::Speculative` at every
//! stage the committed stream is distributed *exactly* as the target's
//! sampling distribution (chained losslessness, see `verify.rs`); with
//! `VerifyRule::Greedy` it equals the target's greedy decode token-for-token
//! — both properties are asserted in tests.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::dualistic::{dist_row, pick};
use super::rng::Pcg32;
use super::types::{GenerationOutput, LanguageModel, SamplingParams, Token, VerifyRule};
use super::verify::{verify_block, BlockVerdict};

/// Configuration of a polybasic decode.
#[derive(Debug, Clone)]
pub struct PolyConfig {
    /// Tokens drafted by `M_n` per drafting burst (Algorithm 1's `K`).
    pub draft_k: usize,
    /// Verification thresholds `μ_j` per verifier stage, target first
    /// (`thresholds[0]` is Algorithm 1's `μ`). Length must be `n - 1`.
    pub thresholds: Vec<usize>,
    pub rule: VerifyRule,
    pub sampling: SamplingParams,
    pub max_new: usize,
}

impl PolyConfig {
    /// Sensible defaults for an `n`-model chain: target threshold `mu`,
    /// everything deeper verifies every `draft_k` tokens.
    pub fn for_chain(n_models: usize, draft_k: usize, mu: usize, max_new: usize) -> Self {
        assert!(n_models >= 2);
        let mut thresholds = vec![draft_k.max(1); n_models - 1];
        thresholds[0] = mu.max(1);
        Self {
            draft_k,
            thresholds,
            rule: VerifyRule::Speculative,
            sampling: SamplingParams::default(),
            max_new,
        }
    }

    /// Context headroom the pipeline may occupy beyond committed tokens
    /// (used for admission control).
    pub fn headroom(&self) -> usize {
        self.thresholds.iter().sum::<usize>() + self.draft_k + self.thresholds.len() + 2
    }
}

/// A token in flight, with the distribution it was proposed from.
#[derive(Debug, Clone)]
struct Pending {
    tok: Token,
    q: Vec<f32>,
}

/// Generate with a polybasic chain. `models[0]` is the target `M_1`,
/// `models[n-1]` the drafter `M_n`.
pub fn generate(
    models: &[Arc<dyn LanguageModel>],
    prompt: &[Token],
    cfg: &PolyConfig,
) -> Result<GenerationOutput> {
    let n = models.len();
    anyhow::ensure!(n >= 2, "polybasic needs at least two models");
    anyhow::ensure!(cfg.thresholds.len() == n - 1, "need one threshold per verifier");
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(cfg.draft_k >= 1, "draft_k must be >= 1");
    let seq_cap = models.iter().map(|m| m.seq_len()).min().unwrap();
    anyhow::ensure!(
        prompt.len() + cfg.max_new + cfg.headroom() <= seq_cap,
        "prompt {} + max_new {} + pipeline headroom {} exceeds context {}",
        prompt.len(),
        cfg.max_new,
        cfg.headroom(),
        seq_cap
    );

    for m in models {
        m.reset_counters();
    }
    let start = Instant::now();
    let mut rng = Pcg32::seeded(cfg.sampling.seed);

    let mut ctx = prompt.to_vec();
    let mut pending: Vec<VecDeque<Pending>> = (0..n - 1).map(|_| VecDeque::new()).collect();
    let mut accept_lengths: Vec<u32> = Vec::new();
    let mut stage_accepts: Vec<Vec<u32>> = vec![Vec::new(); n - 1];

    'outer: while ctx.len() - prompt.len() < cfg.max_new {
        let committed = ctx.len() - prompt.len();
        let remaining = cfg.max_new - committed;
        let in_flight: usize = pending.iter().map(|p| p.len()).sum();
        // Flush mode: the pipeline already holds enough tokens to finish the
        // request (or drafting would overflow the context) — stop drafting
        // and fire every non-empty stage regardless of thresholds.
        let draft_room = seq_cap.saturating_sub(ctx.len() + in_flight);
        let flush = in_flight >= remaining || draft_room == 0;

        let mut fired = false;

        // ---- 1. draft with M_n into the deepest queue --------------------
        let deepest = n - 2;
        if !flush && pending[deepest].len() < cfg.thresholds[deepest].max(1) {
            let want = cfg.draft_k.min(remaining.saturating_sub(in_flight)).min(draft_room);
            if want > 0 {
                let mut frontier = flat_sequence(&ctx, &pending);
                for _ in 0..want {
                    let logits = models[n - 1].forward(&frontier)?;
                    let mut q = dist_row(&logits, frontier.len() - 1, &cfg.sampling);
                    let tok = pick(&mut q, &cfg.sampling, cfg.rule, &mut rng);
                    pending[deepest].push_back(Pending { tok, q });
                    frontier.push(tok);
                }
                fired = true;
            }
        }

        // ---- 2. verification sweep, deepest stage first ------------------
        for j in (0..n - 1).rev() {
            if pending[j].is_empty() {
                continue;
            }
            let ready = pending[j].len() >= cfg.thresholds[j];
            if !(ready || flush) {
                continue;
            }
            let committed_now = verify_stage(
                models, j, &mut ctx, &mut pending, cfg, &mut rng, &mut stage_accepts,
            )?;
            fired = true;
            if j == 0 {
                accept_lengths.push(committed_now as u32);
                if ctx.len() - prompt.len() >= cfg.max_new {
                    break 'outer;
                }
            }
        }

        // ---- 3. deadlock backstop ----------------------------------------
        if !fired {
            // Nothing met its threshold and drafting was blocked: force the
            // deepest non-empty stage (guaranteed progress).
            if let Some(j) = (0..n - 1).rev().find(|&j| !pending[j].is_empty()) {
                let committed_now = verify_stage(
                    models, j, &mut ctx, &mut pending, cfg, &mut rng, &mut stage_accepts,
                )?;
                if j == 0 {
                    accept_lengths.push(committed_now as u32);
                }
            } else {
                anyhow::bail!("decode stalled: empty pipeline but no draft room");
            }
        }
    }

    ctx.truncate(prompt.len() + cfg.max_new);
    Ok(GenerationOutput {
        tokens: ctx[prompt.len()..].to_vec(),
        wall: start.elapsed(),
        forward_passes: models.iter().map(|m| m.calls()).collect(),
        forward_time: models.iter().map(|m| m.total_time()).collect(),
        accept_lengths,
        stage_accept_lengths: stage_accepts,
    })
}

/// The logical token sequence: ctx followed by every pending queue in
/// position order.
fn flat_sequence(ctx: &[Token], pending: &[VecDeque<Pending>]) -> Vec<Token> {
    let mut seq = ctx.to_vec();
    for queue in pending {
        seq.extend(queue.iter().map(|p| p.tok));
    }
    seq
}

/// Run verifier `j` over its queue. Returns the number of tokens committed
/// (only non-zero for `j == 0`).
#[allow(clippy::too_many_arguments)]
fn verify_stage(
    models: &[Arc<dyn LanguageModel>],
    j: usize,
    ctx: &mut Vec<Token>,
    pending: &mut [VecDeque<Pending>],
    cfg: &PolyConfig,
    rng: &mut Pcg32,
    stage_accepts: &mut [Vec<u32>],
) -> Result<usize> {
    // Input: everything up to and including pending[j].
    let mut input = ctx.clone();
    for queue in pending[..j].iter() {
        input.extend(queue.iter().map(|p| p.tok));
    }
    let base = input.len(); // position of pending[j][0]
    let block: Vec<Token> = pending[j].iter().map(|p| p.tok).collect();
    let q_rows: Vec<Vec<f32>> = pending[j].iter().map(|p| p.q.clone()).collect();
    input.extend(&block);

    let logits = models[j].forward(&input)?;
    let p_rows: Vec<Vec<f32>> = (0..block.len())
        .map(|i| dist_row(&logits, base - 1 + i, &cfg.sampling))
        .collect();

    let BlockVerdict { accepted, replacement } =
        verify_block(&block, &p_rows, &q_rows, cfg.rule, rng);
    stage_accepts[j].push(accepted as u32);

    // Emitted stream = accepted prefix (+ replacement | bonus), each with
    // proposal distribution p_j (the verifier's own rows).
    let mut emitted: Vec<Pending> = Vec::with_capacity(accepted + 1);
    for i in 0..accepted {
        emitted.push(Pending { tok: block[i], q: p_rows[i].clone() });
    }
    let rejected = replacement.is_some();
    if let Some(r) = replacement {
        emitted.push(Pending { tok: r, q: p_rows[accepted].clone() });
    } else {
        // Full acceptance: free bonus token from the row after the block.
        let mut p = dist_row(&logits, base + block.len() - 1, &cfg.sampling);
        let bonus = pick(&mut p, &cfg.sampling, cfg.rule, rng);
        emitted.push(Pending { tok: bonus, q: p });
    }

    // A rejection invalidates every later position in the pipeline.
    if rejected {
        for queue in pending[j..].iter_mut() {
            queue.clear();
        }
    } else {
        pending[j].clear();
    }

    if j == 0 {
        let committed = emitted.len();
        ctx.extend(emitted.into_iter().map(|p| p.tok));
        Ok(committed)
    } else {
        for p in emitted {
            pending[j - 1].push_back(p);
        }
        Ok(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::autoregressive;
    use crate::spec::mock::{mock_chain, MockModel};

    fn greedy_cfg(n: usize, max_new: usize) -> PolyConfig {
        let mut cfg = PolyConfig::for_chain(n, 4, 4, max_new);
        cfg.rule = VerifyRule::Greedy;
        cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
        cfg
    }

    #[test]
    fn greedy_three_model_matches_target_greedy() {
        // THE lossless-cascade correctness check: committed output must be
        // token-for-token the target's own greedy decode.
        let chain = mock_chain(512, 24, 11);
        let cfg = greedy_cfg(3, 48);
        let out = generate(&chain, &[3, 1, 4], &cfg).unwrap();
        let ar = autoregressive::generate(
            chain[0].as_ref(),
            &[3, 1, 4],
            48,
            &cfg.sampling,
        )
        .unwrap();
        assert_eq!(out.tokens, ar.tokens);
    }

    #[test]
    fn greedy_four_model_matches_target_greedy() {
        let mut chain = mock_chain(512, 24, 13);
        chain.push(Arc::new(MockModel::new("mock-tiny", 512, 24, 13, 1.4)));
        let cfg = greedy_cfg(4, 40);
        let out = generate(&chain, &[9, 2], &cfg).unwrap();
        let ar = autoregressive::generate(chain[0].as_ref(), &[9, 2], 40, &cfg.sampling)
            .unwrap();
        assert_eq!(out.tokens, ar.tokens);
    }

    #[test]
    fn produces_exact_length() {
        let chain = mock_chain(512, 24, 7);
        let cfg = PolyConfig::for_chain(3, 5, 6, 33);
        let out = generate(&chain, &[1, 2], &cfg).unwrap();
        assert_eq!(out.tokens.len(), 33);
    }

    #[test]
    fn target_forwards_fewer_than_tokens() {
        let chain = mock_chain(512, 24, 7);
        let cfg = PolyConfig::for_chain(3, 4, 6, 64);
        let out = generate(&chain, &[1, 2], &cfg).unwrap();
        assert!(
            out.forward_passes[0] < 64 / 2,
            "target forwards {:?}",
            out.forward_passes
        );
        assert!(out.mean_accept() > 2.0, "mu {}", out.mean_accept());
    }

    #[test]
    fn n2_matches_dualistic_statistics() {
        // polybasic with n=2 should behave like the dedicated dualistic
        // implementation (same acceptance behaviour, exact greedy equality).
        let chain = mock_chain(512, 24, 19);
        let two: Vec<Arc<dyn LanguageModel>> = vec![chain[0].clone(), chain[2].clone()];
        let mut cfg = PolyConfig::for_chain(2, 4, 4, 40);
        cfg.rule = VerifyRule::Greedy;
        cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
        let poly = generate(&two, &[8, 8], &cfg).unwrap();
        let dual = crate::spec::dualistic::generate(
            chain[0].as_ref(),
            chain[2].as_ref(),
            &[8, 8],
            &crate::spec::dualistic::DualisticConfig {
                draft_k: 4,
                rule: VerifyRule::Greedy,
                sampling: cfg.sampling,
                max_new: 40,
            },
        )
        .unwrap();
        assert_eq!(poly.tokens, dual.tokens);
    }

    #[test]
    fn speculative_sampling_reproducible() {
        let chain = mock_chain(512, 24, 23);
        let mut cfg = PolyConfig::for_chain(3, 4, 6, 32);
        cfg.sampling.seed = 77;
        let a = generate(&chain, &[5], &cfg).unwrap();
        let b = generate(&chain, &[5], &cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    /// Statistical losslessness: the marginal distribution of the first
    /// generated token under polybasic speculative sampling must match
    /// direct target sampling.
    #[test]
    fn speculative_first_token_distribution_matches_target() {
        let chain = mock_chain(512, 12, 31);
        let prompt = [4, 2, 4];
        let trials = 4000;
        let mut poly_counts = vec![0f64; 12];
        let mut ar_counts = vec![0f64; 12];
        for s in 0..trials {
            let mut cfg = PolyConfig::for_chain(3, 3, 2, 1);
            cfg.sampling.seed = s;
            let out = generate(&chain, &prompt, &cfg).unwrap();
            poly_counts[out.tokens[0] as usize] += 1.0;
            let ar = autoregressive::generate(
                chain[0].as_ref(),
                &prompt,
                1,
                &SamplingParams { seed: s + 500_000, ..Default::default() },
            )
            .unwrap();
            ar_counts[ar.tokens[0] as usize] += 1.0;
        }
        // Total-variation distance between the two empirical distributions.
        let tv: f64 = poly_counts
            .iter()
            .zip(&ar_counts)
            .map(|(&a, &b)| (a - b).abs())
            .sum::<f64>()
            / (2.0 * trials as f64);
        assert!(tv < 0.05, "total variation {tv} too large — lossless property violated?");
    }

    #[test]
    fn rejects_bad_configs() {
        let chain = mock_chain(64, 24, 7);
        let cfg = PolyConfig::for_chain(3, 4, 4, 64); // doesn't fit in 64 ctx
        assert!(generate(&chain, &[1], &cfg).is_err());
        let mut cfg2 = PolyConfig::for_chain(3, 4, 4, 8);
        cfg2.thresholds.pop();
        assert!(generate(&chain, &[1], &cfg2).is_err());
    }
}
