//! Polybasic speculative decoding — the paper's Algorithm 1, generalized
//! from three models to an arbitrary chain `M_1 (target) … M_n (drafter)`.
//!
//! Pipeline model: tokens are drafted by `M_n` and flow *up* the chain.
//! `queues[j]` holds the proposal distributions of tokens awaiting
//! verification by `models[j]`; the tokens themselves live in one logical
//! sequence `flat`, in position order
//!
//! ```text
//! committed | queues[0] | queues[1] | … | queues[n-2] | (new drafts)
//! ```
//!
//! Stage `j` fires once `queues[j]` reaches its threshold `μ_j` (Algorithm
//! 1's `cnt >= μ` check) and verifies its block sequentially.  Accepted
//! tokens (plus the replacement emitted on a rejection, whose marginal is
//! exactly `p_j` by the speculative-sampling theorem) move to `queues[j-1]`
//! with proposal distribution `p_j`; a full acceptance yields a bonus
//! token.  A rejection at stage `j` invalidates everything at later
//! positions.  Stage 0 commits to the output.
//!
//! The loop is a resumable [`PolyTask`]: one [`step`](DecodeTask::step) =
//! one drafting burst + one threshold-gated verification sweep, so the
//! serving coordinator can interleave many decodes on one worker and stream
//! commits as they land; [`generate`] drives a task to completion.  Every
//! chain member holds one [`ScoringSession`]: drafting scores only each new
//! token, a verify scores only the block (not the whole prefix), and a
//! rejection *rolls the session back* to the surviving prefix — the
//! cached-prefix cost model of Lemma 3.1.  Distribution rows are pooled and
//! verification materializes verifier rows lazily, so the steady-state loop
//! allocates nothing.  Committed output is token-for-token identical to the
//! stateless implementation under every [`VerifyRule`], stepped or not
//! (sessions change where rows come from, never their values — asserted in
//! `tests/property_tests.rs`).
//!
//! With `VerifyRule::Speculative` at every stage the committed stream is
//! distributed *exactly* as the target's sampling distribution (chained
//! losslessness, see `verify.rs`); with `VerifyRule::Greedy` it equals the
//! target's greedy decode token-for-token — both properties are asserted in
//! tests.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::dualistic::{dist_row_into, pick};
use super::rng::Pcg32;
use super::sampler::FilterScratch;
use super::task::{
    model_key, DecodeTask, InflightState, PlannedAppend, ResumeState, StepMeter, StepOutcome,
};
use super::types::{
    reconcile, GenerationOutput, LanguageModel, Logits, SamplingParams, ScoringSession, Token,
    VerifyRule,
};
use super::verify::{verify_token, TokenVerdict};

/// Configuration of a polybasic decode.
#[derive(Debug, Clone)]
pub struct PolyConfig {
    /// Tokens drafted by `M_n` per drafting burst (Algorithm 1's `K`).
    pub draft_k: usize,
    /// Verification thresholds `μ_j` per verifier stage, target first
    /// (`thresholds[0]` is Algorithm 1's `μ`). Length must be `n - 1`.
    pub thresholds: Vec<usize>,
    pub rule: VerifyRule,
    pub sampling: SamplingParams,
    pub max_new: usize,
}

impl PolyConfig {
    /// Sensible defaults for an `n`-model chain: target threshold `mu`,
    /// everything deeper verifies every `draft_k` tokens.
    pub fn for_chain(n_models: usize, draft_k: usize, mu: usize, max_new: usize) -> Self {
        assert!(n_models >= 2);
        let mut thresholds = vec![draft_k.max(1); n_models - 1];
        thresholds[0] = mu.max(1);
        Self {
            draft_k,
            thresholds,
            rule: VerifyRule::Speculative,
            sampling: SamplingParams::default(),
            max_new,
        }
    }

    /// Context headroom the pipeline may occupy beyond committed tokens
    /// (used for admission control).
    pub fn headroom(&self) -> usize {
        self.thresholds.iter().sum::<usize>() + self.draft_k + self.thresholds.len() + 2
    }
}

/// Mutable decode-loop state: the logical token sequence plus per-stage
/// queues of proposal distributions and a buffer pool keeping the hot path
/// allocation-free.  `flat[..committed]` is committed output; `queues[j]`'s
/// tokens occupy `flat[start(j) .. start(j) + queues[j].len()]`.
struct Pipeline {
    flat: Vec<Token>,
    committed: usize,
    queues: Vec<VecDeque<Vec<f32>>>,
    /// Recycled vocab-sized distribution buffers.
    pool: Vec<Vec<f32>>,
}

impl Pipeline {
    /// Position of `queues[j]`'s first token in `flat`.
    fn start(&self, j: usize) -> usize {
        self.committed + self.queues[..j].iter().map(|q| q.len()).sum::<usize>()
    }

    fn in_flight(&self) -> usize {
        self.flat.len() - self.committed
    }

    fn grab(&mut self) -> Vec<f32> {
        self.pool.pop().unwrap_or_default()
    }

    fn recycle(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        self.pool.push(buf);
    }

    fn recycle_queue(&mut self, j: usize) {
        while let Some(buf) = self.queues[j].pop_front() {
            self.recycle(buf);
        }
    }
}

/// Polybasic decode as a resumable state machine. `models[0]` is the
/// target `M_1`, `models[n-1]` the drafter `M_n`.
///
/// # Graceful degradation
///
/// Only the target's verification determines the output distribution, so
/// every other chain member is disposable for correctness: when a drafter
/// errors or its health breaker opens, [`drop_member`](Self::drop_member)
/// removes it at the step boundary and the decode continues on the shorter
/// chain — polybasic shrinks toward dualistic and ultimately plain
/// autoregressive (`n == 1`) instead of failing the request. In-flight
/// speculation is discarded on a drop, which is distribution-free (those
/// tokens were never committed) and keeps deterministic rules
/// byte-identical to a fault-free run. Only a target failure propagates.
pub struct PolyTask<'m> {
    models: Vec<&'m dyn LanguageModel>,
    sessions: Vec<Box<dyn ScoringSession + 'm>>,
    cfg: PolyConfig,
    rng: Pcg32,
    scratch: FilterScratch,
    pipe: Pipeline,
    prompt_len: usize,
    seq_cap: usize,
    accept_lengths: Vec<u32>,
    stage_accepts: Vec<Vec<u32>>,
    meter: StepMeter,
    /// Dispatch-chain indices of the surviving members (`live_models[0] ==
    /// 0` always: the target cannot be dropped).
    live_models: Vec<usize>,
    /// Length of the chain the task was dispatched on; `dispatch_n -
    /// models.len()` is the degradation count.
    dispatch_n: usize,
    /// Failure delivered by [`DecodeTask::absorb_append`], surfaced by the
    /// next `step` exactly like the equivalent in-step append failure.
    pending_fault: Option<anyhow::Error>,
}

/// Why a step could not complete normally.
enum StepError {
    /// The target (or a fully-degraded chain) failed: the request fails.
    Fatal(anyhow::Error),
    /// Live-chain member `idx` (never 0) failed: drop it and continue.
    Member { idx: usize, source: anyhow::Error },
}

impl<'m> PolyTask<'m> {
    pub fn new(
        models: &'m [Arc<dyn LanguageModel>],
        prompt: &[Token],
        cfg: PolyConfig,
    ) -> Result<Self> {
        let n = models.len();
        anyhow::ensure!(n >= 2, "polybasic needs at least two models");
        anyhow::ensure!(cfg.thresholds.len() == n - 1, "need one threshold per verifier");
        // A fresh task skips drafters whose breaker is already open rather
        // than opening sessions doomed to fail on the first append.
        let want: Vec<usize> =
            (0..n).filter(|&i| i == 0 || models[i].healthy()).collect();
        let (task, _dropped) = Self::build(models, prompt, cfg, want)?;
        Ok(task)
    }

    /// Construct on the `want` subset of the dispatch chain (ascending,
    /// starting with 0 = target). Drafters whose session fails to open are
    /// dropped on the spot; the returned vec holds their *positions in
    /// `want`* so `resume` can subset its saved per-model stats to match.
    fn build(
        models: &'m [Arc<dyn LanguageModel>],
        prompt: &[Token],
        mut cfg: PolyConfig,
        mut want: Vec<usize>,
    ) -> Result<(Self, Vec<usize>)> {
        let dispatch_n = models.len();
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(cfg.draft_k >= 1, "draft_k must be >= 1");
        anyhow::ensure!(
            !want.is_empty() && want[0] == 0,
            "live chain must include the target"
        );
        anyhow::ensure!(
            // xtask:allow(panic): the caller guard above proves `want` non-empty.
            want.windows(2).all(|w| w[0] < w[1]) && *want.last().unwrap() < dispatch_n,
            "live chain indices must be ascending dispatch indices"
        );

        // Open a session per surviving member. A drafter whose open fails
        // is degradation, not an error; a target failure is fatal. Each
        // retry restarts from scratch — dropped session boxes close their
        // engine sessions, so nothing leaks.
        let mut dropped: Vec<usize> = Vec::new();
        let mut sessions: Vec<Box<dyn ScoringSession + 'm>>;
        'open: loop {
            sessions = Vec::with_capacity(want.len());
            for (pos, &idx) in want.iter().enumerate() {
                match models[idx].open_session() {
                    Ok(s) => sessions.push(s),
                    Err(e) if idx == 0 => {
                        return Err(e.context("opening target session"));
                    }
                    Err(_) => {
                        want.remove(pos);
                        dropped.push(pos);
                        continue 'open;
                    }
                }
            }
            break;
        }
        // `dropped` holds positions relative to the shrinking list; map to
        // positions in the *original* want order (ascending adjustment).
        for i in (0..dropped.len()).rev() {
            for j in (0..i).rev() {
                if dropped[j] <= dropped[i] {
                    dropped[i] += 1;
                }
            }
        }

        let k = want.len();
        // Per-verifier thresholds for the live chain: each surviving
        // verifier keeps its own dispatch-chain threshold (the last live
        // member is the pure drafter and has none).
        let live_thresholds: Vec<usize> = want[..k.saturating_sub(1)]
            .iter()
            .map(|&i| cfg.thresholds[i.min(dispatch_n.saturating_sub(2))].max(1))
            .collect();
        cfg.thresholds = live_thresholds;

        let live_refs: Vec<&'m dyn LanguageModel> =
            want.iter().map(|&i| models[i].as_ref()).collect();
        // xtask:allow(panic): the live chain always contains the target.
        let seq_cap = live_refs.iter().map(|m| m.seq_len()).min().unwrap();
        anyhow::ensure!(
            prompt.len() + cfg.max_new + cfg.headroom() <= seq_cap,
            "prompt {} + max_new {} + pipeline headroom {} exceeds context {}",
            prompt.len(),
            cfg.max_new,
            cfg.headroom(),
            seq_cap
        );

        let task = Self {
            models: live_refs,
            sessions,
            rng: Pcg32::seeded(cfg.sampling.seed),
            cfg,
            scratch: FilterScratch::default(),
            pipe: Pipeline {
                flat: prompt.to_vec(),
                committed: prompt.len(),
                queues: (0..k.saturating_sub(1)).map(|_| VecDeque::new()).collect(),
                pool: Vec::new(),
            },
            prompt_len: prompt.len(),
            seq_cap,
            accept_lengths: Vec::new(),
            stage_accepts: vec![Vec::new(); k.saturating_sub(1)],
            meter: StepMeter::new(k),
            live_models: want,
            dispatch_n,
            pending_fault: None,
        };
        Ok((task, dropped))
    }

    /// Re-open a suspended decode from `prompt + state`; see
    /// [`DecodeTask::suspend`]. Unlike the single-round task types, the
    /// polybasic pipeline carries uncommitted drafts and their proposal
    /// distributions across steps, so the suspended pipeline suffix is
    /// restored wholesale — the fresh sessions re-score the whole frontier
    /// on the next `reconcile`, after which decode continues
    /// byte-identically to an uninterrupted run. A task that degraded
    /// before suspension resumes on its surviving subset
    /// (`state.live_models`) of the dispatch chain.
    pub fn resume(
        models: &'m [Arc<dyn LanguageModel>],
        prompt: &[Token],
        cfg: PolyConfig,
        state: ResumeState,
    ) -> Result<Self> {
        anyhow::ensure!(models.len() >= 2, "polybasic needs at least two models");
        anyhow::ensure!(
            cfg.thresholds.len() == models.len() - 1,
            "need one threshold per verifier"
        );
        anyhow::ensure!(
            state.committed.len() <= cfg.max_new,
            "resume state carries {} tokens for a budget of {}",
            state.committed.len(),
            cfg.max_new
        );
        let want: Vec<usize> = if state.live_models.is_empty() {
            (0..models.len()).collect()
        } else {
            state.live_models.clone()
        };
        anyhow::ensure!(
            state.forward_passes.len() == want.len(),
            "resume state covers {} models, live chain has {}",
            state.forward_passes.len(),
            want.len()
        );
        anyhow::ensure!(
            state.stage_accepts.len() == want.len().saturating_sub(1),
            "resume state covers {} verifier stages, live chain has {}",
            state.stage_accepts.len(),
            want.len().saturating_sub(1)
        );
        let want_len = want.len();
        // NOTE: resume does not pre-filter unhealthy drafters — the first
        // step's health sweep drops them through the normal path, keeping
        // the saved per-model stats aligned. Only open *failures* force a
        // subset here.
        let (mut task, dropped) = Self::build(models, prompt, cfg, want)?;

        let mut passes = state.forward_passes;
        let mut times = state.forward_time;
        let mut stage_accepts = state.stage_accepts;
        let mut k = want_len;
        // Mirror drop_member's index arithmetic, highest position first.
        let mut drop_desc = dropped.clone();
        drop_desc.sort_unstable_by(|a, b| b.cmp(a));
        for &p in &drop_desc {
            passes.remove(p);
            times.remove(p);
            stage_accepts.remove(p.min(k - 2));
            k -= 1;
        }

        task.pipe.flat.extend_from_slice(&state.committed);
        task.pipe.committed += state.committed.len();
        match state.inflight {
            InflightState::None => {}
            InflightState::Polybasic { .. } if !dropped.is_empty() => {
                // The chain shrank between suspend and resume: the saved
                // speculation references queues that no longer line up.
                // Discard it — uncommitted drafts are free to drop.
            }
            InflightState::Polybasic { drafted, queues } => {
                anyhow::ensure!(
                    queues.len() == task.sessions.len() - 1,
                    "in-flight state covers {} queues, live chain has {}",
                    queues.len(),
                    task.sessions.len() - 1
                );
                anyhow::ensure!(
                    drafted.len() == queues.iter().map(|q| q.len()).sum::<usize>(),
                    "in-flight tokens and proposal queues disagree"
                );
                task.pipe.flat.extend_from_slice(&drafted);
                task.pipe.queues = queues;
            }
        }
        task.rng = state.rng;
        task.accept_lengths = state.accept_lengths;
        task.stage_accepts = stage_accepts;
        task.meter = StepMeter::resumed(state.wall, passes, times);
        Ok(task)
    }

    /// Drop live-chain member `d` (never the target) at a step boundary:
    /// discard all in-flight speculation, close its session, and shrink
    /// every per-member structure in lockstep. Distribution-free — see the
    /// type-level docs.
    fn drop_member(&mut self, d: usize) {
        let n = self.models.len();
        debug_assert!(d > 0, "the target is never dropped");
        debug_assert!(d < n && n >= 2);
        // Uncommitted speculation is discarded wholesale: it is equivalent
        // to never having proposed those tokens, so the committed-token
        // distribution (and greedy byte-identity) is untouched.
        self.pipe.flat.truncate(self.pipe.committed);
        for j in 0..self.pipe.queues.len() {
            self.pipe.recycle_queue(j);
        }
        self.models.remove(d);
        self.sessions.remove(d); // Box drop closes the engine session
        self.meter.drop_model(d);
        let t = d.min(n - 2);
        self.cfg.thresholds.remove(t);
        self.stage_accepts.remove(t);
        self.pipe.queues.remove(t);
        self.live_models.remove(d);
    }

    /// One drafting burst + one verification sweep on the current live
    /// chain (the `n == 1` case is plain autoregressive decode). Metering
    /// brackets the body on every path, including member failures.
    fn step_live(&mut self) -> Result<(), StepError> {
        let Self {
            models,
            sessions,
            cfg,
            rng,
            scratch,
            pipe,
            prompt_len,
            seq_cap,
            accept_lengths,
            stage_accepts,
            meter,
            ..
        } = self;
        meter.begin(models);
        let r = step_body(
            sessions,
            cfg,
            rng,
            scratch,
            pipe,
            *prompt_len,
            *seq_cap,
            accept_lengths,
            stage_accepts,
        );
        meter.end(models);
        r
    }
}

impl DecodeTask for PolyTask<'_> {
    fn committed(&self) -> &[Token] {
        let end = (self.prompt_len + self.cfg.max_new).min(self.pipe.committed);
        &self.pipe.flat[self.prompt_len..end]
    }

    fn finished(&self) -> bool {
        self.pipe.committed - self.prompt_len >= self.cfg.max_new
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.finished() {
            return Ok(StepOutcome::Finished { new_tokens: 0 });
        }
        if let Some(e) = self.pending_fault.take() {
            // A batched pre-append failed. Same trichotomy as in-step: a
            // drafter failure drops that member, a target failure (only
            // possible once fully degraded) fails the request.
            let n = self.models.len();
            if n > 1 {
                self.drop_member(n - 1);
                return Ok(StepOutcome::Progress { new_tokens: 0 });
            }
            return Err(e);
        }
        // Proactive health sweep: drop drafters whose breaker opened (e.g.
        // another task's calls tripped it) before spending calls on them.
        let mut d = self.models.len();
        while d > 1 {
            d -= 1;
            if !self.models[d].healthy() {
                self.drop_member(d);
            }
        }
        let before = self.committed().len();
        match self.step_live() {
            Ok(()) => {}
            Err(StepError::Member { idx, source: _ }) => {
                // A drafter failed mid-step: drop it and report zero
                // progress; the next step continues on the shorter chain.
                self.drop_member(idx);
            }
            Err(StepError::Fatal(e)) => return Err(e),
        }
        let new_tokens = self.committed().len() - before;
        if self.finished() {
            Ok(StepOutcome::Finished { new_tokens })
        } else {
            Ok(StepOutcome::Progress { new_tokens })
        }
    }

    fn finish(self: Box<Self>) -> GenerationOutput {
        let end = (self.prompt_len + self.cfg.max_new).min(self.pipe.committed);
        let tokens = self.pipe.flat[self.prompt_len..end].to_vec();
        let accept_lengths = self.accept_lengths;
        let stage_accept_lengths = self.stage_accepts;
        let degraded = (self.dispatch_n - self.models.len()) as u32;
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        GenerationOutput {
            tokens,
            wall,
            forward_passes,
            forward_time,
            accept_lengths,
            stage_accept_lengths,
            degraded,
        }
    }

    fn suspend(self: Box<Self>) -> ResumeState {
        let committed = self.pipe.flat[self.prompt_len..self.pipe.committed].to_vec();
        let drafted = self.pipe.flat[self.pipe.committed..].to_vec();
        let queues = self.pipe.queues;
        let degraded = (self.dispatch_n - self.models.len()) as u32;
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        ResumeState {
            committed,
            rng: self.rng,
            accept_lengths: self.accept_lengths,
            stage_accepts: self.stage_accepts,
            wall,
            forward_passes,
            forward_time,
            inflight: if drafted.is_empty() {
                InflightState::None
            } else {
                InflightState::Polybasic { drafted, queues }
            },
            live_models: self.live_models,
            degraded,
            swap: None,
        }
    }

    fn degraded(&self) -> u32 {
        (self.dispatch_n - self.models.len()) as u32
    }

    fn plan_append(&mut self) -> Option<PlannedAppend> {
        if self.finished() || self.pending_fault.is_some() {
            return None;
        }
        let n = self.models.len();
        if (1..n).any(|d| !self.models[d].healthy()) {
            return None; // the next step's health sweep reshapes the chain
        }
        // Fully degraded: the next step is an autoregressive target
        // reconcile against `flat`.
        if n == 1 {
            let sess = &self.sessions[0];
            let handle = sess.batch_handle()?;
            let have = sess.len();
            if have >= self.pipe.flat.len() || sess.tokens() != &self.pipe.flat[..have] {
                return None;
            }
            return Some(PlannedAppend {
                model_key: model_key(self.models[0]),
                handle,
                tokens: Arc::from(&self.pipe.flat[have..]),
                prefix_len: have,
            });
        }
        // Otherwise the next step's first engine call is the deepest
        // drafter's catch-up reconcile — but only when the step will open
        // with a drafting burst (mirrors step_body's gate; flush mode and
        // a full deepest queue open with a verify instead, which is never
        // a pure append).
        let committed = self.pipe.committed - self.prompt_len;
        let remaining = self.cfg.max_new - committed;
        let in_flight = self.pipe.in_flight();
        let draft_room = self.seq_cap.saturating_sub(self.pipe.flat.len());
        let flush = in_flight >= remaining || draft_room == 0;
        let deepest = n - 2;
        let want = self.cfg.draft_k.min(remaining.saturating_sub(in_flight)).min(draft_room);
        if flush || want == 0 || self.pipe.queues[deepest].len() >= self.cfg.thresholds[deepest].max(1)
        {
            return None;
        }
        let dsess = &self.sessions[n - 1];
        let handle = dsess.batch_handle()?;
        let have = dsess.len();
        if have >= self.pipe.flat.len() || dsess.tokens() != &self.pipe.flat[..have] {
            return None; // rollback-first reconcile: not a pure append
        }
        Some(PlannedAppend {
            model_key: model_key(self.models[n - 1]),
            handle,
            tokens: Arc::from(&self.pipe.flat[have..]),
            prefix_len: have,
        })
    }

    fn absorb_append(&mut self, rows: Result<Option<Logits>>) {
        let n = self.models.len();
        let idx = if n == 1 { 0 } else { n - 1 };
        let sess = &mut self.sessions[idx];
        let have = sess.len();
        let suffix: Vec<Token> = self.pipe.flat[have..].to_vec();
        match rows.and_then(|r| sess.absorb_batched(&suffix, r)) {
            // The batch charged the model counters once; per-task pass
            // accounting stays solo-equivalent via an explicit charge.
            Ok(()) => self.meter.charge(idx, Duration::ZERO),
            Err(e) => self.pending_fault = Some(e),
        }
    }
}

/// Generate with a polybasic chain, driven to completion. `models[0]` is
/// the target `M_1`, `models[n-1]` the drafter `M_n`.
pub fn generate(
    models: &[Arc<dyn LanguageModel>],
    prompt: &[Token],
    cfg: &PolyConfig,
) -> Result<GenerationOutput> {
    for m in models {
        m.reset_counters();
    }
    let mut task = PolyTask::new(models, prompt, cfg.clone())?;
    while !task.finished() {
        task.step()?;
    }
    Ok(Box::new(task).finish())
}

/// One decode round on the live chain: a drafting burst, a threshold-gated
/// verification sweep, and the deadlock backstop. Errors are classified by
/// the member that raised them so the task can degrade instead of failing.
/// Every fallible call fails *before* mutating the pipeline for its
/// iteration, so a `Member` error always leaves the pipeline consistent.
#[allow(clippy::too_many_arguments)]
fn step_body(
    sessions: &mut [Box<dyn ScoringSession + '_>],
    cfg: &PolyConfig,
    rng: &mut Pcg32,
    scratch: &mut FilterScratch,
    pipe: &mut Pipeline,
    prompt_len: usize,
    seq_cap: usize,
    accept_lengths: &mut Vec<u32>,
    stage_accepts: &mut [Vec<u32>],
) -> Result<(), StepError> {
    let n = sessions.len();
    let committed = pipe.committed - prompt_len;
    let remaining = cfg.max_new - committed;

    // ---- 0. fully degraded: plain autoregressive on the target -------
    if n == 1 {
        reconcile(&mut *sessions[0], &pipe.flat).map_err(StepError::Fatal)?;
        let mut p = pipe.grab();
        dist_row_into(sessions[0].row(pipe.flat.len() - 1), &cfg.sampling, scratch, &mut p);
        let tok = pick(&mut p, &cfg.sampling, cfg.rule, rng);
        pipe.recycle(p);
        pipe.flat.push(tok);
        pipe.committed += 1;
        accept_lengths.push(1);
        return Ok(());
    }

    let in_flight = pipe.in_flight();
    // Flush mode: the pipeline already holds enough tokens to finish the
    // request (or drafting would overflow the context) — stop drafting
    // and fire every non-empty stage regardless of thresholds.
    let draft_room = seq_cap.saturating_sub(pipe.flat.len());
    let flush = in_flight >= remaining || draft_room == 0;

    let mut fired = false;

    // ---- 1. draft with M_n into the deepest queue --------------------
    let deepest = n - 2;
    if !flush && pipe.queues[deepest].len() < cfg.thresholds[deepest].max(1) {
        let want = cfg.draft_k.min(remaining.saturating_sub(in_flight)).min(draft_room);
        if want > 0 {
            let dsess = &mut sessions[n - 1];
            for _ in 0..want {
                // Score up to the frontier (a single incremental append
                // in the steady state) and sample the next draft.
                reconcile(&mut **dsess, &pipe.flat)
                    .map_err(|e| StepError::Member { idx: n - 1, source: e })?;
                let mut q = pipe.grab();
                dist_row_into(dsess.row(pipe.flat.len() - 1), &cfg.sampling, scratch, &mut q);
                let tok = pick(&mut q, &cfg.sampling, cfg.rule, rng);
                pipe.queues[deepest].push_back(q);
                pipe.flat.push(tok);
            }
            fired = true;
        }
    }

    // ---- 2. verification sweep, deepest stage first ------------------
    let mut budget_reached = false;
    for j in (0..n - 1).rev() {
        if pipe.queues[j].is_empty() {
            continue;
        }
        let ready = pipe.queues[j].len() >= cfg.thresholds[j];
        if !(ready || flush) {
            continue;
        }
        let committed_now = verify_stage(&mut *sessions[j], j, pipe, cfg, rng, scratch, stage_accepts)
            .map_err(|e| member_or_fatal(j, e))?;
        fired = true;
        if j == 0 {
            accept_lengths.push(committed_now as u32);
            if pipe.committed - prompt_len >= cfg.max_new {
                budget_reached = true;
                break;
            }
        }
    }

    // ---- 3. deadlock backstop ----------------------------------------
    if !fired && !budget_reached {
        // Nothing met its threshold and drafting was blocked: force the
        // deepest non-empty stage (guaranteed progress).
        if let Some(j) = (0..n - 1).rev().find(|&j| !pipe.queues[j].is_empty()) {
            let committed_now =
                verify_stage(&mut *sessions[j], j, pipe, cfg, rng, scratch, stage_accepts)
                    .map_err(|e| member_or_fatal(j, e))?;
            if j == 0 {
                accept_lengths.push(committed_now as u32);
            }
        } else {
            return Err(StepError::Fatal(anyhow::anyhow!(
                "decode stalled: empty pipeline but no draft room"
            )));
        }
    }
    Ok(())
}

/// Stage-0 (target) failures are fatal; any other stage degrades.
fn member_or_fatal(j: usize, e: anyhow::Error) -> StepError {
    if j == 0 {
        StepError::Fatal(e)
    } else {
        StepError::Member { idx: j, source: e }
    }
}

/// Run verifier `j` over its queue through its incremental session: sync
/// the session to the block's prefix (rollback + one append), verify
/// sequentially with lazily materialized verifier rows, and splice the
/// outcome into the pipeline. Returns the number of tokens committed
/// (non-zero only for `j == 0`).
#[allow(clippy::too_many_arguments)]
fn verify_stage<S: ScoringSession + ?Sized>(
    session: &mut S,
    j: usize,
    pipe: &mut Pipeline,
    cfg: &PolyConfig,
    rng: &mut Pcg32,
    scratch: &mut FilterScratch,
    stage_accepts: &mut [Vec<u32>],
) -> Result<usize> {
    let base = pipe.start(j);
    let len = pipe.queues[j].len();
    reconcile(session, &pipe.flat[..base + len])?;

    // Sequential verification; rows after the first rejection are never
    // computed. `emitted_q` collects the verifier rows that become the
    // emitted tokens' proposal distributions at stage j-1.
    let mut accepted = 0usize;
    let mut replacement: Option<Token> = None;
    let mut emitted_q: Vec<Vec<f32>> = Vec::with_capacity(len + 1);
    for i in 0..len {
        let mut p = pipe.grab();
        dist_row_into(session.row(base - 1 + i), &cfg.sampling, scratch, &mut p);
        match verify_token(pipe.flat[base + i], &p, &pipe.queues[j][i], cfg.rule, rng) {
            TokenVerdict::Accepted => {
                emitted_q.push(p);
                accepted += 1;
            }
            TokenVerdict::Rejected { replacement: r } => {
                // The rejected position's verifier row is exactly the
                // replacement token's proposal distribution.
                emitted_q.push(p);
                replacement = Some(r);
                break;
            }
        }
    }
    stage_accepts[j].push(accepted as u32);

    if let Some(r) = replacement {
        // A rejection invalidates every later position in the pipeline:
        // truncate the logical sequence and drop this + all deeper queues.
        pipe.flat.truncate(base + accepted);
        pipe.flat.push(r);
        for q in j..pipe.queues.len() {
            pipe.recycle_queue(q);
        }
    } else {
        // Full acceptance: free bonus token from the row after the block,
        // inserted at the block boundary (deeper queues shift right by 1).
        let mut p = pipe.grab();
        dist_row_into(session.row(base + len - 1), &cfg.sampling, scratch, &mut p);
        let bonus = pick(&mut p, &cfg.sampling, cfg.rule, rng);
        pipe.flat.insert(base + len, bonus);
        emitted_q.push(p);
        pipe.recycle_queue(j);
    }

    if j == 0 {
        let committed_now = accepted + 1;
        pipe.committed += committed_now;
        for q in emitted_q {
            pipe.recycle(q);
        }
        Ok(committed_now)
    } else {
        for q in emitted_q {
            pipe.queues[j - 1].push_back(q);
        }
        Ok(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::autoregressive;
    use crate::spec::mock::{mock_chain, MockModel};
    use crate::spec::types::ForceStateless;

    fn greedy_cfg(n: usize, max_new: usize) -> PolyConfig {
        let mut cfg = PolyConfig::for_chain(n, 4, 4, max_new);
        cfg.rule = VerifyRule::Greedy;
        cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
        cfg
    }

    #[test]
    fn greedy_three_model_matches_target_greedy() {
        // THE lossless-cascade correctness check: committed output must be
        // token-for-token the target's own greedy decode.
        let chain = mock_chain(512, 24, 11);
        let cfg = greedy_cfg(3, 48);
        let out = generate(&chain, &[3, 1, 4], &cfg).unwrap();
        let ar = autoregressive::generate(
            chain[0].as_ref(),
            &[3, 1, 4],
            48,
            &cfg.sampling,
        )
        .unwrap();
        assert_eq!(out.tokens, ar.tokens);
    }

    #[test]
    fn greedy_four_model_matches_target_greedy() {
        let mut chain = mock_chain(512, 24, 13);
        chain.push(Arc::new(MockModel::new("mock-tiny", 512, 24, 13, 1.4)));
        let cfg = greedy_cfg(4, 40);
        let out = generate(&chain, &[9, 2], &cfg).unwrap();
        let ar = autoregressive::generate(chain[0].as_ref(), &[9, 2], 40, &cfg.sampling)
            .unwrap();
        assert_eq!(out.tokens, ar.tokens);
    }

    #[test]
    fn produces_exact_length() {
        let chain = mock_chain(512, 24, 7);
        let cfg = PolyConfig::for_chain(3, 5, 6, 33);
        let out = generate(&chain, &[1, 2], &cfg).unwrap();
        assert_eq!(out.tokens.len(), 33);
    }

    #[test]
    fn target_forwards_fewer_than_tokens() {
        let chain = mock_chain(512, 24, 7);
        let cfg = PolyConfig::for_chain(3, 4, 6, 64);
        let out = generate(&chain, &[1, 2], &cfg).unwrap();
        assert!(
            out.forward_passes[0] < 64 / 2,
            "target forwards {:?}",
            out.forward_passes
        );
        assert!(out.mean_accept() > 2.0, "mu {}", out.mean_accept());
    }

    #[test]
    fn n2_matches_dualistic_statistics() {
        // polybasic with n=2 should behave like the dedicated dualistic
        // implementation (same acceptance behaviour, exact greedy equality).
        let chain = mock_chain(512, 24, 19);
        let two: Vec<Arc<dyn LanguageModel>> = vec![chain[0].clone(), chain[2].clone()];
        let mut cfg = PolyConfig::for_chain(2, 4, 4, 40);
        cfg.rule = VerifyRule::Greedy;
        cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
        let poly = generate(&two, &[8, 8], &cfg).unwrap();
        let dual = crate::spec::dualistic::generate(
            chain[0].as_ref(),
            chain[2].as_ref(),
            &[8, 8],
            &crate::spec::dualistic::DualisticConfig {
                draft_k: 4,
                rule: VerifyRule::Greedy,
                sampling: cfg.sampling,
                max_new: 40,
            },
        )
        .unwrap();
        assert_eq!(poly.tokens, dual.tokens);
    }

    #[test]
    fn speculative_sampling_reproducible() {
        let chain = mock_chain(512, 24, 23);
        let mut cfg = PolyConfig::for_chain(3, 4, 6, 32);
        cfg.sampling.seed = 77;
        let a = generate(&chain, &[5], &cfg).unwrap();
        let b = generate(&chain, &[5], &cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn session_decode_identical_to_stateless() {
        // Cached sessions vs the StatelessSession fallback on the same
        // weights: outputs and per-stage forward accounting must agree.
        let mk = |stateless: bool| -> Vec<Arc<dyn LanguageModel>> {
            [("mock-target", 0.0f32), ("mock-mid", 0.35), ("mock-draft", 0.8)]
                .iter()
                .map(|&(name, noise)| -> Arc<dyn LanguageModel> {
                    let m = MockModel::new(name, 512, 24, 29, noise);
                    if stateless {
                        Arc::new(ForceStateless(m))
                    } else {
                        Arc::new(m)
                    }
                })
                .collect()
        };
        let mut cfg = PolyConfig::for_chain(3, 4, 6, 48);
        cfg.sampling.seed = 5;
        let cached = generate(&mk(false), &[2, 4, 6], &cfg).unwrap();
        let stateless = generate(&mk(true), &[2, 4, 6], &cfg).unwrap();
        assert_eq!(cached.tokens, stateless.tokens);
        assert_eq!(cached.forward_passes, stateless.forward_passes);
        assert_eq!(cached.accept_lengths, stateless.accept_lengths);
    }

    #[test]
    fn stepped_task_matches_generate_and_streams_monotonically() {
        let chain = mock_chain(512, 24, 41);
        let mut cfg = PolyConfig::for_chain(3, 4, 6, 48);
        cfg.sampling.seed = 9;
        let whole = generate(&chain, &[2, 4, 6], &cfg).unwrap();
        for m in &chain {
            m.reset_counters();
        }
        let mut task = PolyTask::new(&chain, &[2, 4, 6], cfg).unwrap();
        let mut streamed: Vec<Token> = Vec::new();
        while !task.finished() {
            let before = task.committed().len();
            let outcome = task.step().unwrap();
            let after = task.committed().len();
            assert!(after >= before, "committed stream must be monotone");
            assert_eq!(outcome.new_tokens(), after - before);
            streamed.extend_from_slice(&task.committed()[before..]);
        }
        assert_eq!(streamed, whole.tokens, "streamed deltas diverged");
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, whole.tokens);
        assert_eq!(out.forward_passes, whole.forward_passes);
        assert_eq!(out.accept_lengths, whole.accept_lengths);
        assert_eq!(out.stage_accept_lengths, whole.stage_accept_lengths);
    }

    #[test]
    fn suspend_resume_mid_pipeline_is_byte_identical() {
        // Suspend after a step that leaves drafts in flight: the restored
        // pipeline (tokens + proposal distributions + RNG) must continue
        // exactly where the uninterrupted run would have gone.
        for seed in [9u64, 17, 23] {
            let chain = mock_chain(512, 24, 41);
            let mut cfg = PolyConfig::for_chain(3, 4, 6, 48);
            cfg.sampling.seed = seed;
            let whole = generate(&chain, &[2, 4, 6], &cfg).unwrap();
            for suspend_after in 1..5usize {
                let mut task = PolyTask::new(&chain, &[2, 4, 6], cfg.clone()).unwrap();
                for _ in 0..suspend_after {
                    task.step().unwrap();
                }
                let state = Box::new(task).suspend();
                let mut task = PolyTask::resume(&chain, &[2, 4, 6], cfg.clone(), state).unwrap();
                while !task.finished() {
                    task.step().unwrap();
                }
                let out = Box::new(task).finish();
                assert_eq!(
                    out.tokens, whole.tokens,
                    "seed {seed}, suspend after {suspend_after}: resumed decode diverged"
                );
                assert_eq!(out.accept_lengths, whole.accept_lengths, "seed {seed}");
                assert_eq!(out.stage_accept_lengths, whole.stage_accept_lengths, "seed {seed}");
            }
        }
    }

    /// Statistical losslessness: the marginal distribution of the first
    /// generated token under polybasic speculative sampling must match
    /// direct target sampling.
    #[test]
    fn speculative_first_token_distribution_matches_target() {
        let chain = mock_chain(512, 12, 31);
        let prompt = [4, 2, 4];
        let trials = 4000;
        let mut poly_counts = vec![0f64; 12];
        let mut ar_counts = vec![0f64; 12];
        for s in 0..trials {
            let mut cfg = PolyConfig::for_chain(3, 3, 2, 1);
            cfg.sampling.seed = s;
            let out = generate(&chain, &prompt, &cfg).unwrap();
            poly_counts[out.tokens[0] as usize] += 1.0;
            let ar = autoregressive::generate(
                chain[0].as_ref(),
                &prompt,
                1,
                &SamplingParams { seed: s + 500_000, ..Default::default() },
            )
            .unwrap();
            ar_counts[ar.tokens[0] as usize] += 1.0;
        }
        // Total-variation distance between the two empirical distributions.
        let tv: f64 = poly_counts
            .iter()
            .zip(&ar_counts)
            .map(|(&a, &b)| (a - b).abs())
            .sum::<f64>()
            / (2.0 * trials as f64);
        assert!(tv < 0.05, "total variation {tv} too large — lossless property violated?");
    }

    #[test]
    fn rejects_bad_configs() {
        let chain = mock_chain(64, 24, 7);
        let cfg = PolyConfig::for_chain(3, 4, 4, 64); // doesn't fit in 64 ctx
        assert!(generate(&chain, &[1], &cfg).is_err());
        let mut cfg2 = PolyConfig::for_chain(3, 4, 4, 8);
        cfg2.thresholds.pop();
        assert!(generate(&chain, &[1], &cfg2).is_err());
    }

    use crate::spec::chaos::{ChaosModel, Fault};

    /// The mock chain with the member at `idx` replaced by a chaos-wrapped
    /// clone (identical weights, scripted faults).
    fn chaos_chain(
        seed: u64,
        idx: usize,
        faults: &[(u64, Fault)],
    ) -> Vec<Arc<dyn LanguageModel>> {
        let mut chain = mock_chain(512, 24, seed);
        let (name, noise) = [("mock-target", 0.0f32), ("mock-mid", 0.35), ("mock-draft", 0.8)][idx];
        let mut m = ChaosModel::new(MockModel::new(name, 512, 24, seed, noise));
        for &(at, f) in faults {
            m = m.fault_at(at, f);
        }
        chain[idx] = Arc::new(m);
        chain
    }

    #[test]
    fn drafter_fault_degrades_and_stays_greedy_identical() {
        let cfg = greedy_cfg(3, 48);
        let clean = generate(&mock_chain(512, 24, 11), &[3, 1, 4], &cfg).unwrap();
        // The drafter dies mid-decode; the task must shrink the chain and
        // still produce the target's greedy decode byte-for-byte.
        let faulty = chaos_chain(11, 2, &[(6, Fault::Lost)]);
        let out = generate(&faulty, &[3, 1, 4], &cfg).unwrap();
        assert_eq!(out.tokens, clean.tokens, "degradation changed greedy output");
        assert_eq!(out.degraded, 1);
        assert_eq!(out.forward_passes.len(), 2, "stats cover the surviving chain");
    }

    #[test]
    fn all_drafters_dead_degrades_to_autoregressive() {
        let cfg = greedy_cfg(3, 32);
        let mut faulty = chaos_chain(11, 1, &[(2, Fault::Lost)]);
        faulty[2] = {
            let m = ChaosModel::new(MockModel::new("mock-draft", 512, 24, 11, 0.8))
                .fault_at(0, Fault::Lost);
            Arc::new(m)
        };
        let out = generate(&faulty, &[9, 2], &cfg).unwrap();
        let ar =
            autoregressive::generate(faulty[0].as_ref(), &[9, 2], 32, &cfg.sampling).unwrap();
        assert_eq!(out.tokens, ar.tokens, "fully degraded chain must match target AR");
        assert_eq!(out.degraded, 2);
        assert_eq!(out.tokens.len(), 32, "budget still fully committed");
    }

    #[test]
    fn target_fault_fails_the_request() {
        let cfg = greedy_cfg(3, 32);
        let faulty = chaos_chain(11, 0, &[(0, Fault::Lost)]);
        assert!(generate(&faulty, &[1, 2], &cfg).is_err(), "target loss must fail");
    }

    #[test]
    fn transient_drafter_fault_drops_member_once() {
        // A single clean-error blip also drops the member (the task does
        // not retry drafters — the engine boundary owns retries); output
        // stays greedy-identical.
        let cfg = greedy_cfg(3, 40);
        let clean = generate(&mock_chain(512, 24, 13), &[7], &cfg).unwrap();
        let faulty = chaos_chain(13, 2, &[(3, Fault::Fail)]);
        let out = generate(&faulty, &[7], &cfg).unwrap();
        assert_eq!(out.tokens, clean.tokens);
        assert_eq!(out.degraded, 1);
    }

    #[test]
    fn degraded_task_suspends_and_resumes_on_subset() {
        let cfg = greedy_cfg(3, 40);
        let clean = generate(&mock_chain(512, 24, 17), &[5, 5], &cfg).unwrap();
        let faulty = chaos_chain(17, 2, &[(1, Fault::Lost)]);
        let mut task = PolyTask::new(&faulty, &[5, 5], cfg.clone()).unwrap();
        while task.degraded() == 0 && !task.finished() {
            task.step().unwrap();
        }
        assert_eq!(task.degraded(), 1, "drafter loss must register before suspension");
        let state = Box::new(task).suspend();
        assert_eq!(state.live_models, vec![0, 1]);
        let mut task = PolyTask::resume(&faulty, &[5, 5], cfg, state).unwrap();
        while !task.finished() {
            task.step().unwrap();
        }
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, clean.tokens, "degraded resume diverged from greedy");
        assert_eq!(out.degraded, 1);
    }

    #[test]
    fn unhealthy_drafter_skipped_at_construction() {
        let faulty = chaos_chain(19, 2, &[(0, Fault::Lost)]);
        // Trip the drafter's breaker before the task is even built.
        let _ = faulty[2].forward(&[1]);
        assert!(!faulty[2].healthy());
        let cfg = greedy_cfg(3, 24);
        let task = PolyTask::new(&faulty, &[4, 2], cfg.clone()).unwrap();
        assert_eq!(task.degraded(), 1, "open-time skip counts as degradation");
        let clean = generate(&mock_chain(512, 24, 19), &[4, 2], &cfg).unwrap();
        let mut task = task;
        while !task.finished() {
            task.step().unwrap();
        }
        assert_eq!(Box::new(task).finish().tokens, clean.tokens);
    }
}
