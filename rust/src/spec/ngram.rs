//! Statistical (non-neural) drafter: an in-context bigram model.
//!
//! CS Drafting (Chen et al. 2023) terminates its vertical cascade with a
//! "statistical language model" so the lowest drafting tier costs ~nothing.
//! This is our equivalent: next-token distribution = smoothed counts of the
//! bigram transitions observed *within the given context*.  It implements
//! [`LanguageModel`] so it can sit at the bottom of any chain.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::types::{LanguageModel, Logits, ModelCounters, Token};

#[derive(Debug)]
pub struct BigramModel {
    name: String,
    seq_len: usize,
    vocab: usize,
    /// Add-k smoothing mass.
    smoothing: f32,
    counters: ModelCounters,
}

impl BigramModel {
    pub fn new(seq_len: usize, vocab: usize) -> Self {
        Self {
            name: "bigram".to_string(),
            seq_len,
            vocab,
            smoothing: 0.05,
            counters: ModelCounters::default(),
        }
    }
}

impl LanguageModel for BigramModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn forward(&self, tokens: &[Token]) -> Result<Logits> {
        anyhow::ensure!(tokens.len() <= self.seq_len, "context too long");
        let start = Instant::now();
        let v = self.vocab;
        // Rolling bigram counts: row t uses transitions seen in tokens[0..=t]
        // (prefix-causal, like every other scorer here).
        let mut counts = vec![0f32; v * v];
        let mut data = Vec::with_capacity(tokens.len() * v);
        for t in 0..tokens.len() {
            if t > 0 {
                let prev = tokens[t - 1] as usize;
                let cur = tokens[t] as usize;
                if prev < v && cur < v {
                    counts[prev * v + cur] += 1.0;
                }
            }
            let cur = tokens[t] as usize;
            let row = &counts[cur * v..(cur + 1) * v];
            let total: f32 = row.iter().sum::<f32>() + self.smoothing * v as f32;
            // Emit log-probabilities (consumers softmax, which is a no-op
            // transform up to temperature on logits = ln p).
            for j in 0..v {
                let p = (row[j] + self.smoothing) / total;
                data.push(p.ln());
            }
        }
        self.counters.record(start.elapsed());
        Ok(Logits::new(data, tokens.len(), v))
    }

    fn calls(&self) -> u64 {
        self.counters.calls()
    }

    fn total_time(&self) -> Duration {
        self.counters.total_time()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::softmax;

    #[test]
    fn favors_observed_transitions() {
        let m = BigramModel::new(64, 8);
        // Context where 3 is always followed by 5.
        let ctx = [3, 5, 1, 3, 5, 2, 3, 5, 3];
        let logits = m.forward(&ctx).unwrap();
        let p = softmax(logits.row(ctx.len() - 1), 1.0);
        let best = p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 5, "dist {p:?}");
    }

    #[test]
    fn rows_are_distributions() {
        let m = BigramModel::new(64, 8);
        let logits = m.forward(&[1, 2, 3]).unwrap();
        for t in 0..3 {
            let p = softmax(logits.row(t), 1.0);
            assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn prefix_causal() {
        let m = BigramModel::new(64, 8);
        let a = m.forward(&[1, 2, 3, 4]).unwrap();
        let b = m.forward(&[1, 2, 3, 7]).unwrap();
        assert_eq!(a.row(2), b.row(2));
    }
}
