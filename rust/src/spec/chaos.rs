//! Deterministic fault injection for the fault-tolerance layer.
//!
//! [`ChaosModel`] wraps any [`LanguageModel`] and injects failures on
//! *scripted call indices*: the wrapper counts every fallible model call
//! (`forward` plus every non-empty session `append`; a batched append
//! claims one index per entry, in batch order) and consults a fault
//! script keyed by that index. Everything is deterministic — same script,
//! same call sequence, same faults — so every fault-tolerance behavior in
//! the serving stack is pinnable in a test.
//!
//! Faults are injected *before* the inner call runs, so an injected append
//! error leaves the wrapped session unchanged (the `ScoringSession`
//! error contract). Value-level output is never perturbed: a call that is
//! not scripted to fail returns the inner model's bits untouched, which is
//! what lets the fault-injection suite assert byte-identical output
//! between faulty and fault-free runs.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::types::{
    FaultKind, HealthConfig, HealthTracker, LanguageModel, Logits, ModelFault, ScoringSession,
    Token,
};

/// What to inject at a scripted call index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// The call fails cleanly ([`FaultKind::Transient`]); the next call is
    /// back to normal.
    Fail,
    /// The call succeeds after an added delay (exercises deadlines that
    /// are generous enough to survive it).
    Latency(Duration),
    /// The call blocks for the given time and then fails with
    /// [`FaultKind::Timeout`] — a stand-in for a deadline expiring on a
    /// hung engine, without needing a real engine thread.
    Hang(Duration),
    /// The backing engine dies: this call and *every* later call against
    /// this model fail with [`FaultKind::Lost`].
    Lost,
}

/// Shared fault state, referenced by the model wrapper and every session
/// it opens (sessions count against the same per-model call index).
struct ChaosState {
    name: String,
    faults: BTreeMap<u64, Fault>,
    calls: AtomicU64,
    lost: AtomicBool,
    health: Arc<HealthTracker>,
}

impl ChaosState {
    /// Claim the next call index and inject its scripted fault, if any.
    fn check(&self) -> anyhow::Result<()> {
        let idx = self.calls.fetch_add(1, Ordering::Relaxed);
        if self.lost.load(Ordering::Relaxed) {
            self.health.record_failure(FaultKind::Lost);
            return Err(self.fault(FaultKind::Lost));
        }
        match self.faults.get(&idx) {
            None => {
                self.health.record_success();
                Ok(())
            }
            Some(Fault::Fail) => {
                self.health.record_failure(FaultKind::Transient);
                Err(self.fault(FaultKind::Transient))
            }
            Some(Fault::Latency(d)) => {
                std::thread::sleep(*d);
                self.health.record_success();
                Ok(())
            }
            Some(Fault::Hang(d)) => {
                std::thread::sleep(*d);
                self.health.record_failure(FaultKind::Timeout);
                Err(self.fault(FaultKind::Timeout))
            }
            Some(Fault::Lost) => {
                self.lost.store(true, Ordering::Relaxed);
                self.health.record_failure(FaultKind::Lost);
                Err(self.fault(FaultKind::Lost))
            }
        }
    }

    fn fault(&self, kind: FaultKind) -> anyhow::Error {
        anyhow::Error::new(ModelFault { kind, model: self.name.clone() })
    }
}

/// Fault-injecting wrapper over any [`LanguageModel`]. Build with
/// [`ChaosModel::new`], script faults with [`fault_at`](Self::fault_at).
pub struct ChaosModel<M: LanguageModel> {
    inner: M,
    state: ChaosState,
}

impl<M: LanguageModel> ChaosModel<M> {
    pub fn new(inner: M) -> Self {
        let name = format!("chaos({})", inner.name());
        Self {
            inner,
            state: ChaosState {
                name,
                faults: BTreeMap::new(),
                calls: AtomicU64::new(0),
                lost: AtomicBool::new(false),
                health: Arc::new(HealthTracker::default()),
            },
        }
    }

    /// Script `fault` for the `idx`-th fallible call (0-based; counts
    /// `forward` and non-empty session appends against this model).
    pub fn fault_at(mut self, idx: u64, fault: Fault) -> Self {
        self.state.faults.insert(idx, fault);
        self
    }

    /// Replace the default health tracker config (e.g. a short cooldown
    /// so tests can watch the breaker reopen).
    pub fn with_health(mut self, config: HealthConfig) -> Self {
        self.state.health = Arc::new(HealthTracker::new(config));
        self
    }

    /// Fallible calls observed so far (next call gets this index).
    pub fn calls_seen(&self) -> u64 {
        self.state.calls.load(Ordering::Relaxed)
    }
}

impl<M: LanguageModel> LanguageModel for ChaosModel<M> {
    fn name(&self) -> &str {
        &self.state.name
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn forward(&self, tokens: &[Token]) -> anyhow::Result<Logits> {
        self.state.check()?;
        self.inner.forward(tokens)
    }

    fn calls(&self) -> u64 {
        self.inner.calls()
    }

    fn total_time(&self) -> Duration {
        self.inner.total_time()
    }

    fn reset_counters(&self) {
        self.inner.reset_counters()
    }

    fn cost_ms(&self) -> f64 {
        self.inner.cost_ms()
    }

    fn open_session(&self) -> anyhow::Result<Box<dyn ScoringSession + '_>> {
        // Opening is host-side bookkeeping here; faults fire on appends.
        let inner = self.inner.open_session()?;
        Ok(Box::new(ChaosSession { inner, state: &self.state }))
    }

    fn healthy(&self) -> bool {
        if self.state.lost.load(Ordering::Relaxed) {
            return false;
        }
        self.state.health.healthy()
    }

    fn health_handle(&self) -> Option<Arc<HealthTracker>> {
        Some(self.state.health.clone())
    }

    fn append_batch(
        &self,
        appends: &[(u64, Arc<[Token]>)],
    ) -> Option<Vec<anyhow::Result<Option<Logits>>>> {
        // Capability probe: an empty batch asks the inner model whether it
        // has a batched path at all (backends answer `Some(vec![])` iff
        // they do) without claiming any fault index. If the answer is
        // `None` the scheduler falls back to per-session appends and the
        // call indices stay aligned with an unbatched fault script.
        self.inner.append_batch(&[])?;
        if appends.is_empty() {
            return Some(Vec::new());
        }
        // Claim one scripted call index per entry, in batch order, before
        // the inner call runs — a faulted entry must leave its session
        // unchanged, exactly like a faulted solo append. Each entry's
        // success/failure feeds the health tracker individually, so one
        // poisoned session in a batch charges one failure, not N.
        let mut slots: Vec<Option<anyhow::Result<Option<Logits>>>> =
            Vec::with_capacity(appends.len());
        let mut survivors = Vec::new();
        for entry in appends {
            match self.state.check() {
                Ok(()) => {
                    slots.push(None);
                    survivors.push(entry.clone());
                }
                Err(e) => slots.push(Some(Err(e))),
            }
        }
        let inner = if survivors.is_empty() {
            Vec::new()
        } else {
            // The probe said the inner model batches; a `None` here would
            // be an inner-model bug and aborts the whole batch.
            self.inner.append_batch(&survivors)?
        };
        let mut inner = inner.into_iter();
        Some(
            slots
                .into_iter()
                .map(|slot| match slot {
                    Some(fault) => fault,
                    None => inner
                        .next()
                        .unwrap_or_else(|| Err(anyhow::anyhow!("batched reply missing an entry"))),
                })
                .collect(),
        )
    }
}

/// Session wrapper: injects the model's scripted faults on appends,
/// delegates everything else untouched.
struct ChaosSession<'m> {
    inner: Box<dyn ScoringSession + 'm>,
    state: &'m ChaosState,
}

impl ScoringSession for ChaosSession<'_> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn tokens(&self) -> &[Token] {
        self.inner.tokens()
    }

    fn append(&mut self, suffix: &[Token]) -> anyhow::Result<()> {
        if suffix.is_empty() {
            return Ok(()); // empty append is a free no-op, not a call
        }
        // Fault before touching the inner session, so an injected error
        // leaves it unchanged (append's error contract).
        self.state.check()?;
        self.inner.append(suffix)
    }

    fn rollback(&mut self, to_len: usize) -> anyhow::Result<()> {
        self.inner.rollback(to_len)
    }

    fn row(&self, pos: usize) -> &[f32] {
        self.inner.row(pos)
    }

    fn batch_handle(&self) -> Option<u64> {
        self.inner.batch_handle()
    }

    fn absorb_batched(&mut self, suffix: &[Token], rows: Option<Logits>) -> anyhow::Result<()> {
        // The batched model call already claimed this session's fault
        // index; absorbing the reply is local bookkeeping, not a call.
        self.inner.absorb_batched(suffix, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::MockModel;

    fn mock() -> MockModel {
        MockModel::new("m", 64, 16, 3, 0.4)
    }

    #[test]
    fn passthrough_is_bit_identical() {
        let clean = mock();
        let chaotic = ChaosModel::new(mock());
        let a = clean.forward(&[1, 2, 3]).unwrap();
        let b = chaotic.forward(&[1, 2, 3]).unwrap();
        for t in 0..3 {
            assert_eq!(a.row(t), b.row(t), "row {t}");
        }
        assert!(chaotic.healthy());
    }

    #[test]
    fn fault_fires_on_scripted_index_only() {
        let m = ChaosModel::new(mock()).fault_at(1, Fault::Fail);
        assert!(m.forward(&[1]).is_ok(), "call 0 clean");
        let err = m.forward(&[1]).unwrap_err();
        let fault = err.downcast_ref::<ModelFault>().expect("typed fault");
        assert_eq!(fault.kind, FaultKind::Transient);
        assert!(m.forward(&[1]).is_ok(), "call 2 clean again");
        assert_eq!(m.calls_seen(), 3);
        assert_eq!(m.health_handle().unwrap().errors(), 1);
    }

    #[test]
    fn session_append_fault_leaves_session_unchanged() {
        let m = ChaosModel::new(mock()).fault_at(1, Fault::Fail);
        let mut sess = m.open_session().unwrap();
        sess.append(&[5, 6]).unwrap(); // call 0
        assert!(sess.append(&[7]).is_err(), "call 1 is the scripted fault");
        assert_eq!(sess.tokens(), &[5, 6], "failed append must not apply");
        assert_eq!(sess.len(), 2);
        sess.append(&[7]).unwrap(); // call 2
        let full = mock().forward(&[5, 6, 7]).unwrap();
        for t in 0..3 {
            assert_eq!(sess.row(t), full.row(t), "row {t}");
        }
        assert!(sess.append(&[]).is_ok(), "empty append never counts as a call");
        assert_eq!(m.calls_seen(), 3);
    }

    #[test]
    fn batched_appends_claim_indices_in_batch_order_and_fault_one_entry() {
        let m = ChaosModel::new(mock()).fault_at(1, Fault::Fail);
        let mut a = m.open_session().unwrap();
        let mut b = m.open_session().unwrap();
        assert!(a.batch_handle().is_some(), "mock sessions advertise a batch handle");
        let entries: Vec<(u64, Arc<[Token]>)> =
            vec![(0, Arc::from(&[5, 6][..])), (0, Arc::from(&[5, 6][..]))];
        let results = m.append_batch(&entries).expect("mock has a batched path");
        assert_eq!(results.len(), 2);
        let rows_a = results[0].as_ref().expect("entry 0 claims index 0: clean").clone();
        a.absorb_batched(&[5, 6], rows_a).unwrap();
        let err = results[1].as_ref().expect_err("entry 1 claims index 1: scripted fault");
        assert_eq!(err.downcast_ref::<ModelFault>().unwrap().kind, FaultKind::Transient);
        assert_eq!(b.len(), 0, "faulted entry leaves its session unchanged");
        assert_eq!(m.calls_seen(), 2, "one fault index per batch entry");
        assert_eq!(m.health_handle().unwrap().errors(), 1, "one failure charged, not N");
        let full = mock().forward(&[5, 6]).unwrap();
        for t in 0..2 {
            assert_eq!(a.row(t), full.row(t), "row {t}");
        }
    }

    #[test]
    fn lost_is_permanent_and_marks_unhealthy() {
        let m = ChaosModel::new(mock()).fault_at(0, Fault::Lost);
        let err = m.forward(&[1]).unwrap_err();
        assert_eq!(err.downcast_ref::<ModelFault>().unwrap().kind, FaultKind::Lost);
        let err = m.forward(&[1]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ModelFault>().unwrap().kind,
            FaultKind::Lost,
            "every later call fails too"
        );
        assert!(!m.healthy());
    }

    #[test]
    fn hang_reports_timeout() {
        let m = ChaosModel::new(mock()).fault_at(0, Fault::Hang(Duration::from_millis(5)));
        let err = m.forward(&[1]).unwrap_err();
        assert_eq!(err.downcast_ref::<ModelFault>().unwrap().kind, FaultKind::Timeout);
        assert_eq!(m.health_handle().unwrap().timeouts(), 1);
    }

    #[test]
    fn breaker_opens_after_consecutive_faults() {
        let m = ChaosModel::new(mock())
            .with_health(HealthConfig { failure_threshold: 2, cooldown: Duration::from_secs(60) })
            .fault_at(0, Fault::Fail)
            .fault_at(1, Fault::Fail);
        let _ = m.forward(&[1]);
        assert!(m.healthy(), "one failure: still below threshold");
        let _ = m.forward(&[1]);
        assert!(!m.healthy(), "streak hit threshold: breaker open");
    }
}
