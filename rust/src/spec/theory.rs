//! The paper's theoretical results as executable code.
//!
//! * [`lemma31_time`] — Lemma 3.1 optimal-inference-time decomposition,
//!   `T = Σ_{i=1}^{n-1} (N/L_i)·T_i + β·(N/L_{n-1})·T_n`.
//! * [`InsertionCheck`] — Theorem 3.2 model-insertion criterion (both
//!   sufficient conditions).
//! * [`accept_len_mean` / `accept_len_variance`] — Theorem 3.3 moments of
//!   the truncated-geometric acceptance length, computed from the exact
//!   pmf, plus [`thm33_variance_paper`], the formula exactly as printed in
//!   the paper (the two are compared in tests/benches; see EXPERIMENTS.md
//!   for the observed discrepancy in the printed algebra).

/// Lemma 3.1: predicted total time for generating `n_tokens` with a chain.
///
/// `l[i]` is the expected acceptance length at verifier `i` (target first,
/// so `l[0] = L_1`); `t[i]` the per-forward cost of model `i` in ms, with
/// `t` one element longer than `l` (the last entry is the drafter's `T_n`);
/// `beta` the drafter scaling factor.
pub fn lemma31_time(n_tokens: f64, l: &[f64], t: &[f64], beta: f64) -> f64 {
    assert_eq!(t.len(), l.len() + 1, "need T_i for every verifier plus the drafter");
    assert!(!l.is_empty());
    let mut total = 0.0;
    for i in 0..l.len() {
        assert!(l[i] > 0.0, "acceptance lengths must be positive");
        total += n_tokens / l[i] * t[i];
    }
    total += beta * n_tokens / l[l.len() - 1] * t[l.len()];
    total
}

/// Theorem 3.2: should `M_new` be inserted between `M_i` and `M_{i+1}`?
///
/// Quantities follow the paper's Table 1 columns:
/// * `t_i`       — per-forward cost of the model above the insertion point;
/// * `t_new`     — per-forward cost of the candidate;
/// * `t_next`    — per-forward cost of the model below (`M_{i+1}`);
/// * `l_i`       — acceptance length of the *current* pair (M_i verifying
///                 M_{i+1} proposals);
/// * `l_i_new`   — acceptance length of M_i verifying M_new proposals;
/// * `l_new`     — acceptance length of M_new verifying M_{i+1} proposals;
/// * `beta`      — drafter scaling factor.
#[derive(Debug, Clone, Copy)]
pub struct InsertionCheck {
    pub t_i: f64,
    pub t_new: f64,
    pub t_next: f64,
    pub l_i: f64,
    pub l_i_new: f64,
    pub l_new: f64,
    pub beta: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct InsertionVerdict {
    /// LHS/RHS of condition 1: `T_new/T_i < L_new (1/L_i - 1/L_{i-new})`.
    pub cond1_lhs: f64,
    pub cond1_rhs: f64,
    pub cond1: bool,
    /// LHS/RHS of condition 2: `T_new/T_{i+1} < β (L_{new-(i+1)}/L_i - 1)`.
    pub cond2_lhs: f64,
    pub cond2_rhs: f64,
    pub cond2: bool,
}

impl InsertionVerdict {
    /// Either sufficient condition predicts an end-to-end improvement.
    pub fn predicts_improvement(&self) -> bool {
        self.cond1 || self.cond2
    }
}

impl InsertionCheck {
    pub fn evaluate(&self) -> InsertionVerdict {
        // Condition 1 (paper's first display): the new model's cost relative
        // to the model above is paid for by the acceptance-length increase
        // seen from above.
        let cond1_lhs = self.t_new / self.t_i;
        let cond1_rhs = self.l_new * (1.0 / self.l_i - 1.0 / self.l_i_new);
        // Condition 2: relative to the model below; `L_new` here plays the
        // paper's `L_{new-(i+1)}` (acceptance of the pair M_new / M_{i+1}).
        let cond2_lhs = self.t_new / self.t_next;
        let cond2_rhs = self.beta * (self.l_new / self.l_i - 1.0);
        InsertionVerdict {
            cond1_lhs,
            cond1_rhs,
            cond1: cond1_lhs < cond1_rhs,
            cond2_lhs,
            cond2_rhs,
            cond2: cond2_lhs < cond2_rhs,
        }
    }
}

// ---------------------------------------------------------------------------
// Theorem 3.3 — acceptance-length distribution under speculative sampling.
//
// Convention: per-token acceptance probability p = 1 - alpha; a draft block
// allows up to `n` tokens. The acceptance length N is
//   P(N = k) = p^k (1 - p)   for k = 0..n-1,     P(N = n) = p^n.
// ---------------------------------------------------------------------------

/// Exact pmf of the (capped) acceptance length.
pub fn accept_len_pmf(p: f64, n: usize) -> Vec<f64> {
    assert!((0.0..=1.0).contains(&p));
    let mut pmf = Vec::with_capacity(n + 1);
    for k in 0..n {
        pmf.push(p.powi(k as i32) * (1.0 - p));
    }
    pmf.push(p.powi(n as i32));
    pmf
}

/// E[N] from the exact pmf. Closed form: `p (1 - p^n) / (1 - p)`.
pub fn accept_len_mean(p: f64, n: usize) -> f64 {
    accept_len_pmf(p, n).iter().enumerate().map(|(k, &pr)| k as f64 * pr).sum()
}

/// Var[N] from the exact pmf (the quantity Theorem 3.3 characterizes).
pub fn accept_len_variance(p: f64, n: usize) -> f64 {
    let pmf = accept_len_pmf(p, n);
    let mean: f64 = pmf.iter().enumerate().map(|(k, &pr)| k as f64 * pr).sum();
    let ex2: f64 = pmf.iter().enumerate().map(|(k, &pr)| (k as f64).powi(2) * pr).sum();
    ex2 - mean * mean
}

/// The paper's *printed* Theorem 3.3 formula,
/// `σ² = (α[1 − (n²−1)αⁿ] − (n²−1)α^{n+1}) / (1−α)²`.
///
/// Kept verbatim for comparison; the reproduction uses the exact-pmf
/// variance above. (Table-driven tests document where the printed algebra
/// diverges from the exact moments — see EXPERIMENTS.md §Theory.)
pub fn thm33_variance_paper(alpha: f64, n: usize) -> f64 {
    let nn = n as f64;
    let a_n = alpha.powi(n as i32);
    (alpha * (1.0 - (nn * nn - 1.0) * a_n) - (nn * nn - 1.0) * alpha.powi(n as i32 + 1))
        / (1.0 - alpha).powi(2)
}

/// The paper's E[N] convention (number of *trials* including the success):
/// `E[N] = (1 − (1−p)^n) / p`.
pub fn thm33_mean_paper(p: f64, n: usize) -> f64 {
    (1.0 - (1.0 - p).powi(n as i32)) / p
}

/// Dualistic speedup estimate (the classical speculative-decoding formula,
/// used as a sanity baseline in benches): tokens per target-forward = L+1,
/// cost per cycle = T_1 + K·T_2.
pub fn dualistic_speedup(l: f64, k: f64, t1: f64, t2: f64) -> f64 {
    ((l + 1.0) * t1) / (t1 + k * t2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        for &p in &[0.0, 0.3, 0.8, 0.95, 1.0] {
            for &n in &[1usize, 4, 16] {
                let s: f64 = accept_len_pmf(p, n).iter().sum();
                assert!((s - 1.0).abs() < 1e-12, "p={p} n={n} sum={s}");
            }
        }
    }

    #[test]
    fn mean_matches_closed_form() {
        for &p in &[0.2, 0.5, 0.9] {
            for &n in &[1usize, 3, 10] {
                let exact = accept_len_mean(p, n);
                let closed = p * (1.0 - p.powi(n as i32)) / (1.0 - p);
                assert!((exact - closed).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn variance_matches_monte_carlo() {
        use crate::spec::rng::Pcg32;
        let (p, n) = (0.8, 8usize);
        let mut rng = Pcg32::seeded(123);
        let trials = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..trials {
            let mut k = 0;
            while k < n && rng.next_f64() < p {
                k += 1;
            }
            sum += k as f64;
            sum2 += (k * k) as f64;
        }
        let mean = sum / trials as f64;
        let var = sum2 / trials as f64 - mean * mean;
        assert!((mean - accept_len_mean(p, n)).abs() < 0.02, "{mean}");
        assert!((var - accept_len_variance(p, n)).abs() < 0.05, "{var}");
    }

    #[test]
    fn stability_improves_with_acceptance_probability() {
        // Thm 3.3's qualitative claim: higher acceptance probability (smaller
        // alpha) gives more *stable* acceptance lengths. Raw variance of the
        // truncated geometric is non-monotone in p (truncation creates a
        // mid-range hump), so stability is measured as the coefficient of
        // variation std/mean — which is what "predictable performance" means
        // operationally (per-cycle cost spread relative to throughput).
        let n = 10;
        let cv = |p: f64| accept_len_variance(p, n).sqrt() / accept_len_mean(p, n);
        assert!(cv(0.95) < cv(0.8), "{} !< {}", cv(0.95), cv(0.8));
        assert!(cv(0.8) < cv(0.6), "{} !< {}", cv(0.8), cv(0.6));
        // And in the high-acceptance limit the distribution concentrates.
        assert!(accept_len_variance(0.999, n) < accept_len_variance(0.8, n));
    }

    #[test]
    fn lemma31_reduces_to_dualistic() {
        // n=2: T = N/L1 * T1 + beta * N/L1 * T2 (paper §3.2).
        let t = lemma31_time(100.0, &[4.0], &[10.0, 1.0], 2.0);
        assert!((t - (100.0 / 4.0 * 10.0 + 2.0 * 100.0 / 4.0 * 1.0)).abs() < 1e-9);
    }

    #[test]
    fn lemma31_three_model_decomposition() {
        let n = 960.0;
        let t = lemma31_time(n, &[8.0, 5.0], &[20.0, 6.0, 1.0], 3.0);
        let expect = n / 8.0 * 20.0 + n / 5.0 * 6.0 + 3.0 * n / 5.0 * 1.0;
        assert!((t - expect).abs() < 1e-9);
    }

    #[test]
    fn insertion_check_paper_table1_compliant() {
        // Paper Table 1 "Compliant" row: T_i=22, T_new=7.00, L_i=4.34,
        // L_i_new=6.26, L_new=4.67 -> 0.318 < 0.330.
        let c = InsertionCheck {
            t_i: 22.0,
            t_new: 7.0,
            t_next: 4.0,
            l_i: 4.34,
            l_i_new: 6.26,
            l_new: 4.67,
            beta: 1.0,
        };
        let v = c.evaluate();
        assert!((v.cond1_lhs - 0.318).abs() < 0.01, "{}", v.cond1_lhs);
        assert!((v.cond1_rhs - 0.330).abs() < 0.01, "{}", v.cond1_rhs);
        assert!(v.cond1);
        assert!(v.predicts_improvement());
    }

    #[test]
    fn insertion_check_paper_table1_noncompliant() {
        // "Non-compliant" row: T_new=17.61 -> 0.80 vs 0.117.
        let c = InsertionCheck {
            t_i: 22.0,
            t_new: 17.61,
            t_next: 4.0,
            l_i: 4.34,
            l_i_new: 3.83,
            l_new: 3.77,
            beta: 1.0,
        };
        let v = c.evaluate();
        assert!((v.cond1_lhs - 0.80).abs() < 0.01);
        assert!(!v.cond1, "lhs {} rhs {}", v.cond1_lhs, v.cond1_rhs);
    }

    #[test]
    fn dualistic_speedup_sane() {
        // L=4, K=4, T1=10, T2=1: (5*10)/(10+4) ≈ 3.57
        let s = dualistic_speedup(4.0, 4.0, 10.0, 1.0);
        assert!((s - 50.0 / 14.0).abs() < 1e-9);
    }
}
