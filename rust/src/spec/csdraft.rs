//! CS Drafting baseline (Chen et al. 2023, "Cascade Speculative Drafting").
//!
//! Reproduced for the paper's Table-1 "Case 3: Generalization" experiment,
//! which inserts a mid-tier model into a CS-Drafting cascade and checks
//! Theorem 3.2 on it.
//!
//! * **Vertical cascade** — the draft block is assembled by a ladder of
//!   drafters, cheapest at the tail; the lowest tier is the statistical
//!   [`BigramModel`](super::ngram::BigramModel) (no neural autoregression at
//!   the bottom, the paper's headline trick).
//! * **Horizontal cascade** — earlier block positions (more likely to be
//!   accepted) get the *better* drafters and longer budgets; later positions
//!   fall to cheaper drafters.
//!
//! Implemented as a steppable [`CsDraftTask`]: one
//! [`step`](DecodeTask::step) assembles one cascade block and verifies it
//! with one target scoring, each position checked against the distribution
//! of whichever drafter proposed it; [`generate`] drives a task to
//! completion. Every cascade member holds a [`ScoringSession`], so drafters
//! score only their own new tokens and a rejection rolls cached prefixes
//! back instead of rescoring them.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::dualistic::{dist_row_into, pick};
use super::rng::Pcg32;
use super::sampler::FilterScratch;
use super::task::{
    model_key, DecodeTask, InflightState, PlannedAppend, ResumeState, StepMeter, StepOutcome,
};
use super::types::{
    reconcile, GenerationOutput, LanguageModel, Logits, SamplingParams, ScoringSession, Token,
    VerifyRule,
};
use super::verify::{verify_token, TokenVerdict};

#[derive(Debug, Clone)]
pub struct CsDraftConfig {
    /// `lens[d]` = tokens contributed by drafter `d` (`models[d + 1]`),
    /// in horizontal-cascade order. Decreasing quality with d.
    pub lens: Vec<usize>,
    pub rule: VerifyRule,
    pub sampling: SamplingParams,
    pub max_new: usize,
}

impl CsDraftConfig {
    pub fn block_len(&self) -> usize {
        self.lens.iter().sum()
    }
}

/// CS-Drafting decode as a resumable state machine. `models[0]` is the
/// target; the remaining entries are drafters in decreasing capability (the
/// last one is typically a [`BigramModel`](super::ngram::BigramModel)).
///
/// # Graceful degradation
///
/// Drafters are disposable: only the target's verification commits tokens,
/// so a drafter that fails a scoring call — or whose health breaker is open
/// at a step boundary — is removed from the cascade (its horizontal budget
/// with it) and the step's partial block is discarded. With every drafter
/// gone the block is empty and each step commits exactly the bonus token:
/// plain autoregressive decode on the target. Dropping a drafter never
/// changes the committed-token distribution, and under deterministic verify
/// rules the output stays byte-identical. Only a target failure fails the
/// task.
pub struct CsDraftTask<'m> {
    models: Vec<&'m dyn LanguageModel>,
    sessions: Vec<Box<dyn ScoringSession + 'm>>,
    cfg: CsDraftConfig,
    rng: Pcg32,
    scratch: FilterScratch,
    ctx: Vec<Token>,
    prompt_len: usize,
    // Round-persistent buffers: the assembled block, per-position proposal
    // distributions, the verifier row, and the frontier (ctx + block).
    block: Vec<Token>,
    q_rows: Vec<Vec<f32>>,
    p: Vec<f32>,
    frontier: Vec<Token>,
    accept_lengths: Vec<u32>,
    stage_accepts: Vec<Vec<u32>>,
    meter: StepMeter,
    /// Dispatch-chain indices of the members still alive (ascending, always
    /// starting with 0 — the target).
    live_models: Vec<usize>,
    /// Length of the cascade as dispatched, before any degradation.
    dispatch_n: usize,
    /// Failure delivered by [`DecodeTask::absorb_append`], surfaced by the
    /// next `step` exactly like the equivalent in-step append failure.
    pending_fault: Option<anyhow::Error>,
}

impl<'m> CsDraftTask<'m> {
    pub fn new(
        models: &'m [Arc<dyn LanguageModel>],
        prompt: &[Token],
        cfg: CsDraftConfig,
    ) -> Result<Self> {
        // Skip drafters whose health breaker is already open; the target is
        // always attempted (without it there is no request).
        let want: Vec<usize> =
            (0..models.len()).filter(|&i| i == 0 || models[i].healthy()).collect();
        let (task, _dropped) = Self::build(models, prompt, cfg, want)?;
        Ok(task)
    }

    /// Open sessions for the `want` subset of the dispatch cascade,
    /// dropping drafters whose sessions fail to open. Returns the task plus
    /// the positions *within the original `want`* that were dropped, so
    /// `resume` can subset saved per-model statistics to match.
    fn build(
        models: &'m [Arc<dyn LanguageModel>],
        prompt: &[Token],
        mut cfg: CsDraftConfig,
        mut want: Vec<usize>,
    ) -> Result<(Self, Vec<usize>)> {
        anyhow::ensure!(models.len() >= 2, "need a target and at least one drafter");
        anyhow::ensure!(
            cfg.lens.len() == models.len() - 1,
            "need a horizontal budget per drafter ({} != {})",
            cfg.lens.len(),
            models.len() - 1
        );
        anyhow::ensure!(cfg.block_len() >= 1, "empty draft block");
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            want.first() == Some(&0)
                && want.windows(2).all(|w| w[0] < w[1])
                // xtask:allow(panic): first() == Some(&0) proves non-empty.
                && *want.last().unwrap() < models.len(),
            "live-model set must be ascending, in range, and contain the target"
        );
        let dispatch_n = models.len();
        let dispatch_lens = cfg.lens.clone();
        let mut dropped: Vec<usize> = Vec::new();
        let mut sessions: Vec<Box<dyn ScoringSession + 'm>> = Vec::with_capacity(want.len());
        'open: loop {
            sessions.clear();
            for (pos, &i) in want.iter().enumerate() {
                match models[i].open_session() {
                    Ok(s) => sessions.push(s),
                    Err(e) if pos == 0 => return Err(e.context("opening target session")),
                    // A drafter that cannot open a session is dropped before
                    // the decode starts; sessions opened so far close on the
                    // `clear` above, so nothing leaks.
                    Err(_) => {
                        want.remove(pos);
                        dropped.push(pos);
                        continue 'open;
                    }
                }
            }
            break;
        }
        // `dropped` holds positions in the want-vector *as it shrank*; map
        // them back to positions in the original `want`.
        for i in (0..dropped.len()).rev() {
            for j in (0..i).rev() {
                if dropped[j] <= dropped[i] {
                    dropped[i] += 1;
                }
            }
        }
        cfg.lens = want[1..].iter().map(|&i| dispatch_lens[i - 1]).collect();
        // xtask:allow(panic): `want` was just validated non-empty.
        let seq_cap = want.iter().map(|&i| models[i].seq_len()).min().unwrap();
        anyhow::ensure!(
            prompt.len() + cfg.max_new + cfg.block_len() + 1 <= seq_cap,
            "request does not fit the context window"
        );
        let k = want.len();
        let task = Self {
            models: want.iter().map(|&i| models[i].as_ref()).collect(),
            sessions,
            rng: Pcg32::seeded(cfg.sampling.seed),
            cfg,
            scratch: FilterScratch::default(),
            ctx: prompt.to_vec(),
            prompt_len: prompt.len(),
            block: Vec::new(),
            q_rows: Vec::new(),
            p: Vec::new(),
            frontier: Vec::new(),
            accept_lengths: Vec::new(),
            stage_accepts: vec![Vec::new(); k - 1],
            meter: StepMeter::new(k),
            live_models: want,
            dispatch_n,
            pending_fault: None,
        };
        Ok((task, dropped))
    }

    /// Re-open a suspended decode from `prompt + state`; see
    /// [`DecodeTask::suspend`]. Fresh sessions re-score the committed
    /// prefix on the next step's `reconcile`, after which decode continues
    /// byte-identically to an uninterrupted run.
    pub fn resume(
        models: &'m [Arc<dyn LanguageModel>],
        prompt: &[Token],
        cfg: CsDraftConfig,
        state: ResumeState,
    ) -> Result<Self> {
        anyhow::ensure!(
            state.committed.len() <= cfg.max_new,
            "resume state carries {} tokens for a budget of {}",
            state.committed.len(),
            cfg.max_new
        );
        anyhow::ensure!(
            matches!(state.inflight, InflightState::None),
            "CS-Drafting tasks carry no in-flight state"
        );
        // A degraded task resumes on its surviving subset; empty
        // `live_models` (a pre-degradation state) means the full cascade.
        let want = if state.live_models.is_empty() {
            ResumeState::full_chain(models.len())
        } else {
            state.live_models.clone()
        };
        anyhow::ensure!(
            state.forward_passes.len() == want.len(),
            "resume state covers {} models, live cascade has {}",
            state.forward_passes.len(),
            want.len()
        );
        anyhow::ensure!(
            state.stage_accepts.len() == want.len() - 1,
            "resume state covers {} drafter tiers, live cascade has {}",
            state.stage_accepts.len(),
            want.len() - 1
        );
        let (mut task, mut dropped) = Self::build(models, prompt, cfg, want)?;
        // Members that failed to re-open sessions shrink the saved stats in
        // lockstep (target open failure is fatal in `build`, so every
        // dropped position is a drafter, `p >= 1`).
        let mut passes = state.forward_passes;
        let mut times = state.forward_time;
        let mut stage = state.stage_accepts;
        dropped.sort_unstable();
        for &p in dropped.iter().rev() {
            passes.remove(p);
            times.remove(p);
            stage.remove(p - 1);
        }
        task.ctx.extend_from_slice(&state.committed);
        task.rng = state.rng;
        task.accept_lengths = state.accept_lengths;
        task.stage_accepts = stage;
        task.meter = StepMeter::resumed(state.wall, passes, times);
        Ok(task)
    }

    /// Remove cascade member `d` (a drafter; never the target). Its session
    /// closes on drop, releasing any engine-side state; its horizontal
    /// budget and tier statistics go with it.
    fn drop_member(&mut self, d: usize) {
        debug_assert!(d >= 1 && d < self.models.len(), "only drafters can be dropped");
        self.models.remove(d);
        self.sessions.remove(d);
        self.cfg.lens.remove(d - 1);
        self.stage_accepts.remove(d - 1);
        self.meter.drop_model(d);
        self.live_models.remove(d);
    }

    /// Live-chain index of the session the next step reconciles first: the
    /// first drafter with a horizontal budget, or the target once every
    /// drafter is gone (autoregressive bonus-only decode).
    fn next_append_member(&self) -> usize {
        match self.cfg.lens.iter().position(|&len| len > 0) {
            Some(d) => d + 1,
            None => 0,
        }
    }
}

impl DecodeTask for CsDraftTask<'_> {
    fn committed(&self) -> &[Token] {
        let end = (self.prompt_len + self.cfg.max_new).min(self.ctx.len());
        &self.ctx[self.prompt_len..end]
    }

    fn finished(&self) -> bool {
        self.ctx.len() - self.prompt_len >= self.cfg.max_new
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.finished() {
            return Ok(StepOutcome::Finished { new_tokens: 0 });
        }
        if let Some(e) = self.pending_fault.take() {
            // A batched pre-append failed. Same trichotomy as in-step: a
            // drafter failure drops that member, a target failure fails
            // the request.
            let idx = self.next_append_member();
            if idx >= 1 {
                self.drop_member(idx);
                return Ok(StepOutcome::Progress { new_tokens: 0 });
            }
            return Err(e);
        }
        // Proactive degradation: drop drafters whose health breaker is open
        // before spending a scoring call on them.
        let mut d = self.models.len();
        while d > 1 {
            d -= 1;
            if !self.models[d].healthy() {
                self.drop_member(d);
            }
        }
        let before = self.committed().len();
        let Self {
            models,
            sessions,
            cfg,
            rng,
            scratch,
            ctx,
            prompt_len,
            block,
            q_rows,
            p,
            frontier,
            accept_lengths,
            stage_accepts,
            meter,
            ..
        } = self;
        meter.begin(models);
        let remaining = cfg.max_new - (ctx.len() - *prompt_len);

        // ---- horizontal cascade: assemble the block ----------------------
        block.clear();
        frontier.clear();
        frontier.extend_from_slice(ctx);
        let mut failed_member: Option<usize> = None;
        'assemble: for (d, &len) in cfg.lens.iter().enumerate() {
            let dsess = &mut sessions[d + 1];
            for _ in 0..len {
                if block.len() >= remaining + 1 {
                    break 'assemble;
                }
                if reconcile(&mut **dsess, frontier).is_err() {
                    failed_member = Some(d + 1);
                    break 'assemble;
                }
                if q_rows.len() == block.len() {
                    q_rows.push(Vec::new());
                }
                let q = &mut q_rows[block.len()];
                dist_row_into(dsess.row(frontier.len() - 1), &cfg.sampling, scratch, q);
                let tok = pick(q, &cfg.sampling, cfg.rule, rng);
                block.push(tok);
                frontier.push(tok);
            }
        }
        if let Some(idx) = failed_member {
            // A drafter failed mid-block: discard the partial block (nothing
            // was committed, so the output distribution is untouched), drop
            // the member, and report zero progress for this step.
            meter.end(models);
            self.drop_member(idx);
            return Ok(StepOutcome::Progress { new_tokens: 0 });
        }

        // ---- one target scoring verifies everything ----------------------
        // With every drafter degraded away the block is empty and the bonus
        // token below is plain autoregressive decode on the target.
        let tsess = &mut sessions[0];
        if let Err(e) = reconcile(&mut **tsess, frontier) {
            meter.end(models);
            return Err(e);
        }
        let base = ctx.len();
        let mut accepted = 0usize;
        let mut replacement: Option<Token> = None;
        for i in 0..block.len() {
            dist_row_into(tsess.row(base - 1 + i), &cfg.sampling, scratch, p);
            match verify_token(block[i], p, &q_rows[i], cfg.rule, rng) {
                TokenVerdict::Accepted => accepted += 1,
                TokenVerdict::Rejected { replacement: r } => {
                    replacement = Some(r);
                    break;
                }
            }
        }

        // Attribute the acceptance to the drafter tiers (for L measurements
        // in the Table-1 case-3 experiment).
        let mut seen = 0usize;
        for (d, &len) in cfg.lens.iter().enumerate() {
            let tier_accepted = accepted.saturating_sub(seen).min(len);
            stage_accepts[d].push(tier_accepted as u32);
            seen += len;
        }

        ctx.extend_from_slice(&block[..accepted]);
        let mut committed_now = accepted;
        if let Some(r) = replacement {
            ctx.push(r);
            committed_now += 1;
        } else {
            dist_row_into(tsess.row(base + block.len() - 1), &cfg.sampling, scratch, p);
            let bonus = pick(p, &cfg.sampling, cfg.rule, rng);
            ctx.push(bonus);
            committed_now += 1;
        }
        accept_lengths.push(committed_now as u32);
        meter.end(models);

        let new_tokens = self.committed().len() - before;
        if self.finished() {
            Ok(StepOutcome::Finished { new_tokens })
        } else {
            Ok(StepOutcome::Progress { new_tokens })
        }
    }

    fn finish(self: Box<Self>) -> GenerationOutput {
        let degraded = (self.dispatch_n - self.models.len()) as u32;
        let end = (self.prompt_len + self.cfg.max_new).min(self.ctx.len());
        let tokens = self.ctx[self.prompt_len..end].to_vec();
        let accept_lengths = self.accept_lengths;
        let stage_accept_lengths = self.stage_accepts;
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        GenerationOutput {
            tokens,
            wall,
            forward_passes,
            forward_time,
            accept_lengths,
            stage_accept_lengths,
            degraded,
        }
    }

    fn suspend(self: Box<Self>) -> ResumeState {
        let degraded = (self.dispatch_n - self.models.len()) as u32;
        let committed = self.ctx[self.prompt_len..].to_vec();
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        ResumeState {
            committed,
            rng: self.rng,
            accept_lengths: self.accept_lengths,
            stage_accepts: self.stage_accepts,
            wall,
            forward_passes,
            forward_time,
            inflight: InflightState::None,
            live_models: self.live_models,
            degraded,
            swap: None,
        }
    }

    fn degraded(&self) -> u32 {
        (self.dispatch_n - self.models.len()) as u32
    }

    fn plan_append(&mut self) -> Option<PlannedAppend> {
        if self.finished() || self.pending_fault.is_some() {
            return None;
        }
        if (1..self.models.len()).any(|d| !self.models[d].healthy()) {
            return None; // the next step's health sweep reshapes the cascade
        }
        let idx = self.next_append_member();
        let sess = &self.sessions[idx];
        let handle = sess.batch_handle()?;
        let have = sess.len();
        // Coalescible iff the first reconcile is a pure non-empty append.
        if have >= self.ctx.len() || sess.tokens() != &self.ctx[..have] {
            return None;
        }
        Some(PlannedAppend {
            model_key: model_key(self.models[idx]),
            handle,
            tokens: Arc::from(&self.ctx[have..]),
            prefix_len: have,
        })
    }

    fn absorb_append(&mut self, rows: Result<Option<Logits>>) {
        let idx = self.next_append_member();
        let sess = &mut self.sessions[idx];
        let have = sess.len();
        let suffix: Vec<Token> = self.ctx[have..].to_vec();
        match rows.and_then(|r| sess.absorb_batched(&suffix, r)) {
            // The batch charged the model counters once; per-task pass
            // accounting stays solo-equivalent via an explicit charge.
            Ok(()) => self.meter.charge(idx, Duration::ZERO),
            Err(e) => self.pending_fault = Some(e),
        }
    }
}

/// Generate with a CS-Drafting cascade, driven to completion.
pub fn generate(
    models: &[Arc<dyn LanguageModel>],
    prompt: &[Token],
    cfg: &CsDraftConfig,
) -> Result<GenerationOutput> {
    for m in models {
        m.reset_counters();
    }
    let mut task = CsDraftTask::new(models, prompt, cfg.clone())?;
    while !task.finished() {
        task.step()?;
    }
    Ok(Box::new(task).finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::autoregressive;
    use crate::spec::mock::MockModel;
    use crate::spec::ngram::BigramModel;

    fn cascade() -> Vec<Arc<dyn LanguageModel>> {
        vec![
            Arc::new(MockModel::new("t", 512, 24, 5, 0.0)),
            Arc::new(MockModel::new("d1", 512, 24, 5, 0.4)),
            Arc::new(BigramModel::new(512, 24)),
        ]
    }

    fn greedy(max_new: usize, lens: Vec<usize>) -> CsDraftConfig {
        CsDraftConfig {
            lens,
            rule: VerifyRule::Greedy,
            sampling: SamplingParams { temperature: 0.0, ..Default::default() },
            max_new,
        }
    }

    #[test]
    fn greedy_matches_target_greedy() {
        let models = cascade();
        let out = generate(&models, &[3, 1], &greedy(32, vec![3, 2])).unwrap();
        let ar = autoregressive::generate(
            models[0].as_ref(),
            &[3, 1],
            32,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.tokens, ar.tokens);
    }

    #[test]
    fn exact_output_length() {
        let models = cascade();
        let out = generate(&models, &[1], &greedy(17, vec![2, 2])).unwrap();
        assert_eq!(out.tokens.len(), 17);
    }

    #[test]
    fn tier_attribution_sums() {
        let models = cascade();
        let out = generate(&models, &[1, 2, 3], &greedy(40, vec![3, 2])).unwrap();
        // Per round, tier acceptances are each bounded by their budget.
        for &a in &out.stage_accept_lengths[0] {
            assert!(a <= 3);
        }
        for &a in &out.stage_accept_lengths[1] {
            assert!(a <= 2);
        }
        assert_eq!(out.stage_accept_lengths[0].len(), out.accept_lengths.len());
    }

    #[test]
    fn speculative_reproducible_across_session_backends() {
        use crate::spec::types::ForceStateless;
        let models = cascade();
        let stateless: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(ForceStateless(MockModel::new("t", 512, 24, 5, 0.0))),
            Arc::new(ForceStateless(MockModel::new("d1", 512, 24, 5, 0.4))),
            Arc::new(BigramModel::new(512, 24)),
        ];
        let cfg = CsDraftConfig {
            lens: vec![3, 2],
            rule: VerifyRule::Speculative,
            sampling: SamplingParams { seed: 17, ..Default::default() },
            max_new: 30,
        };
        let a = generate(&models, &[4, 2], &cfg).unwrap();
        let b = generate(&stateless, &[4, 2], &cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn stepped_task_matches_generate() {
        let models = cascade();
        let cfg = CsDraftConfig {
            lens: vec![3, 2],
            rule: VerifyRule::Speculative,
            sampling: SamplingParams { seed: 29, ..Default::default() },
            max_new: 26,
        };
        let whole = generate(&models, &[4, 2], &cfg).unwrap();
        for m in &models {
            m.reset_counters();
        }
        let mut task = CsDraftTask::new(&models, &[4, 2], cfg).unwrap();
        let mut streamed: Vec<Token> = Vec::new();
        while !task.finished() {
            let before = task.committed().len();
            let outcome = task.step().unwrap();
            assert_eq!(outcome.new_tokens(), task.committed().len() - before);
            streamed.extend_from_slice(&task.committed()[before..]);
        }
        assert_eq!(streamed, whole.tokens);
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, whole.tokens);
        assert_eq!(out.forward_passes, whole.forward_passes);
        assert_eq!(out.stage_accept_lengths, whole.stage_accept_lengths);
    }

    #[test]
    fn suspend_resume_mid_decode_is_byte_identical() {
        let models = cascade();
        let cfg = CsDraftConfig {
            lens: vec![3, 2],
            rule: VerifyRule::Speculative,
            sampling: SamplingParams { seed: 37, ..Default::default() },
            max_new: 30,
        };
        let whole = generate(&models, &[4, 2], &cfg).unwrap();
        let mut task = CsDraftTask::new(&models, &[4, 2], cfg.clone()).unwrap();
        for _ in 0..2 {
            task.step().unwrap();
        }
        let state = Box::new(task).suspend();
        let mut task = CsDraftTask::resume(&models, &[4, 2], cfg, state).unwrap();
        while !task.finished() {
            task.step().unwrap();
        }
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, whole.tokens, "resumed decode diverged");
        assert_eq!(out.accept_lengths, whole.accept_lengths);
        assert_eq!(out.stage_accept_lengths, whole.stage_accept_lengths);
    }

    #[test]
    fn drafter_fault_degrades_and_stays_greedy_identical() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let models: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(MockModel::new("t", 512, 24, 5, 0.0)),
            Arc::new(
                ChaosModel::new(MockModel::new("d1", 512, 24, 5, 0.4)).fault_at(4, Fault::Lost),
            ),
            Arc::new(BigramModel::new(512, 24)),
        ];
        let out = generate(&models, &[3, 1], &greedy(32, vec![3, 2])).unwrap();
        let ar = autoregressive::generate(
            models[0].as_ref(),
            &[3, 1],
            32,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.tokens, ar.tokens, "degraded greedy decode must stay target-argmax");
        assert_eq!(out.degraded, 1);
        assert_eq!(out.forward_passes.len(), 2, "surviving cascade is target + bigram");
    }

    #[test]
    fn all_drafters_dead_degrades_to_autoregressive() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let models: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(MockModel::new("t", 512, 24, 5, 0.0)),
            Arc::new(
                ChaosModel::new(MockModel::new("d1", 512, 24, 5, 0.4)).fault_at(2, Fault::Lost),
            ),
            Arc::new(
                ChaosModel::new(MockModel::new("d2", 512, 24, 5, 0.8)).fault_at(0, Fault::Lost),
            ),
        ];
        let out = generate(&models, &[3, 1], &greedy(32, vec![3, 2])).unwrap();
        let ar = autoregressive::generate(
            models[0].as_ref(),
            &[3, 1],
            32,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.tokens, ar.tokens);
        assert_eq!(out.tokens.len(), 32, "request still completes in full");
        assert_eq!(out.degraded, 2);
        assert_eq!(out.forward_passes.len(), 1, "only the target survives");
    }

    #[test]
    fn target_fault_fails_the_request() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let models: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(
                ChaosModel::new(MockModel::new("t", 512, 24, 5, 0.0)).fault_at(0, Fault::Lost),
            ),
            Arc::new(MockModel::new("d1", 512, 24, 5, 0.4)),
            Arc::new(BigramModel::new(512, 24)),
        ];
        assert!(generate(&models, &[3, 1], &greedy(16, vec![3, 2])).is_err());
    }

    #[test]
    fn degraded_task_suspends_and_resumes_on_subset() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let models: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(MockModel::new("t", 512, 24, 5, 0.0)),
            Arc::new(
                ChaosModel::new(MockModel::new("d1", 512, 24, 5, 0.4)).fault_at(1, Fault::Lost),
            ),
            Arc::new(BigramModel::new(512, 24)),
        ];
        let cfg = greedy(32, vec![3, 2]);
        let mut task = CsDraftTask::new(&models, &[3, 1], cfg.clone()).unwrap();
        while task.degraded() == 0 {
            task.step().unwrap();
        }
        let state = Box::new(task).suspend();
        assert_eq!(state.live_models, vec![0, 2], "drafter d1 must be gone from the live set");
        assert_eq!(state.degraded, 1);
        let mut task = CsDraftTask::resume(&models, &[3, 1], cfg.clone(), state).unwrap();
        assert_eq!(task.degraded(), 1);
        while !task.finished() {
            task.step().unwrap();
        }
        let out = Box::new(task).finish();
        let ar =
            autoregressive::generate(models[0].as_ref(), &[3, 1], 32, &cfg.sampling).unwrap();
        assert_eq!(out.tokens, ar.tokens, "degraded + resumed decode must stay target-argmax");
    }

    #[test]
    fn unhealthy_drafter_skipped_at_construction() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let chaos =
            ChaosModel::new(MockModel::new("d1", 512, 24, 5, 0.4)).fault_at(0, Fault::Lost);
        let _ = chaos.forward(&[1]); // trips the lost flag
        assert!(!chaos.healthy());
        let models: Vec<Arc<dyn LanguageModel>> = vec![
            Arc::new(MockModel::new("t", 512, 24, 5, 0.0)),
            Arc::new(chaos),
            Arc::new(BigramModel::new(512, 24)),
        ];
        let mut task = CsDraftTask::new(&models, &[3, 1], greedy(16, vec![3, 2])).unwrap();
        assert_eq!(task.degraded(), 1, "unhealthy drafter is skipped at open time");
        while !task.finished() {
            task.step().unwrap();
        }
        let out = Box::new(task).finish();
        let ar = autoregressive::generate(
            models[0].as_ref(),
            &[3, 1],
            16,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.tokens, ar.tokens);
    }

    #[test]
    fn config_validation() {
        let models = cascade();
        let mut cfg = greedy(10, vec![3]);
        assert!(generate(&models, &[1], &cfg).is_err()); // lens mismatch
        cfg = greedy(10, vec![0, 0]);
        assert!(generate(&models, &[1], &cfg).is_err()); // empty block
    }
}
