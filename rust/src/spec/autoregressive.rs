//! Vanilla autoregressive decoding — the speedup-ratio denominator.
//!
//! Runs on a [`ScoringSession`](super::types::ScoringSession), so each step
//! scores only the freshly sampled token on backends with prefix caching
//! (falling back to full-context forwards through `StatelessSession`).
//! Call accounting is unchanged: one scoring call per generated token.

use std::time::Instant;

use anyhow::Result;

use super::rng::Pcg32;
use super::sampler::{self};
use super::types::{softmax_into, GenerationOutput, LanguageModel, SamplingParams, Token};

/// Generate `max_new` tokens with plain next-token sampling.
pub fn generate(
    model: &dyn LanguageModel,
    prompt: &[Token],
    max_new: usize,
    sampling: &SamplingParams,
) -> Result<GenerationOutput> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(
        prompt.len() + max_new <= model.seq_len(),
        "prompt {} + max_new {} exceeds context {}",
        prompt.len(),
        max_new,
        model.seq_len()
    );
    model.reset_counters();
    let start = Instant::now();
    let mut rng = Pcg32::seeded(sampling.seed);
    let mut tokens: Vec<Token> = Vec::with_capacity(max_new);
    if max_new > 0 {
        let mut session = model.open_session()?;
        session.append(prompt)?;
        let mut probs: Vec<f32> = Vec::new();
        let mut scratch = sampler::FilterScratch::default();
        for i in 0..max_new {
            softmax_into(session.row(session.len() - 1), sampling.temperature, &mut probs);
            let tok = sampler::sample_scratch(&mut probs, sampling, &mut rng, &mut scratch);
            tokens.push(tok);
            // The final token's own row is never read — skip scoring it.
            if i + 1 < max_new {
                session.append(&[tok])?;
            }
        }
    }
    Ok(GenerationOutput {
        tokens,
        wall: start.elapsed(),
        forward_passes: vec![model.calls()],
        forward_time: vec![model.total_time()],
        accept_lengths: vec![1; max_new],
        stage_accept_lengths: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::MockModel;
    use crate::spec::types::ForceStateless;

    #[test]
    fn generates_requested_length() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let out = generate(&m, &[1, 2, 3], 10, &SamplingParams::default()).unwrap();
        assert_eq!(out.tokens.len(), 10);
        assert_eq!(out.forward_passes, vec![10]);
    }

    #[test]
    fn greedy_is_deterministic() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let params = SamplingParams { temperature: 0.0, ..Default::default() };
        let a = generate(&m, &[5], 12, &params).unwrap();
        let b = generate(&m, &[5], 12, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn seeded_sampling_is_reproducible() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let params = SamplingParams { seed: 9, ..Default::default() };
        let a = generate(&m, &[5], 12, &params).unwrap();
        let b = generate(&m, &[5], 12, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn cached_session_matches_stateless_decode() {
        let cached = MockModel::new("m", 64, 16, 1, 0.3);
        let stateless = ForceStateless(MockModel::new("m", 64, 16, 1, 0.3));
        let params = SamplingParams { seed: 4, ..Default::default() };
        let a = generate(&cached, &[5, 1], 20, &params).unwrap();
        let b = generate(&stateless, &[5, 1], 20, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.forward_passes, b.forward_passes);
    }

    #[test]
    fn rejects_overlong_request() {
        let m = MockModel::new("m", 8, 16, 1, 0.0);
        assert!(generate(&m, &[1, 2], 10, &SamplingParams::default()).is_err());
    }
}
