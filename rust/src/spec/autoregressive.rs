//! Vanilla autoregressive decoding — the speedup-ratio denominator.
//!
//! Implemented as a steppable [`ArTask`] (one token per
//! [`step`](DecodeTask::step)) with [`generate`] as the drive-to-completion
//! wrapper. Runs on a [`ScoringSession`](super::types::ScoringSession), so
//! each step scores only the freshly sampled token on backends with prefix
//! caching (falling back to full-context forwards through
//! `StatelessSession`). Call accounting is unchanged: one scoring call per
//! generated token.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::rng::Pcg32;
use super::sampler::{self};
use super::task::{
    model_key, DecodeTask, InflightState, PlannedAppend, ResumeState, StepMeter, StepOutcome,
};
use super::types::{
    reconcile, softmax_into, GenerationOutput, LanguageModel, Logits, SamplingParams,
    ScoringSession, Token,
};

/// Autoregressive decode as a resumable state machine: `step` commits
/// exactly one token. Every step opens by reconciling the session to the
/// canonical `prompt + committed` prefix (the whole prompt on the first
/// step, the previously committed token afterwards), so constructing a
/// task is free and the step's one engine call is always a pure append —
/// which makes it plannable for the scheduler's cross-request batching
/// (a batched pre-append turns the reconcile into a free no-op).
pub struct ArTask<'m> {
    model: &'m dyn LanguageModel,
    session: Box<dyn ScoringSession + 'm>,
    prompt: Vec<Token>,
    max_new: usize,
    sampling: SamplingParams,
    rng: Pcg32,
    probs: Vec<f32>,
    scratch: sampler::FilterScratch,
    tokens: Vec<Token>,
    /// Canonical context (`prompt + committed`) the session reconciles to.
    ctx: Vec<Token>,
    /// Failure delivered by [`DecodeTask::absorb_append`], surfaced by the
    /// next `step` exactly like an in-step append failure.
    pending_fault: Option<anyhow::Error>,
    meter: StepMeter,
}

impl<'m> ArTask<'m> {
    pub fn new(
        model: &'m dyn LanguageModel,
        prompt: &[Token],
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<Self> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.len() + max_new <= model.seq_len(),
            "prompt {} + max_new {} exceeds context {}",
            prompt.len(),
            max_new,
            model.seq_len()
        );
        Ok(Self {
            model,
            session: model.open_session()?,
            prompt: prompt.to_vec(),
            max_new,
            sampling,
            rng: Pcg32::seeded(sampling.seed),
            probs: Vec::new(),
            scratch: sampler::FilterScratch::default(),
            tokens: Vec::with_capacity(max_new),
            ctx: prompt.to_vec(),
            pending_fault: None,
            meter: StepMeter::new(1),
        })
    }

    /// Re-open a suspended decode from `prompt + state`; see
    /// [`DecodeTask::suspend`]. The fresh session re-scores the whole
    /// `prompt + committed` prefix lazily on the first step, after which
    /// decode continues byte-identically to an uninterrupted run.
    pub fn resume(
        model: &'m dyn LanguageModel,
        prompt: &[Token],
        max_new: usize,
        sampling: SamplingParams,
        state: ResumeState,
    ) -> Result<Self> {
        anyhow::ensure!(
            state.committed.len() <= max_new,
            "resume state carries {} tokens for a budget of {max_new}",
            state.committed.len()
        );
        anyhow::ensure!(state.forward_passes.len() == 1, "autoregressive resume needs one model");
        anyhow::ensure!(
            matches!(state.inflight, InflightState::None),
            "autoregressive tasks carry no in-flight state"
        );
        anyhow::ensure!(
            state.live_models.is_empty() || state.live_models == [0],
            "autoregressive resume state names models beyond the target"
        );
        let mut task = Self::new(model, prompt, max_new, sampling)?;
        task.tokens = state.committed;
        task.ctx.extend_from_slice(&task.tokens);
        task.rng = state.rng;
        task.meter = StepMeter::resumed(state.wall, state.forward_passes, state.forward_time);
        Ok(task)
    }
}

impl DecodeTask for ArTask<'_> {
    fn committed(&self) -> &[Token] {
        &self.tokens
    }

    fn finished(&self) -> bool {
        self.tokens.len() >= self.max_new
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.finished() {
            return Ok(StepOutcome::Finished { new_tokens: 0 });
        }
        if let Some(e) = self.pending_fault.take() {
            return Err(e);
        }
        let models: [&dyn LanguageModel; 1] = [self.model];
        self.meter.begin(&models);
        // Sync the session to the canonical prefix: the whole prompt (plus
        // any tokens committed before a suspension) on the first step, the
        // previously committed token afterwards. A free no-op when the
        // scheduler's batched pre-append already landed it. The final
        // token's own row is never read — it is pushed below but never
        // reconciled, so it is never scored.
        reconcile(&mut *self.session, &self.ctx)?;
        softmax_into(
            self.session.row(self.session.len() - 1),
            self.sampling.temperature,
            &mut self.probs,
        );
        let tok =
            sampler::sample_scratch(&mut self.probs, &self.sampling, &mut self.rng, &mut self.scratch);
        self.tokens.push(tok);
        self.ctx.push(tok);
        self.meter.end(&models);
        if self.finished() {
            Ok(StepOutcome::Finished { new_tokens: 1 })
        } else {
            Ok(StepOutcome::Progress { new_tokens: 1 })
        }
    }

    fn plan_append(&mut self) -> Option<PlannedAppend> {
        if self.finished() || self.pending_fault.is_some() {
            return None;
        }
        let handle = self.session.batch_handle()?;
        let have = self.session.len();
        // Coalescible iff the next reconcile is a pure non-empty append.
        if have >= self.ctx.len() || self.session.tokens() != &self.ctx[..have] {
            return None;
        }
        Some(PlannedAppend {
            model_key: model_key(self.model),
            handle,
            tokens: Arc::from(&self.ctx[have..]),
            prefix_len: have,
        })
    }

    fn absorb_append(&mut self, rows: Result<Option<Logits>>) {
        let have = self.session.len();
        let suffix: Vec<Token> = self.ctx[have..].to_vec();
        match rows.and_then(|r| self.session.absorb_batched(&suffix, r)) {
            // The batched call charged the model-level counters once for
            // the whole batch; per-task pass accounting stays
            // solo-equivalent via an explicit charge.
            Ok(()) => self.meter.charge(0, Duration::ZERO),
            Err(e) => self.pending_fault = Some(e),
        }
    }

    fn finish(self: Box<Self>) -> GenerationOutput {
        let accept = vec![1; self.tokens.len()];
        let tokens = self.tokens;
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        GenerationOutput {
            tokens,
            wall,
            forward_passes,
            forward_time,
            accept_lengths: accept,
            stage_accept_lengths: vec![],
            degraded: 0,
        }
    }

    fn suspend(self: Box<Self>) -> ResumeState {
        let n = self.tokens.len();
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        ResumeState {
            committed: self.tokens,
            rng: self.rng,
            accept_lengths: vec![1; n],
            stage_accepts: vec![],
            wall,
            forward_passes,
            forward_time,
            inflight: InflightState::None,
            live_models: vec![0],
            degraded: 0,
            swap: None,
        }
    }
}

/// Generate `max_new` tokens with plain next-token sampling.
pub fn generate(
    model: &dyn LanguageModel,
    prompt: &[Token],
    max_new: usize,
    sampling: &SamplingParams,
) -> Result<GenerationOutput> {
    model.reset_counters();
    let mut task = ArTask::new(model, prompt, max_new, *sampling)?;
    while !task.finished() {
        task.step()?;
    }
    Ok(Box::new(task).finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::MockModel;
    use crate::spec::types::ForceStateless;

    #[test]
    fn generates_requested_length() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let out = generate(&m, &[1, 2, 3], 10, &SamplingParams::default()).unwrap();
        assert_eq!(out.tokens.len(), 10);
        assert_eq!(out.forward_passes, vec![10]);
    }

    #[test]
    fn greedy_is_deterministic() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let params = SamplingParams { temperature: 0.0, ..Default::default() };
        let a = generate(&m, &[5], 12, &params).unwrap();
        let b = generate(&m, &[5], 12, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn seeded_sampling_is_reproducible() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let params = SamplingParams { seed: 9, ..Default::default() };
        let a = generate(&m, &[5], 12, &params).unwrap();
        let b = generate(&m, &[5], 12, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn cached_session_matches_stateless_decode() {
        let cached = MockModel::new("m", 64, 16, 1, 0.3);
        let stateless = ForceStateless(MockModel::new("m", 64, 16, 1, 0.3));
        let params = SamplingParams { seed: 4, ..Default::default() };
        let a = generate(&cached, &[5, 1], 20, &params).unwrap();
        let b = generate(&stateless, &[5, 1], 20, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.forward_passes, b.forward_passes);
    }

    #[test]
    fn stepped_task_commits_one_token_per_step() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let mut task = ArTask::new(&m, &[1, 2], 5, SamplingParams::default()).unwrap();
        let mut steps = 0;
        while !task.finished() {
            let before = task.committed().len();
            let o = task.step().unwrap();
            assert_eq!(o.new_tokens(), 1);
            assert_eq!(task.committed().len(), before + 1);
            steps += 1;
        }
        assert_eq!(steps, 5);
        // Stepping a finished task is a no-op.
        assert_eq!(task.step().unwrap(), StepOutcome::Finished { new_tokens: 0 });
        let out = Box::new(task).finish();
        assert_eq!(out.tokens.len(), 5);
        assert_eq!(out.forward_passes, vec![5]);
    }

    #[test]
    fn zero_budget_task_is_born_finished() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let task = ArTask::new(&m, &[1], 0, SamplingParams::default()).unwrap();
        assert!(task.finished());
        assert!(task.committed().is_empty());
        let out = Box::new(task).finish();
        assert!(out.tokens.is_empty());
        assert_eq!(out.forward_passes, vec![0]);
    }

    #[test]
    fn rejects_overlong_request() {
        let m = MockModel::new("m", 8, 16, 1, 0.0);
        assert!(generate(&m, &[1, 2], 10, &SamplingParams::default()).is_err());
    }

    #[test]
    fn suspend_resume_mid_decode_is_byte_identical() {
        let m = MockModel::new("m", 64, 16, 1, 0.3);
        let params = SamplingParams { seed: 21, ..Default::default() };
        let whole = generate(&m, &[5, 1], 20, &params).unwrap();
        let mut task = ArTask::new(&m, &[5, 1], 20, params).unwrap();
        for _ in 0..7 {
            task.step().unwrap();
        }
        let state = Box::new(task).suspend();
        assert_eq!(state.committed.len(), 7);
        let mut task = ArTask::resume(&m, &[5, 1], 20, params, state).unwrap();
        assert_eq!(task.committed().len(), 7);
        while !task.finished() {
            task.step().unwrap();
        }
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, whole.tokens, "resumed decode diverged");
        assert_eq!(out.accept_lengths, whole.accept_lengths);
    }
}
