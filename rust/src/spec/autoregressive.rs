//! Vanilla autoregressive decoding — the speedup-ratio denominator.

use std::time::Instant;

use anyhow::Result;

use super::rng::Pcg32;
use super::sampler::{self};
use super::types::{GenerationOutput, LanguageModel, SamplingParams, Token};

/// Generate `max_new` tokens with plain next-token sampling.
pub fn generate(
    model: &dyn LanguageModel,
    prompt: &[Token],
    max_new: usize,
    sampling: &SamplingParams,
) -> Result<GenerationOutput> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(
        prompt.len() + max_new <= model.seq_len(),
        "prompt {} + max_new {} exceeds context {}",
        prompt.len(),
        max_new,
        model.seq_len()
    );
    model.reset_counters();
    let start = Instant::now();
    let mut rng = Pcg32::seeded(sampling.seed);
    let mut ctx = prompt.to_vec();
    for _ in 0..max_new {
        let logits = model.forward(&ctx)?;
        let mut probs = logits.probs(ctx.len() - 1, sampling.temperature);
        let tok = sampler::sample(&mut probs, sampling, &mut rng);
        ctx.push(tok);
    }
    Ok(GenerationOutput {
        tokens: ctx[prompt.len()..].to_vec(),
        wall: start.elapsed(),
        forward_passes: vec![model.calls()],
        forward_time: vec![model.total_time()],
        accept_lengths: vec![1; max_new],
        stage_accept_lengths: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::MockModel;

    #[test]
    fn generates_requested_length() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let out = generate(&m, &[1, 2, 3], 10, &SamplingParams::default()).unwrap();
        assert_eq!(out.tokens.len(), 10);
        assert_eq!(out.forward_passes, vec![10]);
    }

    #[test]
    fn greedy_is_deterministic() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let params = SamplingParams { temperature: 0.0, ..Default::default() };
        let a = generate(&m, &[5], 12, &params).unwrap();
        let b = generate(&m, &[5], 12, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn seeded_sampling_is_reproducible() {
        let m = MockModel::new("m", 64, 16, 1, 0.0);
        let params = SamplingParams { seed: 9, ..Default::default() };
        let a = generate(&m, &[5], 12, &params).unwrap();
        let b = generate(&m, &[5], 12, &params).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn rejects_overlong_request() {
        let m = MockModel::new("m", 8, 16, 1, 0.0);
        assert!(generate(&m, &[1, 2], 10, &SamplingParams::default()).is_err());
    }
}
