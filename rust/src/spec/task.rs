//! Resumable decode tasks — the unit the serving coordinator schedules.
//!
//! Each decode loop in `spec::` (polybasic, dualistic, CS-Drafting,
//! autoregressive) is a state machine implementing [`DecodeTask`]: the task
//! owns one [`ScoringSession`](super::types::ScoringSession) per chain
//! member, and [`step`](DecodeTask::step) runs exactly one draft→verify
//! round (one token for autoregressive), committing zero or more tokens.
//! `generate(...)` in each module is a thin drive-to-completion wrapper, so
//! a stepped task is **token-identical** to one-shot generation for every
//! method and [`VerifyRule`](super::types::VerifyRule) — asserted in
//! `tests/property_tests.rs`.
//!
//! Why steps matter: a run-to-completion `generate` makes the serving layer
//! schedule whole requests, so a 512-token batch job head-of-line-blocks a
//! 10-token interactive one. With steppable tasks the coordinator
//! round-robins *between* steps (continuous batching), admits new requests
//! mid-flight, and streams committed tokens as they land — see
//! `coordinator::scheduler`.
//!
//! Accounting: tasks meter forward passes and forward time per step as
//! *deltas* of the shared model counters ([`StepMeter`]), so several tasks
//! interleaved on one chain each report their own `F_i` / `T_i` (the
//! quantities Lemma 3.1 prices a chain by). Wall time is the sum of step
//! durations — time the task actually held the worker, not time it spent
//! parked between steps.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::rng::Pcg32;
use super::types::{GenerationOutput, LanguageModel, Logits, Token};

/// What one [`DecodeTask::step`] accomplished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// The task advanced; `new_tokens` tokens were newly committed (may be
    /// zero when only intermediate pipeline stages fired).
    Progress { new_tokens: usize },
    /// The request's budget is fully committed; `new_tokens` were committed
    /// by this final step (zero when called on an already-finished task).
    Finished { new_tokens: usize },
}

impl StepOutcome {
    /// Tokens newly committed by the step.
    pub fn new_tokens(self) -> usize {
        match self {
            StepOutcome::Progress { new_tokens } | StepOutcome::Finished { new_tokens } => {
                new_tokens
            }
        }
    }

    pub fn is_finished(self) -> bool {
        matches!(self, StepOutcome::Finished { .. })
    }
}

/// A reservation in the coordinator's bounded KV swap tier, held by a
/// preempted decode whose blocks were swapped aside instead of discarded.
///
/// Plain data (id + footprint) rather than a coordinator type, so
/// [`ResumeState`] — a `spec`-layer struct — can carry it without the spec
/// layer depending on the coordinator. Tasks never create or consume one:
/// `suspend()` sets [`ResumeState::swap`] to `None` and the scheduler
/// fills it in when the KV manager accepts the swap-out; on resume the
/// scheduler redeems it for a restore that skips the re-score entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwapHandle {
    /// Swap-tier reservation id.
    pub id: u64,
    /// Tokens of KV preserved by the reservation (prompt + committed +
    /// in-flight at suspension) — the recompute a restore saves.
    pub tokens: usize,
    /// Swap blocks held.
    pub blocks: usize,
}

/// Everything a preempted decode needs to continue later, captured at a
/// step boundary by [`DecodeTask::suspend`].
///
/// Preemption tears the task down completely — scoring sessions are
/// dropped and the KV allocation is released — so the state is pure host
/// data: the committed tokens, the sampling RNG mid-stream, the per-model
/// draft/accept statistics, and the `F_i` / `T_i` meter totals. A task
/// re-opened from `prompt + ResumeState` (each task type has a `resume`
/// constructor) continues the decode **byte-identically** to a run that was
/// never suspended: the RNG draws, verify verdicts, and therefore committed
/// tokens are exactly the sequence the uninterrupted task would have
/// produced. The only cost is wasted recompute — the resumed sessions
/// re-score the prefix the dropped sessions had cached (the coordinator's
/// `wasted_recompute_tokens` gauge).
#[derive(Debug)]
pub struct ResumeState {
    /// Tokens committed beyond the prompt when the task was suspended.
    pub committed: Vec<Token>,
    /// The sampling RNG, mid-stream. Restoring it (rather than re-seeding)
    /// is what keeps post-resume draws identical to an uninterrupted run.
    pub rng: Pcg32,
    /// Acceptance lengths observed at the target so far.
    pub accept_lengths: Vec<u32>,
    /// Acceptance lengths at each intermediate verifier (chain order).
    pub stage_accepts: Vec<Vec<u32>>,
    /// Wall time the task spent holding a worker before suspension.
    pub wall: Duration,
    /// Per-model forward passes so far (`F_i`), chain order.
    pub forward_passes: Vec<u64>,
    /// Per-model forward time so far (`T_i`), chain order.
    pub forward_time: Vec<Duration>,
    /// Speculative work in flight at the suspension point.
    pub inflight: InflightState,
    /// Indices into the *dispatch* chain of the members still alive when
    /// the task was suspended (ascending, always containing 0 — the
    /// target). A task that gracefully dropped drafters resumes on the
    /// surviving subset instead of re-opening sessions on dead models.
    pub live_models: Vec<usize>,
    /// Chain members dropped by graceful degradation before suspension.
    pub degraded: u32,
    /// Swap-tier reservation covering this decode's KV at suspension, when
    /// the coordinator swapped the blocks aside instead of discarding them.
    /// Tasks always suspend with `None`; the scheduler fills and redeems
    /// it (see `coordinator::paged::swap`).
    pub swap: Option<SwapHandle>,
}

impl ResumeState {
    /// `live_models` for a task that never degraded: the full chain.
    pub fn full_chain(n_models: usize) -> Vec<usize> {
        (0..n_models).collect()
    }
}

/// Speculative pipeline state that outlives a step boundary. Dualistic,
/// CS-Drafting and autoregressive tasks draft and verify within one step,
/// so between steps they carry nothing; the polybasic pipeline holds
/// partially verified tokens (and their proposal distributions) across
/// steps, which must survive suspension — dropping them would desync the
/// RNG stream from an uninterrupted run and break byte-identity.
#[derive(Debug)]
pub enum InflightState {
    /// No speculative state crosses the step boundary.
    None,
    /// The polybasic pipeline's uncommitted suffix: `drafted` are the
    /// in-flight tokens (`flat[committed..]`), `queues[j]` their proposal
    /// distributions awaiting verifier `j`, in position order.
    Polybasic {
        drafted: Vec<Token>,
        queues: Vec<VecDeque<Vec<f32>>>,
    },
}

/// One pure-append engine call a task proposes for cross-request
/// coalescing (see [`DecodeTask::plan_append`]). The scheduler groups
/// plans from all live tasks by chain member and submits each group as a
/// single [`LanguageModel::append_batch`] per scheduler tick.
///
/// Identity is by value, not by borrow: `model_key` is the planned chain
/// member's data pointer, which the scheduler resolves back to its own
/// `&[Arc<dyn LanguageModel>]` chain slice. This keeps the plan free of
/// task borrows, so the scheduler can collect plans from every live task
/// and still mutate the tasks when absorbing results.
#[derive(Debug, Clone)]
pub struct PlannedAppend {
    /// Data pointer of the chain member the append targets (compare with
    /// [`model_key`] of a chain entry).
    pub model_key: usize,
    /// The session's [`batch_handle`](super::types::ScoringSession::batch_handle).
    pub handle: u64,
    /// Suffix the next step would append first. Shared, not cloned: the
    /// same allocation travels through retries and the channel protocol.
    pub tokens: Arc<[Token]>,
    /// Session length the suffix extends (tokens already scored and cached).
    /// Pure telemetry for the scheduler's recompute-avoided accounting: a
    /// KV-cached engine computes `tokens.len()` rows where a stateless one
    /// recomputes `prefix_len` more.
    pub prefix_len: usize,
}

/// Grouping key for [`PlannedAppend`]: the model's data pointer. The same
/// chain member yields the same key whether reached through a task's
/// borrow or the scheduler's `Arc`.
pub fn model_key(model: &dyn LanguageModel) -> usize {
    model as *const dyn LanguageModel as *const () as usize
}

/// A resumable decode: one (request, chain) pair stepped one draft→verify
/// round at a time. Implementations live next to their `generate` wrappers
/// in [`polybasic`](super::polybasic), [`dualistic`](super::dualistic),
/// [`csdraft`](super::csdraft) and
/// [`autoregressive`](super::autoregressive).
pub trait DecodeTask {
    /// Tokens committed so far (excluding the prompt), capped at the
    /// request's `max_new` — the stream a server delivers incrementally.
    fn committed(&self) -> &[Token];

    /// True once the full output budget is committed. `step` on a finished
    /// task is a no-op returning `Finished { new_tokens: 0 }`.
    fn finished(&self) -> bool;

    /// Run one decode round. Committed tokens are visible through
    /// [`committed`](Self::committed) immediately after the call.
    fn step(&mut self) -> Result<StepOutcome>;

    /// Consume the task into its [`GenerationOutput`] (tokens + the paper's
    /// measurements). Callable at any point; mid-flight it reports the
    /// partial decode.
    fn finish(self: Box<Self>) -> GenerationOutput;

    /// Tear the task down for preemption, capturing a [`ResumeState`] from
    /// which the decode continues byte-identically. Sessions are dropped
    /// (the caller releases the KV allocation); call only at a step
    /// boundary, on an unfinished task.
    fn suspend(self: Box<Self>) -> ResumeState;

    /// Chain members dropped so far by graceful degradation (a failing or
    /// unhealthy drafter removed at a step boundary). Zero for tasks that
    /// cannot degrade. Degradation never changes the committed-token
    /// distribution — only the target verifies — so for deterministic
    /// verify rules the output stays byte-identical.
    fn degraded(&self) -> u32 {
        0
    }

    /// *Plan* phase of the plan→submit→absorb protocol: the first engine
    /// call the next [`step`](Self::step) would issue, **iff** it is a
    /// pure append on a batch-capable session (the canonical context
    /// strictly extends the session's scored prefix). `None` means the
    /// next step is not coalescible — rollback-first, degraded chain,
    /// resume restore, or a session without a batch handle — and the task
    /// falls back to the unbatched in-step path.
    ///
    /// A task that returns `Some` remembers the plan and expects exactly
    /// one [`absorb_append`](Self::absorb_append) before its next `step`.
    /// Safety: a plan only pre-executes work the step would do anyway
    /// against the same canonical context, so a mispredicted plan costs
    /// performance, never correctness — the step's own `reconcile` rolls
    /// back any divergence (prefix determinism + rollback exactness).
    fn plan_append(&mut self) -> Option<PlannedAppend> {
        None
    }

    /// *Absorb* phase: deliver the planned append's slice of the batched
    /// reply. `Ok(rows)` installs the suffix rows into the planned
    /// session (bit-identical to a solo append), after which the next
    /// step's first `reconcile` is a free no-op. `Err` is stashed and
    /// handled by the next `step` exactly like an in-step append failure
    /// (drafter → degrade, target → fail), so batching stays inside the
    /// degrade/fail/delay trichotomy.
    fn absorb_append(&mut self, rows: Result<Option<Logits>>) {
        let _ = rows;
    }
}

/// Per-task forward-pass accounting over shared model counters.
///
/// Counters on [`LanguageModel`] are global to the model instance; when the
/// coordinator interleaves several tasks on one chain they all advance the
/// same counters. The meter brackets each step (`begin`/`end`) and
/// accumulates the *delta*, giving per-task `F_i` and `T_i` that match what
/// a solo run would report.
#[derive(Debug)]
pub(crate) struct StepMeter {
    base_calls: Vec<u64>,
    base_time: Vec<Duration>,
    step_started: Instant,
    passes: Vec<u64>,
    time: Vec<Duration>,
    wall: Duration,
}

impl StepMeter {
    pub fn new(n_models: usize) -> Self {
        Self {
            base_calls: vec![0; n_models],
            base_time: vec![Duration::ZERO; n_models],
            step_started: Instant::now(),
            passes: vec![0; n_models],
            time: vec![Duration::ZERO; n_models],
            wall: Duration::ZERO,
        }
    }

    /// Rebuild a meter from a suspended task's totals, so the resumed
    /// task's `F_i` / `T_i` keep accumulating where they left off.
    pub fn resumed(wall: Duration, passes: Vec<u64>, time: Vec<Duration>) -> Self {
        debug_assert_eq!(passes.len(), time.len());
        let n = passes.len();
        Self {
            base_calls: vec![0; n],
            base_time: vec![Duration::ZERO; n],
            step_started: Instant::now(),
            passes,
            time,
            wall,
        }
    }

    /// Snapshot counters at the top of a step.
    pub fn begin(&mut self, models: &[&dyn LanguageModel]) {
        debug_assert_eq!(models.len(), self.passes.len());
        for (i, m) in models.iter().enumerate() {
            self.base_calls[i] = m.calls();
            self.base_time[i] = m.total_time();
        }
        self.step_started = Instant::now();
    }

    /// Fold the step's counter deltas and wall time into the task totals.
    pub fn end(&mut self, models: &[&dyn LanguageModel]) {
        for (i, m) in models.iter().enumerate() {
            // saturating: a mid-step external `reset_counters` must not panic.
            self.passes[i] += m.calls().saturating_sub(self.base_calls[i]);
            self.time[i] += m.total_time().saturating_sub(self.base_time[i]);
        }
        self.wall += self.step_started.elapsed();
    }

    /// Charge model `idx` with one forward pass of `cost` executed
    /// *outside* a `begin`/`end` bracket — the scheduler's batched submit
    /// runs between steps, where no bracket is open. Keeps a task's
    /// per-request `F_i` identical to a solo (unbatched) run while the
    /// shared model counters record the real, coalesced engine calls.
    pub fn charge(&mut self, idx: usize, cost: Duration) {
        self.passes[idx] += 1;
        self.time[idx] += cost;
    }

    /// Remove model `idx` from the meter when graceful degradation drops a
    /// chain member mid-decode; its accumulated totals are discarded along
    /// with it (the surviving entries keep chain order).
    pub fn drop_model(&mut self, idx: usize) {
        self.base_calls.remove(idx);
        self.base_time.remove(idx);
        self.passes.remove(idx);
        self.time.remove(idx);
    }

    /// (wall, forward_passes, forward_time), consuming the meter.
    pub fn into_parts(self) -> (Duration, Vec<u64>, Vec<Duration>) {
        (self.wall, self.passes, self.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::MockModel;

    #[test]
    fn step_outcome_accessors() {
        assert_eq!(StepOutcome::Progress { new_tokens: 3 }.new_tokens(), 3);
        assert_eq!(StepOutcome::Finished { new_tokens: 1 }.new_tokens(), 1);
        assert!(StepOutcome::Finished { new_tokens: 0 }.is_finished());
        assert!(!StepOutcome::Progress { new_tokens: 0 }.is_finished());
    }

    #[test]
    fn meter_accumulates_deltas_not_totals() {
        let m = MockModel::new("m", 32, 8, 1, 0.0);
        // Calls made before the meter's first `begin` must not be charged.
        m.forward(&[1, 2]).unwrap();
        let mut meter = StepMeter::new(1);
        let models: [&dyn LanguageModel; 1] = [&m];
        meter.begin(&models);
        m.forward(&[1, 2, 3]).unwrap();
        meter.end(&models);
        // Calls between steps (another task's work) are not charged either.
        m.forward(&[9]).unwrap();
        meter.begin(&models);
        m.forward(&[9, 9]).unwrap();
        m.forward(&[9, 9, 9]).unwrap();
        meter.end(&models);
        let (wall, passes, time) = meter.into_parts();
        assert_eq!(passes, vec![3]);
        assert!(time[0] <= m.total_time());
        assert!(wall > Duration::ZERO);
    }

    #[test]
    fn charge_adds_passes_outside_brackets() {
        let m = MockModel::new("m", 32, 8, 1, 0.0);
        let models: [&dyn LanguageModel; 1] = [&m];
        let mut meter = StepMeter::new(1);
        // A batched append executed between steps: charged explicitly.
        meter.charge(0, Duration::from_millis(2));
        meter.begin(&models);
        m.forward(&[1]).unwrap();
        meter.end(&models);
        let (_, passes, time) = meter.into_parts();
        assert_eq!(passes, vec![2], "charge + bracketed delta");
        assert!(time[0] >= Duration::from_millis(2));
    }

    #[test]
    fn resumed_meter_continues_from_saved_totals() {
        let m = MockModel::new("m", 32, 8, 1, 0.0);
        let models: [&dyn LanguageModel; 1] = [&m];
        let mut meter =
            StepMeter::resumed(Duration::from_millis(5), vec![7], vec![Duration::from_millis(3)]);
        meter.begin(&models);
        m.forward(&[1, 2]).unwrap();
        meter.end(&models);
        let (wall, passes, time) = meter.into_parts();
        assert_eq!(passes, vec![8], "resumed pass count must extend the saved total");
        assert!(time[0] >= Duration::from_millis(3));
        assert!(wall >= Duration::from_millis(5));
    }
}
