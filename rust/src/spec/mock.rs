//! Deterministic mock language models for fast, artifact-free testing.
//!
//! A [`MockModel`] derives each next-token distribution from a hash of the
//! context prefix, blended between a shared "oracle" distribution and
//! model-private noise.  Two mocks with the same `base_seed` and different
//! `noise` levels behave like a target and its drafters: lower noise =>
//! closer to the oracle => higher mutual acceptance.  This lets every
//! algorithm in `spec::` be exercised (and its losslessness proven
//! statistically) without PJRT artifacts.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::rng::Pcg32;
use super::types::{LanguageModel, Logits, ModelCounters, ScoringSession, Token};

/// FNV-1a offset basis; the empty-prefix rolling-hash state.
const FNV_OFFSET: u64 = 0xcbf29ce484222325;

#[derive(Debug)]
pub struct MockModel {
    name: String,
    seq_len: usize,
    vocab: usize,
    base_seed: u64,
    model_seed: u64,
    /// 0.0 = identical to the oracle; larger = less faithful.
    noise: f32,
    /// Busy-wait per forward, to emulate a per-forward cost `T_i` in timing
    /// tests and theory validation.
    cost: Duration,
    /// Additional busy-wait per *computed token*, emulating the device cost
    /// model of the KV-cached runtime: a stateless `forward` pays it per
    /// prefix token (O(prefix)), while a session `append` / coalesced
    /// `append_batch` pays it only per suffix token (O(suffix)).  Benches
    /// contrast the two to show per-tick cost flat in prefix length.
    cost_per_token: Duration,
    counters: ModelCounters,
}

impl MockModel {
    pub fn new(name: &str, seq_len: usize, vocab: usize, base_seed: u64, noise: f32) -> Self {
        Self {
            name: name.to_string(),
            seq_len,
            vocab,
            base_seed,
            model_seed: fnv(name.as_bytes(), 0x9e3779b97f4a7c15),
            noise,
            cost: Duration::ZERO,
            cost_per_token: Duration::ZERO,
            counters: ModelCounters::default(),
        }
    }

    /// Emulate a per-forward cost (busy-wait, so wall-clock is realistic).
    pub fn with_cost(mut self, cost: Duration) -> Self {
        self.cost = cost;
        self
    }

    /// Emulate a per-computed-token cost on top of [`with_cost`]'s flat
    /// launch overhead.  `forward` then costs `cost + per_token · prefix`
    /// while session appends cost `cost + per_token · suffix` — the same
    /// O(prefix) vs O(suffix) contrast the device KV cache buys.
    pub fn with_token_cost(mut self, per_token: Duration) -> Self {
        self.cost_per_token = per_token;
        self
    }

    /// Busy-wait out the emulated cost for a pass that computed `n_tokens`
    /// token rows, measured from `start` (row computation overlaps it).
    fn wait_cost(&self, start: Instant, n_tokens: usize) {
        let total = self.cost + self.cost_per_token * n_tokens as u32;
        if !total.is_zero() {
            while start.elapsed() < total {
                std::hint::spin_loop();
            }
        }
    }

    /// Append the logits row for prefix-hash `h` onto `out`. The row is a
    /// pure function of `h` (and model parameters), which is what makes the
    /// rolling-hash session below bit-exact with full forwards.
    fn extend_row_for_hash(&self, h: u64, out: &mut Vec<f32>) {
        let base = out.len();
        // Oracle logits: deterministic in (base_seed, prefix).
        let mut rng = Pcg32::new(h, 0x5851f42d4c957f2d);
        out.extend((0..self.vocab).map(|_| 3.0 * (rng.next_f32() - 0.5)));
        // A few "peaky" tokens so distributions are LLM-like (low entropy).
        let peak = (h % self.vocab as u64) as usize;
        out[base + peak] += 4.0;
        let peak2 = ((h >> 17) % self.vocab as u64) as usize;
        out[base + peak2] += 2.0;
        // Model-private perturbation.
        if self.noise > 0.0 {
            let mut nrng = Pcg32::new(h ^ self.model_seed, 0x14057b7ef767814f);
            for l in &mut out[base..] {
                *l += self.noise * 3.0 * (nrng.next_f32() - 0.5);
            }
        }
    }
}

impl LanguageModel for MockModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn forward(&self, tokens: &[Token]) -> Result<Logits> {
        anyhow::ensure!(tokens.len() <= self.seq_len, "context too long");
        let start = Instant::now();
        let mut data = Vec::with_capacity(tokens.len() * self.vocab);
        // Rolling prefix hash: hash(tokens[..=t]) folds one token into
        // hash(tokens[..t]), so the whole pass is O(len · vocab).
        let mut h = self.base_seed ^ FNV_OFFSET;
        for &t in tokens {
            h = fnv(&t.to_le_bytes(), h);
            self.extend_row_for_hash(h, &mut data);
        }
        // Stateless scoring recomputes every prefix row: O(prefix) cost.
        self.wait_cost(start, tokens.len());
        self.counters.record(start.elapsed());
        Ok(Logits::new(data, tokens.len(), self.vocab))
    }

    fn calls(&self) -> u64 {
        self.counters.calls()
    }

    fn total_time(&self) -> Duration {
        self.counters.total_time()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }

    fn open_session(&self) -> Result<Box<dyn ScoringSession + '_>> {
        Ok(Box::new(MockSession {
            model: self,
            tokens: Vec::new(),
            hashes: Vec::new(),
            rows: Vec::new(),
        }))
    }

    /// Batched suffix scoring: the whole batch counts as **one** forward
    /// (one call record, one `T_i` busy-wait), which is exactly the saving
    /// the scheduler's coalescing exists to produce — tests and benches
    /// observe it through [`calls`](LanguageModel::calls). Rows are a pure
    /// function of each session's rolling prefix hash, so every entry
    /// returns `Ok(None)` and [`MockSession::absorb_batched`] recomputes
    /// them locally, bit-identical to a solo append.
    fn append_batch(&self, appends: &[(u64, Arc<[Token]>)]) -> Option<Vec<Result<Option<Logits>>>> {
        if appends.is_empty() {
            return Some(Vec::new());
        }
        let start = Instant::now();
        // One launch for the whole batch, paying only for suffix rows: the
        // coalesced KV-cached cost model (flat overhead amortized, O(suffix)
        // compute per entry).
        let suffix_tokens: usize = appends.iter().map(|(_, s)| s.len()).sum();
        self.wait_cost(start, suffix_tokens);
        self.counters.record(start.elapsed());
        Some(appends.iter().map(|_| Ok(None)).collect())
    }
}

/// Incremental scoring session over a [`MockModel`]: a rolling prefix hash
/// plus memoized rows make `append` O(suffix · vocab) where a stateless
/// forward is O(prefix · vocab), and `rollback` a truncation. Rows are
/// bit-identical to what [`MockModel::forward`] produces for the same
/// prefix (both derive each row purely from the rolling hash).
pub struct MockSession<'m> {
    model: &'m MockModel,
    tokens: Vec<Token>,
    /// `hashes[t]` = rolling FNV hash of `tokens[0..=t]`.
    hashes: Vec<u64>,
    /// Flat `[len, vocab]` row cache.
    rows: Vec<f32>,
}

impl ScoringSession for MockSession<'_> {
    fn vocab(&self) -> usize {
        self.model.vocab
    }

    fn len(&self) -> usize {
        self.tokens.len()
    }

    fn tokens(&self) -> &[Token] {
        &self.tokens
    }

    fn append(&mut self, suffix: &[Token]) -> Result<()> {
        if suffix.is_empty() {
            return Ok(());
        }
        anyhow::ensure!(
            self.tokens.len() + suffix.len() <= self.model.seq_len,
            "context too long"
        );
        let start = Instant::now();
        let mut h = self
            .hashes
            .last()
            .copied()
            .unwrap_or(self.model.base_seed ^ FNV_OFFSET);
        for &t in suffix {
            h = fnv(&t.to_le_bytes(), h);
            self.hashes.push(h);
            self.model.extend_row_for_hash(h, &mut self.rows);
            self.tokens.push(t);
        }
        // One append emulates one decode-step launch: same flat per-call
        // cost `T_i` and call accounting as a stateless forward, but the
        // per-token component scales with the *suffix* only — the KV cache
        // makes appends O(suffix), not O(prefix).
        self.model.wait_cost(start, suffix.len());
        self.model.counters.record(start.elapsed());
        Ok(())
    }

    fn rollback(&mut self, to_len: usize) -> Result<()> {
        anyhow::ensure!(
            to_len <= self.tokens.len(),
            "rollback to {to_len} past session length {}",
            self.tokens.len()
        );
        self.tokens.truncate(to_len);
        self.hashes.truncate(to_len);
        self.rows.truncate(to_len * self.model.vocab);
        Ok(())
    }

    fn row(&self, pos: usize) -> &[f32] {
        let vocab = self.model.vocab;
        assert!(pos < self.tokens.len(), "row {pos} out of range {}", self.tokens.len());
        &self.rows[pos * vocab..(pos + 1) * vocab]
    }

    /// Mock sessions are host-local, so the handle carries no state; any
    /// value lets the batched path engage.
    fn batch_handle(&self) -> Option<u64> {
        Some(0)
    }

    /// Install a batched append's suffix. The engine side
    /// ([`MockModel::append_batch`]) already recorded the one coalesced
    /// call, so this records nothing and pays no `T_i`; rows are
    /// recomputed from the rolling hash — the same pure function `append`
    /// uses, hence bit-identical.
    fn absorb_batched(&mut self, suffix: &[Token], _rows: Option<Logits>) -> Result<()> {
        if suffix.is_empty() {
            return Ok(());
        }
        anyhow::ensure!(
            self.tokens.len() + suffix.len() <= self.model.seq_len,
            "context too long"
        );
        let mut h = self
            .hashes
            .last()
            .copied()
            .unwrap_or(self.model.base_seed ^ FNV_OFFSET);
        for &t in suffix {
            h = fnv(&t.to_le_bytes(), h);
            self.hashes.push(h);
            self.model.extend_row_for_hash(h, &mut self.rows);
            self.tokens.push(t);
        }
        Ok(())
    }
}

fn fnv(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A standard mock chain for tests: target (noise 0), intermediate, draft.
pub fn mock_chain(seq_len: usize, vocab: usize, seed: u64) -> Vec<std::sync::Arc<dyn LanguageModel>> {
    vec![
        std::sync::Arc::new(MockModel::new("mock-target", seq_len, vocab, seed, 0.0)),
        std::sync::Arc::new(MockModel::new("mock-mid", seq_len, vocab, seed, 0.35)),
        std::sync::Arc::new(MockModel::new("mock-draft", seq_len, vocab, seed, 0.8)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::softmax;

    #[test]
    fn deterministic_per_prefix() {
        let m = MockModel::new("m", 32, 16, 7, 0.5);
        let a = m.forward(&[1, 2, 3]).unwrap();
        let b = m.forward(&[1, 2, 3]).unwrap();
        assert_eq!(a.row(2), b.row(2));
    }

    #[test]
    fn rows_depend_only_on_prefix() {
        // KV-consistency: row t must not change when later tokens change.
        let m = MockModel::new("m", 32, 16, 7, 0.5);
        let a = m.forward(&[1, 2, 3, 4]).unwrap();
        let b = m.forward(&[1, 2, 3, 9]).unwrap();
        assert_eq!(a.row(1), b.row(1));
        assert_eq!(a.row(2), b.row(2));
        assert_ne!(a.row(3), b.row(3));
    }

    #[test]
    fn noise_orders_similarity() {
        // Acceptance proxy sum(min(p, q)) must decrease with noise.
        let target = MockModel::new("t", 64, 32, 3, 0.0);
        let close = MockModel::new("c", 64, 32, 3, 0.3);
        let far = MockModel::new("f", 64, 32, 3, 1.5);
        let ctx: Vec<Token> = (0..40).map(|i| (i * 7 % 32) as Token).collect();
        let lt = target.forward(&ctx).unwrap();
        let lc = close.forward(&ctx).unwrap();
        let lf = far.forward(&ctx).unwrap();
        let overlap = |a: &Logits, b: &Logits| -> f64 {
            (0..ctx.len())
                .map(|t| {
                    let p = softmax(a.row(t), 1.0);
                    let q = softmax(b.row(t), 1.0);
                    p.iter().zip(&q).map(|(&x, &y)| x.min(y) as f64).sum::<f64>()
                })
                .sum::<f64>()
                / ctx.len() as f64
        };
        let oc = overlap(&lt, &lc);
        let of = overlap(&lt, &lf);
        assert!(oc > of + 0.05, "close {oc} vs far {of}");
        assert!(oc > 0.6, "close overlap too low: {oc}");
    }

    #[test]
    fn session_rows_bit_identical_to_forward() {
        let m = MockModel::new("m", 64, 16, 7, 0.5);
        let toks: Vec<Token> = (0..20).map(|i| (i * 5 % 16) as Token).collect();
        let full = m.forward(&toks).unwrap();
        let mut sess = m.open_session().unwrap();
        // Append in uneven chunks; rows must still match the one-shot pass.
        sess.append(&toks[..3]).unwrap();
        sess.append(&toks[3..4]).unwrap();
        sess.append(&toks[4..]).unwrap();
        for t in 0..toks.len() {
            assert_eq!(sess.row(t), full.row(t), "row {t}");
        }
    }

    #[test]
    fn session_rollback_restores_rows_bit_identically() {
        let m = MockModel::new("m", 64, 16, 7, 0.5);
        let mut sess = m.open_session().unwrap();
        sess.append(&[1, 2, 3, 4, 5]).unwrap();
        let keep: Vec<Vec<f32>> = (0..3).map(|t| sess.row(t).to_vec()).collect();
        sess.rollback(3).unwrap();
        assert_eq!(sess.len(), 3);
        for (t, row) in keep.iter().enumerate() {
            assert_eq!(sess.row(t), &row[..], "row {t} changed across rollback");
        }
        // Diverge after the rollback point: rows must match a fresh forward.
        sess.append(&[9, 9]).unwrap();
        let full = m.forward(&[1, 2, 3, 9, 9]).unwrap();
        for t in 0..5 {
            assert_eq!(sess.row(t), full.row(t), "row {t}");
        }
    }

    #[test]
    fn session_counts_appends_as_calls_and_respects_cost() {
        let m = MockModel::new("m", 32, 8, 0, 0.0).with_cost(Duration::from_millis(1));
        let mut sess = m.open_session().unwrap();
        sess.append(&[1, 2, 3]).unwrap();
        sess.append(&[4]).unwrap();
        sess.append(&[]).unwrap(); // no-op, must not count
        assert_eq!(m.calls(), 2);
        assert!(m.total_time() >= Duration::from_millis(2));
        sess.rollback(1).unwrap(); // free, must not count
        assert_eq!(m.calls(), 2);
    }

    #[test]
    fn batched_append_rows_identical_one_call() {
        let m = MockModel::new("m", 64, 16, 7, 0.5);
        let mut solo = m.open_session().unwrap();
        solo.append(&[1, 2, 3]).unwrap();
        solo.append(&[4, 5]).unwrap();
        m.reset_counters();
        // Two sessions coalesced into one engine call.
        let mut a = m.open_session().unwrap();
        let mut b = m.open_session().unwrap();
        a.absorb_batched(&[1, 2, 3], None).unwrap();
        let entries: Vec<(u64, Arc<[Token]>)> = vec![
            (a.batch_handle().unwrap(), Arc::from(&[4, 5][..])),
            (b.batch_handle().unwrap(), Arc::from(&[1, 2, 3][..])),
        ];
        let results = m.append_batch(&entries).unwrap();
        assert_eq!(results.len(), 2);
        a.absorb_batched(&entries[0].1, results[0].as_ref().unwrap().clone()).unwrap();
        b.absorb_batched(&entries[1].1, results[1].as_ref().unwrap().clone()).unwrap();
        assert_eq!(m.calls(), 1, "one coalesced call for the whole batch");
        for t in 0..5 {
            assert_eq!(a.row(t), solo.row(t), "row {t}");
        }
        for t in 0..3 {
            assert_eq!(b.row(t), solo.row(t), "row {t}");
        }
    }

    #[test]
    fn counters_track_calls() {
        let m = MockModel::new("m", 8, 4, 0, 0.0);
        m.forward(&[1]).unwrap();
        m.forward(&[1, 2]).unwrap();
        assert_eq!(m.calls(), 2);
        m.reset_counters();
        assert_eq!(m.calls(), 0);
    }

    #[test]
    fn token_cost_scales_with_suffix_not_prefix() {
        let m = MockModel::new("m", 256, 8, 0, 0.0)
            .with_cost(Duration::from_millis(1))
            .with_token_cost(Duration::from_micros(200));
        let long: Vec<Token> = (0..100).map(|i| (i % 8) as Token).collect();
        // Stateless forward pays per prefix token: >= 1ms + 100 * 200us.
        let t0 = Instant::now();
        m.forward(&long).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(21));
        // A session append over the same 100-token prefix pays only for the
        // 2-token suffix: >= 1ms + 2 * 200us, and well under the stateless
        // bound even on noisy timers.
        let mut sess = m.open_session().unwrap();
        sess.absorb_batched(&long, None).unwrap(); // install prefix, no cost
        let t1 = Instant::now();
        sess.append(&[1, 2]).unwrap();
        let dt = t1.elapsed();
        assert!(dt >= Duration::from_micros(1400), "append too fast: {dt:?}");
        // Batched path: one launch, cost covers total suffix tokens only.
        let entries: Vec<(u64, Arc<[Token]>)> = vec![
            (0, Arc::from(&[3][..])),
            (0, Arc::from(&[4, 5][..])),
        ];
        let t2 = Instant::now();
        m.append_batch(&entries).unwrap();
        assert!(t2.elapsed() >= Duration::from_micros(1600)); // 1ms + 3 tokens
    }

    #[test]
    fn cost_is_respected() {
        let m = MockModel::new("m", 8, 4, 0, 0.0).with_cost(Duration::from_millis(2));
        let t0 = Instant::now();
        m.forward(&[1, 2]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(2));
        assert!(m.cost_ms() >= 2.0);
    }
}
