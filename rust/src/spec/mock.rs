//! Deterministic mock language models for fast, artifact-free testing.
//!
//! A [`MockModel`] derives each next-token distribution from a hash of the
//! context prefix, blended between a shared "oracle" distribution and
//! model-private noise.  Two mocks with the same `base_seed` and different
//! `noise` levels behave like a target and its drafters: lower noise =>
//! closer to the oracle => higher mutual acceptance.  This lets every
//! algorithm in `spec::` be exercised (and its losslessness proven
//! statistically) without PJRT artifacts.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::rng::Pcg32;
use super::types::{LanguageModel, Logits, ModelCounters, Token};

#[derive(Debug)]
pub struct MockModel {
    name: String,
    seq_len: usize,
    vocab: usize,
    base_seed: u64,
    model_seed: u64,
    /// 0.0 = identical to the oracle; larger = less faithful.
    noise: f32,
    /// Busy-wait per forward, to emulate a per-forward cost `T_i` in timing
    /// tests and theory validation.
    cost: Duration,
    counters: ModelCounters,
}

impl MockModel {
    pub fn new(name: &str, seq_len: usize, vocab: usize, base_seed: u64, noise: f32) -> Self {
        Self {
            name: name.to_string(),
            seq_len,
            vocab,
            base_seed,
            model_seed: fnv(name.as_bytes(), 0x9e3779b97f4a7c15),
            noise,
            cost: Duration::ZERO,
            counters: ModelCounters::default(),
        }
    }

    /// Emulate a per-forward cost (busy-wait, so wall-clock is realistic).
    pub fn with_cost(mut self, cost: Duration) -> Self {
        self.cost = cost;
        self
    }

    fn row_for_prefix(&self, prefix: &[Token]) -> Vec<f32> {
        let h = hash_tokens(prefix, self.base_seed);
        // Oracle logits: deterministic in (base_seed, prefix).
        let mut rng = Pcg32::new(h, 0x5851f42d4c957f2d);
        let mut logits: Vec<f32> = (0..self.vocab)
            .map(|_| 3.0 * (rng.next_f32() - 0.5))
            .collect();
        // A few "peaky" tokens so distributions are LLM-like (low entropy).
        let peak = (h % self.vocab as u64) as usize;
        logits[peak] += 4.0;
        let peak2 = ((h >> 17) % self.vocab as u64) as usize;
        logits[peak2] += 2.0;
        // Model-private perturbation.
        if self.noise > 0.0 {
            let mut nrng = Pcg32::new(h ^ self.model_seed, 0x14057b7ef767814f);
            for l in logits.iter_mut() {
                *l += self.noise * 3.0 * (nrng.next_f32() - 0.5);
            }
        }
        logits
    }
}

impl LanguageModel for MockModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn forward(&self, tokens: &[Token]) -> Result<Logits> {
        anyhow::ensure!(tokens.len() <= self.seq_len, "context too long");
        let start = Instant::now();
        let mut data = Vec::with_capacity(tokens.len() * self.vocab);
        for t in 0..tokens.len() {
            data.extend_from_slice(&self.row_for_prefix(&tokens[..=t]));
        }
        if !self.cost.is_zero() {
            while start.elapsed() < self.cost {
                std::hint::spin_loop();
            }
        }
        self.counters.record(start.elapsed());
        Ok(Logits::new(data, tokens.len(), self.vocab))
    }

    fn calls(&self) -> u64 {
        self.counters.calls()
    }

    fn total_time(&self) -> Duration {
        self.counters.total_time()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }
}

fn hash_tokens(tokens: &[Token], seed: u64) -> u64 {
    let mut h = seed ^ 0xcbf29ce484222325;
    for &t in tokens {
        h = fnv(&t.to_le_bytes(), h);
    }
    h
}

fn fnv(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A standard mock chain for tests: target (noise 0), intermediate, draft.
pub fn mock_chain(seq_len: usize, vocab: usize, seed: u64) -> Vec<std::sync::Arc<dyn LanguageModel>> {
    vec![
        std::sync::Arc::new(MockModel::new("mock-target", seq_len, vocab, seed, 0.0)),
        std::sync::Arc::new(MockModel::new("mock-mid", seq_len, vocab, seed, 0.35)),
        std::sync::Arc::new(MockModel::new("mock-draft", seq_len, vocab, seed, 0.8)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::softmax;

    #[test]
    fn deterministic_per_prefix() {
        let m = MockModel::new("m", 32, 16, 7, 0.5);
        let a = m.forward(&[1, 2, 3]).unwrap();
        let b = m.forward(&[1, 2, 3]).unwrap();
        assert_eq!(a.row(2), b.row(2));
    }

    #[test]
    fn rows_depend_only_on_prefix() {
        // KV-consistency: row t must not change when later tokens change.
        let m = MockModel::new("m", 32, 16, 7, 0.5);
        let a = m.forward(&[1, 2, 3, 4]).unwrap();
        let b = m.forward(&[1, 2, 3, 9]).unwrap();
        assert_eq!(a.row(1), b.row(1));
        assert_eq!(a.row(2), b.row(2));
        assert_ne!(a.row(3), b.row(3));
    }

    #[test]
    fn noise_orders_similarity() {
        // Acceptance proxy sum(min(p, q)) must decrease with noise.
        let target = MockModel::new("t", 64, 32, 3, 0.0);
        let close = MockModel::new("c", 64, 32, 3, 0.3);
        let far = MockModel::new("f", 64, 32, 3, 1.5);
        let ctx: Vec<Token> = (0..40).map(|i| (i * 7 % 32) as Token).collect();
        let lt = target.forward(&ctx).unwrap();
        let lc = close.forward(&ctx).unwrap();
        let lf = far.forward(&ctx).unwrap();
        let overlap = |a: &Logits, b: &Logits| -> f64 {
            (0..ctx.len())
                .map(|t| {
                    let p = softmax(a.row(t), 1.0);
                    let q = softmax(b.row(t), 1.0);
                    p.iter().zip(&q).map(|(&x, &y)| x.min(y) as f64).sum::<f64>()
                })
                .sum::<f64>()
                / ctx.len() as f64
        };
        let oc = overlap(&lt, &lc);
        let of = overlap(&lt, &lf);
        assert!(oc > of + 0.05, "close {oc} vs far {of}");
        assert!(oc > 0.6, "close overlap too low: {oc}");
    }

    #[test]
    fn counters_track_calls() {
        let m = MockModel::new("m", 8, 4, 0, 0.0);
        m.forward(&[1]).unwrap();
        m.forward(&[1, 2]).unwrap();
        assert_eq!(m.calls(), 2);
        m.reset_counters();
        assert_eq!(m.calls(), 0);
    }

    #[test]
    fn cost_is_respected() {
        let m = MockModel::new("m", 8, 4, 0, 0.0).with_cost(Duration::from_millis(2));
        let t0 = Instant::now();
        m.forward(&[1, 2]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(2));
        assert!(m.cost_ms() >= 2.0);
    }
}
