//! Dualistic (two-model) speculative decoding — the Leviathan et al. 2023
//! baseline the paper compares against (its "EAGLE2" baseline is this loop
//! with an early-exit drafter; see DESIGN.md §3).
//!
//! Kept as an independent implementation (rather than `polybasic` with n=2)
//! so the general algorithm can be cross-checked against it in tests.
//!
//! Implemented as a steppable [`DualisticTask`]: each
//! [`step`](DecodeTask::step) runs one draft-k → verify round and commits
//! the accepted block (+ replacement or bonus token); [`generate`] drives a
//! task to completion. Both models are driven through
//! [`ScoringSession`]s: drafting scores one new token per step, and a
//! rejection rolls the sessions back to the surviving prefix instead of
//! rescoring it. Call accounting matches the stateless loop exactly (k
//! draft calls + 1 target call per round), and the committed output is
//! token-for-token identical under every [`VerifyRule`] whether stepped or
//! driven to completion — the sessions change *where* rows come from, never
//! their values.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::rng::Pcg32;
use super::sampler::{self, FilterScratch};
use super::task::{
    model_key, DecodeTask, InflightState, PlannedAppend, ResumeState, StepMeter, StepOutcome,
};
use super::types::{
    reconcile, softmax_into, GenerationOutput, LanguageModel, Logits, SamplingParams,
    ScoringSession, Token, VerifyRule,
};
use super::verify::{verify_token, TokenVerdict};

#[derive(Debug, Clone, Copy)]
pub struct DualisticConfig {
    pub draft_k: usize,
    pub rule: VerifyRule,
    pub sampling: SamplingParams,
    pub max_new: usize,
}

impl Default for DualisticConfig {
    fn default() -> Self {
        Self {
            draft_k: 4,
            rule: VerifyRule::Speculative,
            sampling: SamplingParams::default(),
            max_new: 64,
        }
    }
}

/// Temperature-softmaxed, top-k/p-filtered distribution for one logits row,
/// written into `out` — the zero-alloc form of the old `dist_row`.
pub(crate) fn dist_row_into(
    row: &[f32],
    sampling: &SamplingParams,
    scratch: &mut FilterScratch,
    out: &mut Vec<f32>,
) {
    softmax_into(row, sampling.temperature.max(1e-3), out);
    sampler::filter_top_kp_scratch(out, sampling.top_k, sampling.top_p, scratch);
}

pub(crate) fn pick(probs: &mut [f32], sampling: &SamplingParams, rule: VerifyRule,
                   rng: &mut Pcg32) -> Token {
    match rule {
        VerifyRule::Greedy => sampler::argmax(probs),
        _ => {
            if sampling.temperature <= 1e-3 {
                sampler::argmax(probs)
            } else {
                sampler::sample_categorical(probs, rng)
            }
        }
    }
}

/// Standard draft-then-verify speculative decoding as a resumable state
/// machine: one `step` = draft up to `k` tokens, verify them with one
/// target scoring, commit the accepted prefix (+ replacement or bonus).
///
/// Degrades gracefully: if the drafter errors or turns unhealthy, its
/// session is dropped (`dsess = None`) and subsequent steps decode
/// autoregressively on the target — only the target verifies, so the
/// committed-token distribution (and greedy byte-identity) is unchanged.
/// Only a target failure fails the request.
pub struct DualisticTask<'m> {
    target: &'m dyn LanguageModel,
    draft: &'m dyn LanguageModel,
    tsess: Box<dyn ScoringSession + 'm>,
    /// `None` once the drafter has been dropped (graceful degradation).
    dsess: Option<Box<dyn ScoringSession + 'm>>,
    cfg: DualisticConfig,
    rng: Pcg32,
    scratch: FilterScratch,
    /// prompt + committed tokens (may briefly exceed the budget by the
    /// bonus token; `committed()` caps the view).
    ctx: Vec<Token>,
    prompt_len: usize,
    // Buffers reused across rounds: the drafted block, its proposal
    // distributions, the verifier row under scrutiny, and the frontier
    // (ctx + block) the sessions reconcile against.
    block: Vec<Token>,
    q_rows: Vec<Vec<f32>>,
    p: Vec<f32>,
    frontier: Vec<Token>,
    accept_lengths: Vec<u32>,
    /// Failure delivered by [`DecodeTask::absorb_append`], surfaced by the
    /// next `step` exactly like the equivalent in-step append failure.
    pending_fault: Option<anyhow::Error>,
    meter: StepMeter,
}

impl<'m> DualisticTask<'m> {
    pub fn new(
        target: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        prompt: &[Token],
        cfg: DualisticConfig,
    ) -> Result<Self> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(cfg.draft_k >= 1, "draft_k must be >= 1");
        let seq_cap = target.seq_len().min(draft.seq_len());
        anyhow::ensure!(
            prompt.len() + cfg.max_new + cfg.draft_k + 1 <= seq_cap,
            "request does not fit the context window"
        );
        // A drafter that is already unhealthy — or whose session fails to
        // open — is degradation, not an error: start target-only.
        let dsess = if draft.healthy() { draft.open_session().ok() } else { None };
        Ok(Self {
            target,
            draft,
            tsess: target.open_session().map_err(|e| e.context("opening target session"))?,
            dsess,
            rng: Pcg32::seeded(cfg.sampling.seed),
            cfg,
            scratch: FilterScratch::default(),
            ctx: prompt.to_vec(),
            prompt_len: prompt.len(),
            block: Vec::new(),
            q_rows: Vec::new(),
            p: Vec::new(),
            frontier: Vec::new(),
            accept_lengths: Vec::new(),
            pending_fault: None,
            meter: StepMeter::new(2),
        })
    }

    /// Re-open a suspended decode from `prompt + state`; see
    /// [`DecodeTask::suspend`]. Fresh sessions re-score the committed
    /// prefix on the next step's `reconcile`, after which decode continues
    /// byte-identically to an uninterrupted run.
    pub fn resume(
        target: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        prompt: &[Token],
        cfg: DualisticConfig,
        state: ResumeState,
    ) -> Result<Self> {
        anyhow::ensure!(
            state.committed.len() <= cfg.max_new,
            "resume state carries {} tokens for a budget of {}",
            state.committed.len(),
            cfg.max_new
        );
        anyhow::ensure!(state.forward_passes.len() == 2, "dualistic resume needs two models");
        anyhow::ensure!(
            matches!(state.inflight, InflightState::None),
            "dualistic tasks carry no in-flight state"
        );
        anyhow::ensure!(
            state.live_models.is_empty() || state.live_models[0] == 0,
            "live chain must include the target"
        );
        let mut task = Self::new(target, draft, prompt, cfg)?;
        if state.live_models == [0] {
            // The drafter was dropped before suspension: resume target-only
            // instead of re-opening a session on a dead model.
            task.dsess = None;
        }
        task.ctx.extend_from_slice(&state.committed);
        task.rng = state.rng;
        task.accept_lengths = state.accept_lengths;
        task.meter = StepMeter::resumed(state.wall, state.forward_passes, state.forward_time);
        Ok(task)
    }

    /// Drop the drafter at a step boundary; the decode continues
    /// autoregressively on the target.
    fn drop_draft(&mut self) {
        self.dsess = None; // Box drop closes the engine session
    }
}

impl DecodeTask for DualisticTask<'_> {
    fn committed(&self) -> &[Token] {
        let end = (self.prompt_len + self.cfg.max_new).min(self.ctx.len());
        &self.ctx[self.prompt_len..end]
    }

    fn finished(&self) -> bool {
        self.ctx.len() - self.prompt_len >= self.cfg.max_new
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.finished() {
            return Ok(StepOutcome::Finished { new_tokens: 0 });
        }
        if let Some(e) = self.pending_fault.take() {
            // A batched pre-append failed. Same trichotomy as in-step: a
            // drafter failure degrades to target-only, a target failure
            // fails the request.
            if self.dsess.is_some() {
                self.drop_draft();
                return Ok(StepOutcome::Progress { new_tokens: 0 });
            }
            return Err(e);
        }
        // Proactive health check: a drafter whose breaker opened is
        // dropped before wasting calls on it.
        if self.dsess.is_some() && !self.draft.healthy() {
            self.drop_draft();
        }
        let models: [&dyn LanguageModel; 2] = [self.target, self.draft];
        self.meter.begin(&models);
        let before = self.committed().len();

        let remaining = self.cfg.max_new - (self.ctx.len() - self.prompt_len);
        let k = self.cfg.draft_k.min(remaining);

        // ---- degraded path: plain autoregressive on the target -----------
        if self.dsess.is_none() {
            let r = reconcile(&mut *self.tsess, &self.ctx);
            self.meter.end(&models);
            r?;
            dist_row_into(
                self.tsess.row(self.ctx.len() - 1),
                &self.cfg.sampling,
                &mut self.scratch,
                &mut self.p,
            );
            let tok = pick(&mut self.p, &self.cfg.sampling, self.cfg.rule, &mut self.rng);
            self.ctx.push(tok);
            self.accept_lengths.push(1);
            let new_tokens = self.committed().len() - before;
            return Ok(if self.finished() {
                StepOutcome::Finished { new_tokens }
            } else {
                StepOutcome::Progress { new_tokens }
            });
        }

        // ---- draft k tokens, scoring only the unscored suffix ------------
        self.frontier.clear();
        self.frontier.extend_from_slice(&self.ctx);
        self.block.clear();
        let mut draft_failed = false;
        if let Some(dsess) = self.dsess.as_mut() {
            match reconcile(&mut **dsess, &self.frontier) {
                Err(_) => draft_failed = true,
                Ok(()) => {
                    while self.q_rows.len() < k {
                        self.q_rows.push(Vec::new());
                    }
                    for (i, q) in self.q_rows.iter_mut().enumerate().take(k) {
                        dist_row_into(dsess.row(self.frontier.len() - 1), &self.cfg.sampling,
                                      &mut self.scratch, q);
                        let tok = pick(q, &self.cfg.sampling, self.cfg.rule, &mut self.rng);
                        self.block.push(tok);
                        self.frontier.push(tok);
                        // The last drafted token's row is only needed if
                        // drafting continues from it next round; score it
                        // lazily then.
                        if i + 1 < k && dsess.append(&[tok]).is_err() {
                            draft_failed = true;
                            break;
                        }
                    }
                }
            }
        }
        if draft_failed {
            // Drafter failure is degradation, not an error: discard the
            // partial block (uncommitted speculation is free to drop) and
            // continue target-only from the next step.
            self.drop_draft();
            self.meter.end(&models);
            return Ok(StepOutcome::Progress { new_tokens: 0 });
        }

        // ---- one target scoring of the block (+ the bonus row) -----------
        reconcile(&mut *self.tsess, &self.frontier)?;
        let base = self.ctx.len();
        let mut accepted = 0usize;
        let mut replacement: Option<Token> = None;
        for i in 0..k {
            dist_row_into(
                self.tsess.row(base - 1 + i),
                &self.cfg.sampling,
                &mut self.scratch,
                &mut self.p,
            );
            match verify_token(self.block[i], &self.p, &self.q_rows[i], self.cfg.rule, &mut self.rng)
            {
                TokenVerdict::Accepted => accepted += 1,
                TokenVerdict::Rejected { replacement: r } => {
                    replacement = Some(r);
                    break;
                }
            }
        }

        self.ctx.extend_from_slice(&self.block[..accepted]);
        let mut committed_now = accepted;
        if let Some(r) = replacement {
            self.ctx.push(r);
            committed_now += 1;
        } else {
            // Full acceptance: the target's row after the last drafted token
            // yields a free bonus token.
            dist_row_into(
                self.tsess.row(base + k - 1),
                &self.cfg.sampling,
                &mut self.scratch,
                &mut self.p,
            );
            let bonus = pick(&mut self.p, &self.cfg.sampling, self.cfg.rule, &mut self.rng);
            self.ctx.push(bonus);
            committed_now += 1;
        }
        self.accept_lengths.push(committed_now as u32);
        self.meter.end(&models);

        let new_tokens = self.committed().len() - before;
        if self.finished() {
            Ok(StepOutcome::Finished { new_tokens })
        } else {
            Ok(StepOutcome::Progress { new_tokens })
        }
    }

    fn finish(self: Box<Self>) -> GenerationOutput {
        let end = (self.prompt_len + self.cfg.max_new).min(self.ctx.len());
        let tokens = self.ctx[self.prompt_len..end].to_vec();
        let accept_lengths = self.accept_lengths;
        let degraded = if self.dsess.is_none() { 1 } else { 0 };
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        GenerationOutput {
            tokens,
            wall,
            forward_passes,
            forward_time,
            accept_lengths,
            stage_accept_lengths: vec![],
            degraded,
        }
    }

    fn suspend(self: Box<Self>) -> ResumeState {
        let committed = self.ctx[self.prompt_len..].to_vec();
        let live_models = if self.dsess.is_none() { vec![0] } else { vec![0, 1] };
        let degraded = if self.dsess.is_none() { 1 } else { 0 };
        let (wall, forward_passes, forward_time) = self.meter.into_parts();
        ResumeState {
            committed,
            rng: self.rng,
            accept_lengths: self.accept_lengths,
            stage_accepts: vec![],
            wall,
            forward_passes,
            forward_time,
            inflight: InflightState::None,
            live_models,
            degraded,
            swap: None,
        }
    }

    fn degraded(&self) -> u32 {
        if self.dsess.is_none() {
            1
        } else {
            0
        }
    }

    fn plan_append(&mut self) -> Option<PlannedAppend> {
        if self.finished() || self.pending_fault.is_some() {
            return None;
        }
        // The next step's first engine call is the drafter's catch-up
        // reconcile (or the target's, once degraded). Coalescible iff that
        // reconcile is a pure non-empty append.
        let (model, sess) = match self.dsess.as_ref() {
            Some(dsess) => {
                if !self.draft.healthy() {
                    return None; // the next step will drop the drafter
                }
                (self.draft, &**dsess)
            }
            None => (self.target, &*self.tsess),
        };
        let handle = sess.batch_handle()?;
        let have = sess.len();
        if have >= self.ctx.len() || sess.tokens() != &self.ctx[..have] {
            return None; // rollback-first reconcile: not a pure append
        }
        Some(PlannedAppend {
            model_key: model_key(model),
            handle,
            tokens: Arc::from(&self.ctx[have..]),
            prefix_len: have,
        })
    }

    fn absorb_append(&mut self, rows: Result<Option<Logits>>) {
        let (idx, sess) = match self.dsess.as_mut() {
            Some(dsess) => (1, &mut **dsess),
            None => (0, &mut *self.tsess),
        };
        let have = sess.len();
        let suffix: Vec<Token> = self.ctx[have..].to_vec();
        match rows.and_then(|r| sess.absorb_batched(&suffix, r)) {
            // The batch charged the model counters once; per-task pass
            // accounting stays solo-equivalent via an explicit charge.
            Ok(()) => self.meter.charge(idx, Duration::ZERO),
            Err(e) => self.pending_fault = Some(e),
        }
    }
}

/// Standard draft-then-verify speculative decoding, driven to completion.
pub fn generate(
    target: &dyn LanguageModel,
    draft: &dyn LanguageModel,
    prompt: &[Token],
    cfg: &DualisticConfig,
) -> Result<GenerationOutput> {
    target.reset_counters();
    draft.reset_counters();
    let mut task = DualisticTask::new(target, draft, prompt, *cfg)?;
    while !task.finished() {
        task.step()?;
    }
    Ok(Box::new(task).finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::autoregressive;
    use crate::spec::mock::MockModel;
    use crate::spec::types::ForceStateless;

    fn models() -> (MockModel, MockModel) {
        (
            MockModel::new("t", 256, 24, 5, 0.0),
            MockModel::new("d", 256, 24, 5, 0.5),
        )
    }

    #[test]
    fn greedy_matches_target_greedy_decode() {
        // The defining correctness property of greedy verification.
        let (t, d) = models();
        let cfg = DualisticConfig {
            rule: VerifyRule::Greedy,
            sampling: SamplingParams { temperature: 0.0, ..Default::default() },
            max_new: 40,
            ..Default::default()
        };
        let spec = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
        let ar = autoregressive::generate(
            &t,
            &[3, 1, 4],
            40,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(spec.tokens, ar.tokens);
    }

    #[test]
    fn uses_fewer_target_forwards_than_ar() {
        let (t, d) = models();
        let cfg = DualisticConfig { max_new: 48, ..Default::default() };
        let out = generate(&t, &d, &[2, 7], &cfg).unwrap();
        assert_eq!(out.tokens.len(), 48);
        assert!(
            out.forward_passes[0] < 48,
            "target forwards {} not reduced",
            out.forward_passes[0]
        );
        let mu = out.mean_accept();
        assert!(mu > 1.0, "mean accept {mu}");
    }

    #[test]
    fn acceptance_bounded_by_k_plus_one() {
        let (t, d) = models();
        let cfg = DualisticConfig { draft_k: 4, max_new: 60, ..Default::default() };
        let out = generate(&t, &d, &[2], &cfg).unwrap();
        assert!(out.accept_lengths.iter().all(|&a| a >= 1 && a <= 5));
    }

    #[test]
    fn identical_draft_accepts_everything() {
        let t = MockModel::new("t", 256, 24, 5, 0.0);
        let d = MockModel::new("t", 256, 24, 5, 0.0); // same name -> same noise stream
        let cfg = DualisticConfig { draft_k: 4, max_new: 40, ..Default::default() };
        let out = generate(&t, &d, &[1], &cfg).unwrap();
        // Perfect drafter: every block fully accepted (k + bonus).
        assert!(out.mean_accept() > 4.9, "mu = {}", out.mean_accept());
    }

    #[test]
    fn session_decode_identical_to_stateless_all_rules() {
        for rule in [
            VerifyRule::Greedy,
            VerifyRule::Speculative,
            VerifyRule::Typical { eps: 0.25 },
        ] {
            let temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
            let cfg = DualisticConfig {
                rule,
                sampling: SamplingParams { temperature, seed: 11, ..Default::default() },
                max_new: 40,
                ..Default::default()
            };
            let (t, d) = models();
            let cached = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
            let (t, d) = models();
            let stateless =
                generate(&ForceStateless(t), &ForceStateless(d), &[3, 1, 4], &cfg).unwrap();
            assert_eq!(cached.tokens, stateless.tokens, "{rule:?}");
            assert_eq!(cached.forward_passes, stateless.forward_passes, "{rule:?}");
        }
    }

    #[test]
    fn stepped_task_matches_generate() {
        let (t, d) = models();
        let cfg = DualisticConfig {
            sampling: SamplingParams { seed: 23, ..Default::default() },
            max_new: 37,
            ..Default::default()
        };
        let whole = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
        t.reset_counters();
        d.reset_counters();
        let mut task = DualisticTask::new(&t, &d, &[3, 1, 4], cfg).unwrap();
        let mut streamed: Vec<Token> = Vec::new();
        while !task.finished() {
            let before = task.committed().len();
            let outcome = task.step().unwrap();
            let after = task.committed().len();
            assert_eq!(outcome.new_tokens(), after - before);
            streamed.extend_from_slice(&task.committed()[before..]);
        }
        assert_eq!(streamed, whole.tokens);
        let out = Box::new(task).finish();
        assert_eq!(out.tokens, whole.tokens);
        assert_eq!(out.forward_passes, whole.forward_passes);
        assert_eq!(out.accept_lengths, whole.accept_lengths);
    }

    #[test]
    fn drafter_fault_degrades_to_target_only_greedy_identical() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let cfg = DualisticConfig {
            rule: VerifyRule::Greedy,
            sampling: SamplingParams { temperature: 0.0, ..Default::default() },
            max_new: 40,
            ..Default::default()
        };
        let (t, d) = models();
        let clean = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
        let (t, d) = models();
        let d = ChaosModel::new(d).fault_at(5, Fault::Lost);
        let out = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
        assert_eq!(out.tokens, clean.tokens, "degradation changed greedy output");
        assert_eq!(out.degraded, 1);
        assert_eq!(out.tokens.len(), 40, "budget still fully committed");
    }

    #[test]
    fn degraded_suspend_resumes_target_only() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let cfg = DualisticConfig {
            rule: VerifyRule::Greedy,
            sampling: SamplingParams { temperature: 0.0, ..Default::default() },
            max_new: 30,
            ..Default::default()
        };
        let (t, d) = models();
        let clean = generate(&t, &d, &[2, 7], &cfg).unwrap();
        let (t, d) = models();
        let d = ChaosModel::new(d).fault_at(0, Fault::Lost);
        let mut task = DualisticTask::new(&t, &d, &[2, 7], cfg).unwrap();
        task.step().unwrap(); // drafter dies here
        assert_eq!(task.degraded(), 1);
        let state = Box::new(task).suspend();
        assert_eq!(state.live_models, vec![0]);
        let mut task = DualisticTask::resume(&t, &d, &[2, 7], cfg, state).unwrap();
        assert_eq!(task.degraded(), 1, "resume must not re-open the dead drafter");
        while !task.finished() {
            task.step().unwrap();
        }
        assert_eq!(Box::new(task).finish().tokens, clean.tokens);
    }

    #[test]
    fn target_fault_fails_the_request() {
        use crate::spec::chaos::{ChaosModel, Fault};
        let cfg = DualisticConfig { max_new: 30, ..Default::default() };
        let (t, d) = models();
        let t = ChaosModel::new(t).fault_at(0, Fault::Lost);
        assert!(generate(&t, &d, &[1], &cfg).is_err());
    }

    #[test]
    fn suspend_resume_mid_decode_is_byte_identical() {
        for rule in [VerifyRule::Greedy, VerifyRule::Speculative] {
            let cfg = DualisticConfig {
                rule,
                sampling: SamplingParams {
                    temperature: if rule == VerifyRule::Greedy { 0.0 } else { 1.0 },
                    seed: 31,
                    ..Default::default()
                },
                max_new: 44,
                ..Default::default()
            };
            let (t, d) = models();
            let whole = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
            let mut task = DualisticTask::new(&t, &d, &[3, 1, 4], cfg).unwrap();
            for _ in 0..3 {
                task.step().unwrap();
            }
            let state = Box::new(task).suspend();
            let mut task = DualisticTask::resume(&t, &d, &[3, 1, 4], cfg, state).unwrap();
            while !task.finished() {
                task.step().unwrap();
            }
            let out = Box::new(task).finish();
            assert_eq!(out.tokens, whole.tokens, "{rule:?}: resumed decode diverged");
            assert_eq!(out.accept_lengths, whole.accept_lengths, "{rule:?}");
        }
    }
}
