//! Dualistic (two-model) speculative decoding — the Leviathan et al. 2023
//! baseline the paper compares against (its "EAGLE2" baseline is this loop
//! with an early-exit drafter; see DESIGN.md §3).
//!
//! Kept as an independent implementation (rather than `polybasic` with n=2)
//! so the general algorithm can be cross-checked against it in tests.

use std::time::Instant;

use anyhow::Result;

use super::rng::Pcg32;
use super::sampler::{self, filter_top_kp};
use super::types::{GenerationOutput, LanguageModel, SamplingParams, Token, VerifyRule};
use super::verify::{verify_block, BlockVerdict};

#[derive(Debug, Clone, Copy)]
pub struct DualisticConfig {
    pub draft_k: usize,
    pub rule: VerifyRule,
    pub sampling: SamplingParams,
    pub max_new: usize,
}

impl Default for DualisticConfig {
    fn default() -> Self {
        Self {
            draft_k: 4,
            rule: VerifyRule::Speculative,
            sampling: SamplingParams::default(),
            max_new: 64,
        }
    }
}

/// Temperature-softmaxed, top-k/p-filtered distribution at `pos`.
pub(crate) fn dist_row(
    logits: &super::types::Logits,
    pos: usize,
    sampling: &SamplingParams,
) -> Vec<f32> {
    let mut p = logits.probs(pos, sampling.temperature.max(1e-3));
    filter_top_kp(&mut p, sampling.top_k, sampling.top_p);
    p
}

pub(crate) fn pick(probs: &mut [f32], sampling: &SamplingParams, rule: VerifyRule,
                   rng: &mut Pcg32) -> Token {
    match rule {
        VerifyRule::Greedy => sampler::argmax(probs),
        _ => {
            if sampling.temperature <= 1e-3 {
                sampler::argmax(probs)
            } else {
                sampler::sample_categorical(probs, rng)
            }
        }
    }
}

/// Standard draft-then-verify speculative decoding.
pub fn generate(
    target: &dyn LanguageModel,
    draft: &dyn LanguageModel,
    prompt: &[Token],
    cfg: &DualisticConfig,
) -> Result<GenerationOutput> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(cfg.draft_k >= 1, "draft_k must be >= 1");
    let seq_cap = target.seq_len().min(draft.seq_len());
    anyhow::ensure!(
        prompt.len() + cfg.max_new + cfg.draft_k + 1 <= seq_cap,
        "request does not fit the context window"
    );
    target.reset_counters();
    draft.reset_counters();
    let start = Instant::now();
    let mut rng = Pcg32::seeded(cfg.sampling.seed);
    let mut ctx = prompt.to_vec();
    let mut accept_lengths = Vec::new();

    while ctx.len() - prompt.len() < cfg.max_new {
        let remaining = cfg.max_new - (ctx.len() - prompt.len());
        let k = cfg.draft_k.min(remaining);

        // Draft k tokens autoregressively with the small model.
        let mut block: Vec<Token> = Vec::with_capacity(k);
        let mut q_rows: Vec<Vec<f32>> = Vec::with_capacity(k);
        let mut frontier = ctx.clone();
        for _ in 0..k {
            let logits = draft.forward(&frontier)?;
            let mut q = dist_row(&logits, frontier.len() - 1, &cfg.sampling);
            let tok = pick(&mut q, &cfg.sampling, cfg.rule, &mut rng);
            q_rows.push(q);
            block.push(tok);
            frontier.push(tok);
        }

        // One target forward scores the whole block (+ the bonus position).
        let logits = target.forward(&frontier)?;
        let base = ctx.len();
        let p_rows: Vec<Vec<f32>> =
            (0..k).map(|i| dist_row(&logits, base - 1 + i, &cfg.sampling)).collect();

        let BlockVerdict { accepted, replacement } =
            verify_block(&block, &p_rows, &q_rows, cfg.rule, &mut rng);

        let mut committed = 0usize;
        for &tok in &block[..accepted] {
            ctx.push(tok);
            committed += 1;
        }
        if let Some(r) = replacement {
            ctx.push(r);
            committed += 1;
        } else {
            // Full acceptance: the target's row after the last drafted token
            // yields a free bonus token.
            let mut p = dist_row(&logits, base + k - 1, &cfg.sampling);
            let bonus = pick(&mut p, &cfg.sampling, cfg.rule, &mut rng);
            ctx.push(bonus);
            committed += 1;
        }
        accept_lengths.push(committed as u32);
    }

    ctx.truncate(prompt.len() + cfg.max_new);
    Ok(GenerationOutput {
        tokens: ctx[prompt.len()..].to_vec(),
        wall: start.elapsed(),
        forward_passes: vec![target.calls(), draft.calls()],
        forward_time: vec![target.total_time(), draft.total_time()],
        accept_lengths,
        stage_accept_lengths: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::autoregressive;
    use crate::spec::mock::MockModel;

    fn models() -> (MockModel, MockModel) {
        (
            MockModel::new("t", 256, 24, 5, 0.0),
            MockModel::new("d", 256, 24, 5, 0.5),
        )
    }

    #[test]
    fn greedy_matches_target_greedy_decode() {
        // The defining correctness property of greedy verification.
        let (t, d) = models();
        let cfg = DualisticConfig {
            rule: VerifyRule::Greedy,
            sampling: SamplingParams { temperature: 0.0, ..Default::default() },
            max_new: 40,
            ..Default::default()
        };
        let spec = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
        let ar = autoregressive::generate(
            &t,
            &[3, 1, 4],
            40,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(spec.tokens, ar.tokens);
    }

    #[test]
    fn uses_fewer_target_forwards_than_ar() {
        let (t, d) = models();
        let cfg = DualisticConfig { max_new: 48, ..Default::default() };
        let out = generate(&t, &d, &[2, 7], &cfg).unwrap();
        assert_eq!(out.tokens.len(), 48);
        assert!(
            out.forward_passes[0] < 48,
            "target forwards {} not reduced",
            out.forward_passes[0]
        );
        let mu = out.mean_accept();
        assert!(mu > 1.0, "mean accept {mu}");
    }

    #[test]
    fn acceptance_bounded_by_k_plus_one() {
        let (t, d) = models();
        let cfg = DualisticConfig { draft_k: 4, max_new: 60, ..Default::default() };
        let out = generate(&t, &d, &[2], &cfg).unwrap();
        assert!(out.accept_lengths.iter().all(|&a| a >= 1 && a <= 5));
    }

    #[test]
    fn identical_draft_accepts_everything() {
        let t = MockModel::new("t", 256, 24, 5, 0.0);
        let d = MockModel::new("t", 256, 24, 5, 0.0); // same name -> same noise stream
        let cfg = DualisticConfig { draft_k: 4, max_new: 40, ..Default::default() };
        let out = generate(&t, &d, &[1], &cfg).unwrap();
        // Perfect drafter: every block fully accepted (k + bonus).
        assert!(out.mean_accept() > 4.9, "mu = {}", out.mean_accept());
    }
}
