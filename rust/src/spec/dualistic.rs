//! Dualistic (two-model) speculative decoding — the Leviathan et al. 2023
//! baseline the paper compares against (its "EAGLE2" baseline is this loop
//! with an early-exit drafter; see DESIGN.md §3).
//!
//! Kept as an independent implementation (rather than `polybasic` with n=2)
//! so the general algorithm can be cross-checked against it in tests.
//!
//! Both models are driven through [`ScoringSession`]s: drafting scores one
//! new token per step, and a rejection rolls the sessions back to the
//! surviving prefix instead of rescoring it. Call accounting matches the
//! stateless loop exactly (k draft calls + 1 target call per round), and
//! the committed output is token-for-token identical under every
//! [`VerifyRule`] — the sessions change *where* rows come from, never their
//! values.

use std::time::Instant;

use anyhow::Result;

use super::rng::Pcg32;
use super::sampler::{self, FilterScratch};
use super::types::{
    reconcile, softmax_into, GenerationOutput, LanguageModel, SamplingParams, ScoringSession,
    Token, VerifyRule,
};
use super::verify::{verify_token, TokenVerdict};

#[derive(Debug, Clone, Copy)]
pub struct DualisticConfig {
    pub draft_k: usize,
    pub rule: VerifyRule,
    pub sampling: SamplingParams,
    pub max_new: usize,
}

impl Default for DualisticConfig {
    fn default() -> Self {
        Self {
            draft_k: 4,
            rule: VerifyRule::Speculative,
            sampling: SamplingParams::default(),
            max_new: 64,
        }
    }
}

/// Temperature-softmaxed, top-k/p-filtered distribution for one logits row,
/// written into `out` — the zero-alloc form of the old `dist_row`.
pub(crate) fn dist_row_into(
    row: &[f32],
    sampling: &SamplingParams,
    scratch: &mut FilterScratch,
    out: &mut Vec<f32>,
) {
    softmax_into(row, sampling.temperature.max(1e-3), out);
    sampler::filter_top_kp_scratch(out, sampling.top_k, sampling.top_p, scratch);
}

pub(crate) fn pick(probs: &mut [f32], sampling: &SamplingParams, rule: VerifyRule,
                   rng: &mut Pcg32) -> Token {
    match rule {
        VerifyRule::Greedy => sampler::argmax(probs),
        _ => {
            if sampling.temperature <= 1e-3 {
                sampler::argmax(probs)
            } else {
                sampler::sample_categorical(probs, rng)
            }
        }
    }
}

/// Standard draft-then-verify speculative decoding.
pub fn generate(
    target: &dyn LanguageModel,
    draft: &dyn LanguageModel,
    prompt: &[Token],
    cfg: &DualisticConfig,
) -> Result<GenerationOutput> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    anyhow::ensure!(cfg.draft_k >= 1, "draft_k must be >= 1");
    let seq_cap = target.seq_len().min(draft.seq_len());
    anyhow::ensure!(
        prompt.len() + cfg.max_new + cfg.draft_k + 1 <= seq_cap,
        "request does not fit the context window"
    );
    target.reset_counters();
    draft.reset_counters();
    let start = Instant::now();
    let mut rng = Pcg32::seeded(cfg.sampling.seed);
    let mut ctx = prompt.to_vec();
    let mut accept_lengths = Vec::new();

    let mut tsess = target.open_session()?;
    let mut dsess = draft.open_session()?;
    let mut scratch = FilterScratch::default();
    // Buffers reused across rounds: the drafted block, its proposal
    // distributions, the verifier row under scrutiny, and the frontier
    // (ctx + block) the sessions reconcile against.
    let mut block: Vec<Token> = Vec::new();
    let mut q_rows: Vec<Vec<f32>> = Vec::new();
    let mut p: Vec<f32> = Vec::new();
    let mut frontier: Vec<Token> = Vec::new();

    while ctx.len() - prompt.len() < cfg.max_new {
        let remaining = cfg.max_new - (ctx.len() - prompt.len());
        let k = cfg.draft_k.min(remaining);

        // ---- draft k tokens, scoring only the unscored suffix ------------
        frontier.clear();
        frontier.extend_from_slice(&ctx);
        reconcile(&mut *dsess, &frontier)?;
        block.clear();
        while q_rows.len() < k {
            q_rows.push(Vec::new());
        }
        for (i, q) in q_rows.iter_mut().enumerate().take(k) {
            dist_row_into(dsess.row(frontier.len() - 1), &cfg.sampling, &mut scratch, q);
            let tok = pick(q, &cfg.sampling, cfg.rule, &mut rng);
            block.push(tok);
            frontier.push(tok);
            // The last drafted token's row is only needed if drafting
            // continues from it next round; score it lazily then.
            if i + 1 < k {
                dsess.append(&[tok])?;
            }
        }

        // ---- one target scoring of the block (+ the bonus row) -----------
        reconcile(&mut *tsess, &frontier)?;
        let base = ctx.len();
        let mut accepted = 0usize;
        let mut replacement: Option<Token> = None;
        for i in 0..k {
            dist_row_into(tsess.row(base - 1 + i), &cfg.sampling, &mut scratch, &mut p);
            match verify_token(block[i], &p, &q_rows[i], cfg.rule, &mut rng) {
                TokenVerdict::Accepted => accepted += 1,
                TokenVerdict::Rejected { replacement: r } => {
                    replacement = Some(r);
                    break;
                }
            }
        }

        ctx.extend_from_slice(&block[..accepted]);
        let mut committed = accepted;
        if let Some(r) = replacement {
            ctx.push(r);
            committed += 1;
        } else {
            // Full acceptance: the target's row after the last drafted token
            // yields a free bonus token.
            dist_row_into(tsess.row(base + k - 1), &cfg.sampling, &mut scratch, &mut p);
            let bonus = pick(&mut p, &cfg.sampling, cfg.rule, &mut rng);
            ctx.push(bonus);
            committed += 1;
        }
        accept_lengths.push(committed as u32);
    }

    ctx.truncate(prompt.len() + cfg.max_new);
    Ok(GenerationOutput {
        tokens: ctx[prompt.len()..].to_vec(),
        wall: start.elapsed(),
        forward_passes: vec![target.calls(), draft.calls()],
        forward_time: vec![target.total_time(), draft.total_time()],
        accept_lengths,
        stage_accept_lengths: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::autoregressive;
    use crate::spec::mock::MockModel;
    use crate::spec::types::ForceStateless;

    fn models() -> (MockModel, MockModel) {
        (
            MockModel::new("t", 256, 24, 5, 0.0),
            MockModel::new("d", 256, 24, 5, 0.5),
        )
    }

    #[test]
    fn greedy_matches_target_greedy_decode() {
        // The defining correctness property of greedy verification.
        let (t, d) = models();
        let cfg = DualisticConfig {
            rule: VerifyRule::Greedy,
            sampling: SamplingParams { temperature: 0.0, ..Default::default() },
            max_new: 40,
            ..Default::default()
        };
        let spec = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
        let ar = autoregressive::generate(
            &t,
            &[3, 1, 4],
            40,
            &SamplingParams { temperature: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(spec.tokens, ar.tokens);
    }

    #[test]
    fn uses_fewer_target_forwards_than_ar() {
        let (t, d) = models();
        let cfg = DualisticConfig { max_new: 48, ..Default::default() };
        let out = generate(&t, &d, &[2, 7], &cfg).unwrap();
        assert_eq!(out.tokens.len(), 48);
        assert!(
            out.forward_passes[0] < 48,
            "target forwards {} not reduced",
            out.forward_passes[0]
        );
        let mu = out.mean_accept();
        assert!(mu > 1.0, "mean accept {mu}");
    }

    #[test]
    fn acceptance_bounded_by_k_plus_one() {
        let (t, d) = models();
        let cfg = DualisticConfig { draft_k: 4, max_new: 60, ..Default::default() };
        let out = generate(&t, &d, &[2], &cfg).unwrap();
        assert!(out.accept_lengths.iter().all(|&a| a >= 1 && a <= 5));
    }

    #[test]
    fn identical_draft_accepts_everything() {
        let t = MockModel::new("t", 256, 24, 5, 0.0);
        let d = MockModel::new("t", 256, 24, 5, 0.0); // same name -> same noise stream
        let cfg = DualisticConfig { draft_k: 4, max_new: 40, ..Default::default() };
        let out = generate(&t, &d, &[1], &cfg).unwrap();
        // Perfect drafter: every block fully accepted (k + bonus).
        assert!(out.mean_accept() > 4.9, "mu = {}", out.mean_accept());
    }

    #[test]
    fn session_decode_identical_to_stateless_all_rules() {
        for rule in [
            VerifyRule::Greedy,
            VerifyRule::Speculative,
            VerifyRule::Typical { eps: 0.25 },
        ] {
            let temperature = if rule == VerifyRule::Greedy { 0.0 } else { 1.0 };
            let cfg = DualisticConfig {
                rule,
                sampling: SamplingParams { temperature, seed: 11, ..Default::default() },
                max_new: 40,
                ..Default::default()
            };
            let (t, d) = models();
            let cached = generate(&t, &d, &[3, 1, 4], &cfg).unwrap();
            let (t, d) = models();
            let stateless =
                generate(&ForceStateless(t), &ForceStateless(d), &[3, 1, 4], &cfg).unwrap();
            assert_eq!(cached.tokens, stateless.tokens, "{rule:?}");
            assert_eq!(cached.forward_passes, stateless.forward_passes, "{rule:?}");
        }
    }
}
