//! Theory-driven chain planner: turn measured `(T_i, L_ij)` into a chain
//! layout using Theorem 3.2, exactly the workflow §3.2 prescribes
//! ("given model inference times and acceptance probabilities, one can
//! estimate the optimal system layout via Equation (3) and gauge whether a
//! new model confers net benefit").

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::dualistic::{self, DualisticConfig};
use super::theory::{lemma31_time, InsertionCheck, InsertionVerdict};
use super::types::{LanguageModel, SamplingParams, Token, VerifyRule};

/// Measured profile of one candidate model.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    /// Per-forward cost in ms, measured on representative contexts.
    pub t_ms: f64,
}

/// Measure per-forward cost with warmup on a representative context length.
pub fn measure_cost_ms(model: &dyn LanguageModel, ctx_len: usize, iters: usize) -> f64 {
    let ctx: Vec<Token> = (0..ctx_len.min(model.seq_len()))
        .map(|i| (i % model.vocab()) as Token)
        .collect();
    // Warmup (PJRT first-call overhead, caches).
    let _ = model.forward(&ctx);
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        let _ = model.forward(&ctx);
    }
    start.elapsed().as_secs_f64() * 1e3 / iters.max(1) as f64
}

/// Measure the pairwise acceptance length `L` of `verifier` checking
/// `proposer`'s drafts (speculative rule), averaged over prompts.
pub fn measure_pair_acceptance(
    verifier: Arc<dyn LanguageModel>,
    proposer: Arc<dyn LanguageModel>,
    prompts: &[Vec<Token>],
    draft_k: usize,
    max_new: usize,
    sampling: SamplingParams,
) -> Result<f64> {
    let mut total = 0.0;
    let mut count = 0.0;
    for (i, prompt) in prompts.iter().enumerate() {
        let cfg = DualisticConfig {
            draft_k,
            rule: VerifyRule::Speculative,
            sampling: SamplingParams { seed: sampling.seed + i as u64, ..sampling },
            max_new,
        };
        let out = dualistic::generate(verifier.as_ref(), proposer.as_ref(), prompt, &cfg)?;
        total += out.mean_accept() * out.accept_lengths.len() as f64;
        count += out.accept_lengths.len() as f64;
    }
    Ok(if count > 0.0 { total / count } else { 0.0 })
}

/// One candidate insertion evaluated by Theorem 3.2.
#[derive(Debug, Clone)]
pub struct InsertionReport {
    pub candidate: String,
    pub check: InsertionCheck,
    pub verdict: InsertionVerdict,
    /// Lemma 3.1 predicted ms for a reference generation with/without.
    pub predicted_ms_without: f64,
    pub predicted_ms_with: f64,
}

/// The planner's output: the chosen chain plus the full audit trail.
#[derive(Debug, Clone)]
pub struct ChainPlan {
    /// Indices into the candidate list, target first, drafter last.
    pub order: Vec<usize>,
    pub names: Vec<String>,
    pub reports: Vec<InsertionReport>,
}

/// Decide whether to insert `candidate` between `upper` (index i) and
/// `lower` (index i+1) of an existing chain, from measurements.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_insertion(
    upper: Arc<dyn LanguageModel>,
    candidate: Arc<dyn LanguageModel>,
    lower: Arc<dyn LanguageModel>,
    t_upper_ms: f64,
    t_cand_ms: f64,
    t_lower_ms: f64,
    prompts: &[Vec<Token>],
    draft_k: usize,
    max_new: usize,
    sampling: SamplingParams,
    beta: f64,
) -> Result<InsertionReport> {
    // L_i: current pair (upper verifying lower).
    let l_i = measure_pair_acceptance(
        upper.clone(), lower.clone(), prompts, draft_k, max_new, sampling)?;
    // L_{i-new}: upper verifying the candidate.
    let l_i_new = measure_pair_acceptance(
        upper.clone(), candidate.clone(), prompts, draft_k, max_new, sampling)?;
    // L_new (a.k.a. L_{new-(i+1)}): candidate verifying lower.
    let l_new = measure_pair_acceptance(
        candidate.clone(), lower.clone(), prompts, draft_k, max_new, sampling)?;

    let check = InsertionCheck {
        t_i: t_upper_ms,
        t_new: t_cand_ms,
        t_next: t_lower_ms,
        l_i,
        l_i_new,
        l_new,
        beta,
    };
    let verdict = check.evaluate();

    let n = 100.0;
    let predicted_ms_without =
        lemma31_time(n, &[l_i], &[t_upper_ms, t_lower_ms], beta);
    let predicted_ms_with = lemma31_time(
        n,
        &[l_i_new, l_new],
        &[t_upper_ms, t_cand_ms, t_lower_ms],
        beta,
    );

    Ok(InsertionReport {
        candidate: candidate.name().to_string(),
        check,
        verdict,
        predicted_ms_without,
        predicted_ms_with,
    })
}

/// Greedy chain construction: start from (target, drafter), then try to
/// insert every remaining candidate between target and the top of the draft
/// stack, keeping insertions Theorem 3.2 endorses.
pub fn plan_chain(
    models: &[Arc<dyn LanguageModel>],
    profiles: &[ModelProfile],
    prompts: &[Vec<Token>],
    draft_k: usize,
    max_new: usize,
    sampling: SamplingParams,
    beta: f64,
) -> Result<ChainPlan> {
    anyhow::ensure!(models.len() >= 2, "need target + at least one drafter");
    anyhow::ensure!(models.len() == profiles.len());
    // Convention: models[0] = target, models[last] = cheapest drafter,
    // middle entries are insertion candidates.
    let target = 0usize;
    let drafter = models.len() - 1;
    let mut order = vec![target, drafter];
    let mut reports = Vec::new();

    for cand in 1..drafter {
        // Try inserting directly below the target (the paper's three-model
        // reference design: M1 / M_new / current draft stack top).
        let upper = order[0];
        let lower = order[1];
        let report = evaluate_insertion(
            models[upper].clone(),
            models[cand].clone(),
            models[lower].clone(),
            profiles[upper].t_ms,
            profiles[cand].t_ms,
            profiles[lower].t_ms,
            prompts,
            draft_k,
            max_new,
            sampling,
            beta,
        )?;
        if report.verdict.predicts_improvement() {
            order.insert(1, cand);
        }
        reports.push(report);
    }

    Ok(ChainPlan {
        names: order.iter().map(|&i| profiles[i].name.clone()).collect(),
        order,
        reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::mock::MockModel;
    use std::time::Duration;

    fn prompts() -> Vec<Vec<Token>> {
        vec![vec![1, 2, 3], vec![9, 8, 7, 6]]
    }

    #[test]
    fn measures_cost() {
        let m = MockModel::new("m", 64, 16, 1, 0.0).with_cost(Duration::from_millis(1));
        let t = measure_cost_ms(&m, 32, 3);
        assert!(t >= 1.0, "{t}");
    }

    #[test]
    fn pair_acceptance_orders_by_similarity() {
        let t: Arc<dyn LanguageModel> = Arc::new(MockModel::new("t", 512, 24, 3, 0.0));
        let close: Arc<dyn LanguageModel> = Arc::new(MockModel::new("c", 512, 24, 3, 0.3));
        let far: Arc<dyn LanguageModel> = Arc::new(MockModel::new("f", 512, 24, 3, 1.6));
        let sampling = SamplingParams::default();
        let lc = measure_pair_acceptance(t.clone(), close, &prompts(), 4, 24, sampling).unwrap();
        let lf = measure_pair_acceptance(t, far, &prompts(), 4, 24, sampling).unwrap();
        assert!(lc > lf, "close {lc} <= far {lf}");
    }

    #[test]
    fn planner_inserts_good_mid_rejects_decoy() {
        // good mid: cheap and close to target. decoy: expensive and far.
        let target: Arc<dyn LanguageModel> =
            Arc::new(MockModel::new("t", 512, 24, 3, 0.0).with_cost(Duration::from_micros(800)));
        let mid: Arc<dyn LanguageModel> =
            Arc::new(MockModel::new("mid", 512, 24, 3, 0.25).with_cost(Duration::from_micros(150)));
        let decoy: Arc<dyn LanguageModel> =
            Arc::new(MockModel::new("decoy", 512, 24, 991, 1.8).with_cost(Duration::from_micros(700)));
        let draft: Arc<dyn LanguageModel> =
            Arc::new(MockModel::new("d", 512, 24, 3, 0.8).with_cost(Duration::from_micros(40)));
        let models = vec![target, mid, decoy, draft];
        let profiles: Vec<ModelProfile> = [("t", 0.8), ("mid", 0.15), ("decoy", 0.7), ("d", 0.04)]
            .iter()
            .map(|(n, t)| ModelProfile { name: n.to_string(), t_ms: *t })
            .collect();
        let plan = plan_chain(
            &models,
            &profiles,
            &prompts(),
            4,
            24,
            SamplingParams::default(),
            1.0,
        )
        .unwrap();
        assert!(plan.names.contains(&"mid".to_string()), "plan {:?}", plan.names);
        assert!(!plan.names.contains(&"decoy".to_string()), "plan {:?}", plan.names);
        assert_eq!(plan.reports.len(), 2);
    }
}
