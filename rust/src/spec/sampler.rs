//! Token sampling: temperature / top-k / top-p categorical sampling and the
//! residual-distribution resampling used on speculative rejection.

use super::rng::Pcg32;
use super::types::{SamplingParams, Token};

/// Sample from a normalized probability vector.
pub fn sample_categorical(probs: &[f32], rng: &mut Pcg32) -> Token {
    let u = rng.next_f32();
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i as Token;
        }
    }
    // Float round-off: fall back to the last token with mass.
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1) as Token
}

/// Argmax with deterministic (lowest-index) tie-breaking.
pub fn argmax(xs: &[f32]) -> Token {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as Token
}

/// Reusable buffers for [`filter_top_kp_scratch`], so the decode hot path
/// pays no per-token allocation when top-k / top-p filtering is active.
#[derive(Debug, Default)]
pub struct FilterScratch {
    idx: Vec<usize>,
    keep: Vec<bool>,
}

/// Apply top-k / top-p filtering to a normalized distribution in place,
/// renormalizing afterwards. `top_k == 0` and `top_p >= 1.0` disable the
/// respective filter.
pub fn filter_top_kp(probs: &mut [f32], top_k: usize, top_p: f32) {
    filter_top_kp_scratch(probs, top_k, top_p, &mut FilterScratch::default());
}

/// [`filter_top_kp`] with caller-owned scratch buffers (identical results).
pub fn filter_top_kp_scratch(
    probs: &mut [f32],
    top_k: usize,
    top_p: f32,
    scratch: &mut FilterScratch,
) {
    let n = probs.len();
    if (top_k == 0 || top_k >= n) && top_p >= 1.0 {
        return;
    }
    let idx = &mut scratch.idx;
    idx.clear();
    idx.extend(0..n);
    // xtask:allow(panic): probs come out of softmax_into and are never NaN.
    idx.sort_unstable_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());

    let keep = &mut scratch.keep;
    keep.clear();
    keep.resize(n, false);
    let mut cum = 0.0f32;
    for (rank, &i) in idx.iter().enumerate() {
        if top_k > 0 && rank >= top_k {
            break;
        }
        keep[i] = true;
        cum += probs[i];
        if top_p < 1.0 && cum >= top_p {
            break;
        }
    }
    let mut sum = 0.0f32;
    for i in 0..n {
        if !keep[i] {
            probs[i] = 0.0;
        }
        sum += probs[i];
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for p in probs.iter_mut() {
            *p *= inv;
        }
    }
}

/// Sample a token from `logits`-derived `probs` under `params`.
/// `probs` must already be softmaxed at `params.temperature`.
pub fn sample(probs: &mut [f32], params: &SamplingParams, rng: &mut Pcg32) -> Token {
    sample_scratch(probs, params, rng, &mut FilterScratch::default())
}

/// [`sample`] with caller-owned filter scratch (identical results) — the
/// per-token form for decode loops.
pub fn sample_scratch(
    probs: &mut [f32],
    params: &SamplingParams,
    rng: &mut Pcg32,
    scratch: &mut FilterScratch,
) -> Token {
    if params.temperature <= 1e-3 {
        return argmax(probs);
    }
    filter_top_kp_scratch(probs, params.top_k, params.top_p, scratch);
    sample_categorical(probs, rng)
}

/// Residual distribution `norm(max(p - q, 0))` used when a speculative
/// verifier rejects a proposal. Returns None if `p <= q` pointwise (then the
/// caller samples from `p` directly — happens only with degenerate floats).
pub fn residual(p: &[f32], q: &[f32]) -> Option<Vec<f32>> {
    debug_assert_eq!(p.len(), q.len());
    let mut r: Vec<f32> = p.iter().zip(q).map(|(&a, &b)| (a - b).max(0.0)).collect();
    let sum: f32 = r.iter().sum();
    if sum <= 0.0 {
        return None;
    }
    let inv = 1.0 / sum;
    for x in &mut r {
        *x *= inv;
    }
    Some(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categorical_respects_mass() {
        let mut rng = Pcg32::seeded(5);
        let probs = [0.0f32, 0.7, 0.3, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            counts[sample_categorical(&probs, &mut rng) as usize] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[3], 0);
        let f1 = counts[1] as f64 / 20_000.0;
        assert!((f1 - 0.7).abs() < 0.02, "{f1}");
    }

    #[test]
    fn argmax_ties_to_lowest() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }

    #[test]
    fn top_k_keeps_k() {
        let mut p = vec![0.1, 0.4, 0.3, 0.2];
        filter_top_kp(&mut p, 2, 1.0);
        assert_eq!(p.iter().filter(|&&x| x > 0.0).count(), 2);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[1] > p[2] && p[2] == 0.0 || p[2] > 0.0);
    }

    #[test]
    fn top_p_cuts_tail() {
        let mut p = vec![0.5, 0.3, 0.1, 0.1];
        filter_top_kp(&mut p, 0, 0.75);
        // 0.5 + 0.3 = 0.8 >= 0.75 -> keep two.
        assert_eq!(p.iter().filter(|&&x| x > 0.0).count(), 2);
    }

    #[test]
    fn residual_is_normalized() {
        let p = [0.5f32, 0.4, 0.1];
        let q = [0.6f32, 0.2, 0.2];
        let r = residual(&p, &q).unwrap();
        assert!((r.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert_eq!(r[0], 0.0);
        assert!(r[1] > 0.0 && r[2] == 0.0);
    }

    #[test]
    fn residual_none_when_equal() {
        let p = [0.5f32, 0.5];
        assert!(residual(&p, &p).is_none());
    }

    #[test]
    fn greedy_temperature_uses_argmax() {
        let mut rng = Pcg32::seeded(1);
        let params = SamplingParams { temperature: 0.0, ..Default::default() };
        let mut p = vec![0.2, 0.5, 0.3];
        assert_eq!(sample(&mut p, &params, &mut rng), 1);
    }
}
