//! Acceptance / latency statistics: streaming moments, histograms,
//! per-task aggregation. Feeds both the theory layer (L_i, sigma^2
//! estimates) and the benchmark tables.

/// Streaming mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n;
        self.mean += d * other.n as f64 / n;
        self.n += other.n;
    }
}

/// Fixed-bucket histogram for small non-negative integers (accept lengths).
#[derive(Debug, Clone)]
pub struct IntHistogram {
    buckets: Vec<u64>,
    overflow: u64,
}

impl IntHistogram {
    pub fn new(max: usize) -> Self {
        Self { buckets: vec![0; max + 1], overflow: 0 }
    }

    pub fn push(&mut self, v: usize) {
        match self.buckets.get_mut(v) {
            Some(b) => *b += 1,
            None => self.overflow += 1,
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.overflow
    }

    pub fn bucket(&self, v: usize) -> u64 {
        self.buckets.get(v).copied().unwrap_or(0)
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Normalized frequencies (including overflow in the divisor).
    pub fn pmf(&self) -> Vec<f64> {
        let n = self.count().max(1) as f64;
        self.buckets.iter().map(|&b| b as f64 / n).collect()
    }

    /// Render a terminal bar chart (used by the fig4 bench).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.buckets.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &b) in self.buckets.iter().enumerate() {
            let bar = "#".repeat((b as usize * width).div_ceil(max as usize).min(width));
            out.push_str(&format!("{i:>3} | {bar} {b}\n"));
        }
        if self.overflow > 0 {
            out.push_str(&format!(" >{} | {}\n", self.buckets.len() - 1, self.overflow));
        }
        out
    }
}

/// Aggregate over one (method, family, task) benchmark cell.
#[derive(Debug, Clone, Default)]
pub struct CellStats {
    pub accept: Welford,
    pub wall_s: f64,
    pub tokens: u64,
    pub target_forwards: u64,
}

impl CellStats {
    /// Paper's mean acceptance length μ (tokens per target forward).
    pub fn mu(&self) -> f64 {
        self.accept.mean()
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.wall_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64).sin() * 3.0).collect();
        let mut a = Welford::default();
        let mut b = Welford::default();
        let mut all = Welford::default();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn histogram_counts_and_overflow() {
        let mut h = IntHistogram::new(4);
        for v in [0, 1, 1, 4, 9] {
            h.push(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.bucket(1), 2);
        assert_eq!(h.pmf()[1], 0.4);
        assert!(h.ascii(20).contains('#'));
    }
}
