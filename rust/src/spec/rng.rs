//! Deterministic PRNG (PCG32) — the offline crate set has no `rand`.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014). Good statistical quality for sampling
//! and workload generation, fully reproducible across platforms.

#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (Lemire-style rejection, unbiased).
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Exponential with the given rate (for Poisson arrival processes).
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut rng = Pcg32::seeded(3);
        let mut buckets = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            buckets[(rng.next_f64() * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket {frac}");
        }
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Pcg32::seeded(9);
        for bound in [1u32, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.next_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }
}
