//! Verification rules: how a verifier model checks proposed tokens.
//!
//! The paper's three verification strategies (§2):
//!   * greedy matching      — deterministic, output equals the verifier's
//!                            greedy decode;
//!   * speculative sampling — Leviathan et al. 2023 rejection rule, exactly
//!                            preserves the verifier's distribution;
//!   * typical acceptance   — Medusa-style threshold, lossy but fast.
//!
//! Chained losslessness (used by `polybasic.rs`): if a token stream entering
//! stage `j` is distributed as `q` (the distribution of the stage below) and
//! stage `j` applies the speculative rule against its own `p`, the output
//! stream is distributed exactly as `p`.  Induction over stages gives
//! target-exact sampling for the whole polybasic chain.

use super::rng::Pcg32;
use super::sampler::{argmax, residual, sample_categorical};
use super::types::{Token, VerifyRule};

/// Outcome of verifying a single proposed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenVerdict {
    Accepted,
    /// Rejected; the verifier emits `replacement` in its place (sampled from
    /// the residual distribution under the speculative rule, or the argmax
    /// under greedy).
    Rejected { replacement: Token },
}

/// Verify one token `x` proposed from distribution `q` against the
/// verifier's distribution `p` (both normalized, same length).
pub fn verify_token(
    x: Token,
    p: &[f32],
    q: &[f32],
    rule: VerifyRule,
    rng: &mut Pcg32,
) -> TokenVerdict {
    debug_assert_eq!(p.len(), q.len());
    let xi = x as usize;
    match rule {
        VerifyRule::Greedy => {
            let best = argmax(p);
            if best == x {
                TokenVerdict::Accepted
            } else {
                TokenVerdict::Rejected { replacement: best }
            }
        }
        VerifyRule::Speculative => {
            let px = p.get(xi).copied().unwrap_or(0.0);
            let qx = q.get(xi).copied().unwrap_or(0.0).max(1e-20);
            let accept = px >= qx || rng.next_f32() < px / qx;
            if accept {
                TokenVerdict::Accepted
            } else {
                let replacement = match residual(p, q) {
                    Some(r) => sample_categorical(&r, rng),
                    None => sample_categorical(p, rng),
                };
                TokenVerdict::Rejected { replacement }
            }
        }
        VerifyRule::Typical { eps } => {
            let px = p.get(xi).copied().unwrap_or(0.0);
            let pmax = p.iter().copied().fold(0.0f32, f32::max);
            if px >= eps * pmax {
                TokenVerdict::Accepted
            } else {
                TokenVerdict::Rejected { replacement: sample_categorical(p, rng) }
            }
        }
    }
}

/// Result of verifying a block of proposed tokens in order.
#[derive(Debug, Clone)]
pub struct BlockVerdict {
    /// Number of proposals accepted (prefix length).
    pub accepted: usize,
    /// Replacement emitted at the first rejection, if any.
    pub replacement: Option<Token>,
}

/// Verify `tokens[i]` (proposed from `q_rows[i]`) against `p_rows[i]`
/// sequentially; stop at the first rejection. Rows may be owned
/// (`Vec<f32>`) or borrowed (`&[f32]`, e.g. straight out of a
/// [`crate::spec::types::ScoringSession`] cache) — no cloning required.
pub fn verify_block<P: AsRef<[f32]>, Q: AsRef<[f32]>>(
    tokens: &[Token],
    p_rows: &[P],
    q_rows: &[Q],
    rule: VerifyRule,
    rng: &mut Pcg32,
) -> BlockVerdict {
    assert_eq!(tokens.len(), p_rows.len());
    assert_eq!(tokens.len(), q_rows.len());
    for (i, &tok) in tokens.iter().enumerate() {
        match verify_token(tok, p_rows[i].as_ref(), q_rows[i].as_ref(), rule, rng) {
            TokenVerdict::Accepted => continue,
            TokenVerdict::Rejected { replacement } => {
                return BlockVerdict { accepted: i, replacement: Some(replacement) };
            }
        }
    }
    BlockVerdict { accepted: tokens.len(), replacement: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<f32> {
        vec![1.0 / n as f32; n]
    }

    #[test]
    fn greedy_accepts_argmax_only() {
        let mut rng = Pcg32::seeded(0);
        let p = vec![0.1, 0.6, 0.3];
        assert_eq!(
            verify_token(1, &p, &uniform(3), VerifyRule::Greedy, &mut rng),
            TokenVerdict::Accepted
        );
        assert_eq!(
            verify_token(0, &p, &uniform(3), VerifyRule::Greedy, &mut rng),
            TokenVerdict::Rejected { replacement: 1 }
        );
    }

    #[test]
    fn speculative_always_accepts_when_p_dominates() {
        let mut rng = Pcg32::seeded(0);
        let p = vec![0.9, 0.1];
        let q = vec![0.5, 0.5];
        for _ in 0..100 {
            assert_eq!(
                verify_token(0, &p, &q, VerifyRule::Speculative, &mut rng),
                TokenVerdict::Accepted
            );
        }
    }

    /// The fundamental losslessness property: accept-or-resample output is
    /// distributed exactly as p, for ANY proposal q. Chi-square-ish check.
    #[test]
    fn speculative_preserves_target_distribution() {
        let mut rng = Pcg32::seeded(42);
        let p = vec![0.5f32, 0.3, 0.15, 0.05];
        let q = vec![0.1f32, 0.2, 0.3, 0.4]; // very different proposal
        let n = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            let x = sample_categorical(&q, &mut rng);
            let out = match verify_token(x, &p, &q, VerifyRule::Speculative, &mut rng) {
                TokenVerdict::Accepted => x,
                TokenVerdict::Rejected { replacement } => replacement,
            };
            counts[out as usize] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - p[i] as f64).abs() < 0.01, "token {i}: {f} vs {}", p[i]);
        }
    }

    #[test]
    fn typical_thresholds() {
        let mut rng = Pcg32::seeded(0);
        let p = vec![0.05, 0.65, 0.3];
        // p[0]=0.05 < 0.5*0.65 -> rejected
        let v = verify_token(0, &p, &uniform(3), VerifyRule::Typical { eps: 0.5 }, &mut rng);
        assert!(matches!(v, TokenVerdict::Rejected { .. }));
        // p[2]=0.3 < 0.5*0.65=0.325 -> rejected; p[1] accepted
        let v = verify_token(1, &p, &uniform(3), VerifyRule::Typical { eps: 0.5 }, &mut rng);
        assert_eq!(v, TokenVerdict::Accepted);
    }

    #[test]
    fn block_stops_at_first_rejection() {
        let mut rng = Pcg32::seeded(0);
        let p = vec![vec![0.9f32, 0.1], vec![0.1, 0.9], vec![0.9, 0.1]];
        let q = vec![uniform(2), uniform(2), uniform(2)];
        // Greedy: token 0 matches argmax row0 (0), token 0 vs row1 argmax 1 -> reject
        let v = verify_block(&[0, 0, 0], &p, &q, VerifyRule::Greedy, &mut rng);
        assert_eq!(v.accepted, 1);
        assert_eq!(v.replacement, Some(1));
    }

    #[test]
    fn block_full_accept_has_no_replacement() {
        let mut rng = Pcg32::seeded(0);
        let p = vec![vec![0.9f32, 0.1]; 3];
        let q = vec![uniform(2); 3];
        let v = verify_block(&[0, 0, 0], &p, &q, VerifyRule::Greedy, &mut rng);
        assert_eq!(v.accepted, 3);
        assert!(v.replacement.is_none());
    }
}
