//! polyspec CLI — leader entrypoint for the polybasic serving stack.
//!
//!   polyspec generate --prompt "..." [--method poly|dual|vanilla]
//!   polyspec serve    [--rate R --requests N --workers W]
//!   polyspec plan     — theory-driven chain planning (Thm 3.2)
//!   polyspec validate — Lemma 3.1 predicted-vs-measured check
//!   polyspec info     — list artifact families/roles
//!
//! (Hand-rolled arg parsing: the offline crate set has no clap.)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use polyspec::coordinator::{Method, Server, ServerConfig};
use polyspec::runtime::{EngineHost, Manifest};
use polyspec::spec::theory::lemma31_time;
use polyspec::spec::types::{LanguageModel, SamplingParams, VerifyRule};
use polyspec::spec::{autoregressive, dualistic, polybasic, PolyConfig};
use polyspec::workload::tokenizer;

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(key.to_string(), val);
            }
            i += 1;
        }
        Self { flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_n<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "poly" | "polybasic" => Method::Polybasic { draft_k: 6, mu: 8 },
        "dual" | "dualistic" => Method::Dualistic { draft_k: 4 },
        "vanilla" | "ar" => Method::Autoregressive,
        other => bail!("unknown method {other:?} (poly|dual|vanilla)"),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let artifacts = args.get("artifacts", "artifacts");
    let family = args.get("family", "v7b");

    match cmd {
        "generate" => cmd_generate(&args, &artifacts, &family),
        "serve" => cmd_serve(&args, &artifacts, &family),
        "plan" => cmd_plan(&artifacts, &family),
        "validate" => cmd_validate(&args, &artifacts, &family),
        "info" => cmd_info(&artifacts),
        _ => {
            println!(
                "polyspec — polybasic speculative decoding (ICML 2025 reproduction)\n\n\
                 usage: polyspec <generate|serve|plan|validate|info> [--flags]\n\
                 common flags: --artifacts DIR --family v7b\n\
                 generate: --prompt TEXT --max-new N --method poly|dual|vanilla --temp T\n\
                 serve:    --rate R --requests N --workers W --method M\n\
                 validate: --tokens N"
            );
            Ok(())
        }
    }
}

fn cmd_generate(args: &Args, artifacts: &str, family: &str) -> Result<()> {
    let host = EngineHost::load(artifacts, family, &["target", "intermediate", "draft"])?;
    let chain = host.chain();
    let vocab = chain[0].vocab();
    let prompt_text = args.get("prompt", "Q: explain speculative decoding A:");
    let prompt = tokenizer::encode(&prompt_text, vocab);
    let max_new: usize = args.get_n("max-new", 48);
    let method = parse_method(&args.get("method", "poly"))?;
    let sampling = SamplingParams {
        temperature: args.get_n("temp", 0.8f32),
        seed: args.get_n("seed", 0u64),
        ..Default::default()
    };

    let out = match method {
        Method::Autoregressive => {
            autoregressive::generate(chain[0].as_ref(), &prompt, max_new, &sampling)?
        }
        Method::Dualistic { draft_k } => dualistic::generate(
            chain[0].as_ref(),
            chain.last().unwrap().as_ref(),
            &prompt,
            &dualistic::DualisticConfig {
                draft_k,
                rule: VerifyRule::Speculative,
                sampling,
                max_new,
            },
        )?,
        Method::Polybasic { draft_k, mu } => {
            let mut cfg = PolyConfig::for_chain(chain.len(), draft_k, mu, max_new);
            cfg.sampling = sampling;
            polybasic::generate(&chain, &prompt, &cfg)?
        }
    };
    println!("method={} family={family}", method.label());
    println!(
        "generated {} tokens in {:.1} ms ({:.1} tok/s), mu={:.2}, forwards={:?}",
        out.tokens.len(),
        out.wall.as_secs_f64() * 1e3,
        out.tokens.len() as f64 / out.wall.as_secs_f64(),
        out.mean_accept(),
        out.forward_passes
    );
    println!("text: {:?}", tokenizer::decode(&out.tokens));
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: &str, family: &str) -> Result<()> {
    let mut cfg = ServerConfig::new(artifacts, family);
    cfg.workers = args.get_n("workers", 1usize);
    let method = parse_method(&args.get("method", "poly"))?;
    let rate: f64 = args.get_n("rate", 2.0);
    let n: usize = args.get_n("requests", 24);
    let server = Server::start(cfg)?;
    println!("serving {n} requests at {rate}/s with {}", method.label());
    let arrivals: Vec<_> =
        polyspec::workload::ArrivalStream::new(rate, 256, 7).take(n).collect();
    let start = std::time::Instant::now();
    let mut rxs = Vec::new();
    for a in arrivals {
        if let Some(wait) = a.at.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        match server.submit(a.query.prompt, a.query.max_new, method, Some(a.query.task)) {
            Ok(rx) => rxs.push(rx),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let metrics = server.shutdown();
    println!("{}", metrics.snapshot());
    Ok(())
}

fn cmd_plan(artifacts: &str, family: &str) -> Result<()> {
    let roles = ["target", "intermediate", "decoy", "draft"];
    let host = EngineHost::load(artifacts, family, &roles)
        .or_else(|_| EngineHost::load(artifacts, family, &["target", "intermediate", "draft"]))?;
    let n = host.metas().len();
    let models: Vec<Arc<dyn LanguageModel>> =
        (0..n).map(|i| host.model(i) as Arc<dyn LanguageModel>).collect();
    let profiles: Vec<polyspec::spec::planner::ModelProfile> = (0..n)
        .map(|i| polyspec::spec::planner::ModelProfile {
            name: host.roles()[i].clone(),
            t_ms: host.measure_cost_ms(i, 100, 5).unwrap(),
        })
        .collect();
    for p in &profiles {
        println!("{:<13} T = {:.2} ms", p.name, p.t_ms);
    }
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|i| {
            polyspec::workload::tasks::make_query(
                polyspec::workload::TaskKind::MultiTurn,
                i,
                models[0].vocab(),
            )
            .prompt
        })
        .collect();
    let plan = polyspec::spec::planner::plan_chain(
        &models, &profiles, &prompts, 10, 40, SamplingParams::default(), 1.0,
    )?;
    println!("planned chain: {:?}", plan.names);
    Ok(())
}

fn cmd_validate(args: &Args, artifacts: &str, family: &str) -> Result<()> {
    // Lemma 3.1: compare the predicted total time against measurement.
    let host = EngineHost::load(artifacts, family, &["target", "intermediate", "draft"])?;
    let chain = host.chain();
    let t: Vec<f64> =
        (0..3).map(|i| host.measure_cost_ms(i, 100, 5).unwrap()).collect();
    let n_tokens: usize = args.get_n("tokens", 96);
    let prompt = tokenizer::encode("validate lemma 3.1 on this prompt", chain[0].vocab());

    let mut cfg = PolyConfig::for_chain(3, 6, 8, n_tokens.min(96));
    cfg.sampling.seed = 11;
    let out = polybasic::generate(&chain, &prompt, &cfg)?;

    // Measured acceptance lengths per verifier: L_1 from the target stage,
    // L_2 from the intermediate stage (tokens emitted per its forward).
    let n = out.tokens.len() as f64;
    let l1 = n / out.forward_passes[0] as f64;
    let l2 = n / out.forward_passes[1] as f64;
    let beta = out.forward_passes[2] as f64 / (n / l2);
    let predicted = lemma31_time(n, &[l1, l2], &t, beta);
    let measured = out.wall.as_secs_f64() * 1e3;
    println!("measured  T_i (ms): {t:?}");
    println!("measured  L_1 = {l1:.2}  L_2 = {l2:.2}  beta = {beta:.2}");
    println!("Lemma 3.1 predicted: {predicted:.1} ms");
    println!("measured wall:       {measured:.1} ms");
    let err = (predicted - measured).abs() / measured;
    println!("relative error:      {:.1}%  ({})", err * 100.0,
             if err < 0.25 { "OK — within coordination overhead" } else { "LARGE" });
    Ok(())
}

fn cmd_info(artifacts: &str) -> Result<()> {
    let manifest = Manifest::load(artifacts).context("loading manifest")?;
    for (fam, spec) in &manifest.families {
        println!("{fam}:");
        for (role, r) in &spec.roles {
            println!(
                "  {:<13} layers={:<2} d_model={:<4} vocab={:<4} seq={:<4} params={}",
                role, r.meta.n_layers, r.meta.d_model, r.meta.vocab, r.meta.seq_len,
                r.meta.param_count
            );
        }
    }
    Ok(())
}
