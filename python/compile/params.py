"""Parameter construction + chain-member derivation (truncate / quantize).

The chain members are *derived from the target's weights* so that their
output distributions are genuinely correlated with the target's — the
property that makes speculative acceptance lengths non-degenerate (see
DESIGN.md §3):

  * ``derive_draft``        — early-exit: first k blocks + shared final
                              norm/head (paper §3.4).
  * ``derive_intermediate`` — early-exit + group-wise int4 quantization of
                              every projection (paper's W4A16 M2).
  * ``init_params``         — fresh model (targets, and the Table-1 decoy).
"""

import jax
import jax.numpy as jnp

from .kernels.quant_matmul import quantize_weight


def init_params(cfg, dtype=jnp.float32):
    """Initialize a full model for ``cfg`` (deterministic in cfg.seed)."""
    key = jax.random.PRNGKey(cfg.seed)
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    k_emb, k_pos, key = jax.random.split(key, 3)
    params = {
        "tok_emb": 0.3 * jax.random.normal(k_emb, (v, d), dtype),
        "pos_emb": 0.08 * jax.random.normal(k_pos, (s, d), dtype),
        "lnf": _ln_params(d, dtype),
        "layers": [],
    }
    proj = 1.0 / (d ** 0.5)
    for _ in range(cfg.n_layers):
        ks = jax.random.split(key, 7)
        key = ks[0]
        layer = {
            "ln1": _ln_params(d, dtype),
            "wq": proj * jax.random.normal(ks[1], (d, d), dtype),
            "wk": proj * jax.random.normal(ks[2], (d, d), dtype),
            "wv": proj * jax.random.normal(ks[3], (d, d), dtype),
            "wo": proj * jax.random.normal(ks[4], (d, d), dtype),
            "ln2": _ln_params(d, dtype),
            "w1": (1.0 / (d ** 0.5)) * jax.random.normal(ks[5], (d, f), dtype),
            "w2": (1.0 / (f ** 0.5)) * jax.random.normal(ks[6], (f, d), dtype),
        }
        params["layers"].append(layer)
    return params


def _ln_params(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


QUANTIZABLE = ("wq", "wk", "wv", "wo", "w1", "w2")


def derive_draft(target_params, n_layers):
    """Early-exit draft: first ``n_layers`` blocks, shared embeddings/head."""
    assert n_layers <= len(target_params["layers"])
    return {
        "tok_emb": target_params["tok_emb"],
        "pos_emb": target_params["pos_emb"],
        "lnf": target_params["lnf"],
        "layers": list(target_params["layers"][:n_layers]),
    }


def derive_intermediate(target_params, n_layers, group):
    """Early-exit + int4 group-quantized projections (the paper's M2)."""
    p = derive_draft(target_params, n_layers)
    qlayers = []
    for layer in p["layers"]:
        ql = dict(layer)
        for name in QUANTIZABLE:
            q, s, g = quantize_weight(layer[name], group=group)
            ql[name] = {"q": q, "s": s, "group": g}
        qlayers.append(ql)
    return {**p, "layers": qlayers}


def build_role_params(family_cfg, role):
    """Materialize parameters for one chain member of a family."""
    spec = family_cfg.roles()[role]
    cfg = spec["cfg"]
    derive = spec["derive"]
    if derive in ("full", "independent"):
        return cfg, init_params(cfg)
    target = init_params(family_cfg.target)
    if derive == "truncate":
        return cfg, derive_draft(target, cfg.n_layers)
    if derive == "truncate_quantize":
        return cfg, derive_intermediate(target, cfg.n_layers, cfg.quant_group)
    raise ValueError(f"unknown derivation {derive!r}")


def quant_rel_error(w, group):
    """Relative Frobenius error of int4 round-trip (used by tests)."""
    from .kernels.ref import dequant_ref
    q, s, g = quantize_weight(w, group=group)
    wd = dequant_ref(q, s, group=g)
    return float(jnp.linalg.norm(wd - w) / (jnp.linalg.norm(w) + 1e-12))
