"""AOT exporter: lower every chain member to HLO *text* + a weights blob.

Interchange contract with the rust runtime (rust/src/runtime/):

  artifacts/
    manifest.json            — families -> roles -> {hlo, params_bin, args[],
                               batched?, incremental?}
    <family>/<role>.hlo.txt  — HLO text of  f(tokens [S] i32, *weights) ->
                               (logits [S, V] f32,)
    <family>/<role>.params.bin — weights, concatenated little-endian in the
                               exact order of the ``args`` list (f32 or int8)

With ``--batched N`` each role additionally exports:

  <role>.b{N}.hlo.txt         — legacy stacked entry
                                f(tokens [N, S], *w) -> (logits [N, S, V],):
                                a vmap over the full-prefix forward, still
                                O(prefix) per row.  The rust engine uses it
                                to serve *stateless* ``forward_batch`` as one
                                submission instead of a per-row loop.
  <role>.prefill.hlo.txt      — f(tokens [S], slot [] i32, k_pool, v_pool,
                                *w) -> (logits [S, V], k_pool', v_pool'):
                                full-context score that also writes the
                                sequence's K/V cache into pool slot ``slot``.
  <role>.decode.b{N}.hlo.txt  — f(suffixes [N, W], prefix_lens [N] i32,
                                k_pool, v_pool, *w) ->
                                (logits [N, W, V], k_pool', v_pool'):
                                one O(suffix) decode step over every pool
                                slot at once.

Pool tensors are ``[N, L, NB, BS, H, dh]`` f32 — the batch axis is the
*cache-page arena*, block-sized (BS = the coordinator's paged-KV block
size) so rust block tables map 1:1 onto pool pages.  The decode entry is
the device half of ``SessionAppendBatch``: the scheduler coalesces one
append per (chain member, tick) and the engine runs them as a single
submission whose per-tick cost is O(W · S), flat in prefix length — the
``T_i`` Lemma 3.1's cost model needs.  Manifest key ``incremental``:
``{prefill_hlo, decode_hlo, batch, window, cache{block_size, blocks,
n_layers, n_heads, d_head}, params_bin}``.

HLO **text**, not a serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).  Weights are *arguments*, not
embedded constants, so the rust side uploads them to device buffers once and
reuses them across every forward (``execute_b``); pool buffers likewise stay
device-resident, with each call's updated pools replacing the engine's
handles.

Python runs only here — `make artifacts` — and never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs
from .model import forward, forward_decode_pool, forward_prefill_pool
from .params import build_role_params

# Paged-KV block size; must match coordinator::paged (rust/src/coordinator).
BLOCK_SIZE = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_params(params):
    """Deterministic (name, leaf) list for the weights blob + manifest."""
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in leaves_with_path:
        name = "/".join(_path_key(k) for k in path)
        out.append((name, np.asarray(leaf)))
    return out


def _path_key(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


_DTYPES = {np.dtype(np.float32): "f32", np.dtype(np.int8): "s8",
           np.dtype(np.int32): "s32"}


def export_role(family_cfg, role, out_dir):
    """Lower one chain member; returns its manifest entry."""
    cfg, params = build_role_params(family_cfg, role)
    # Skip non-array leaves (the quant "group" ints ride in the manifest).
    named = [(n, a) for n, a in flatten_params(params)
             if isinstance(a, np.ndarray) and a.dtype != object and a.ndim > 0]
    # Quant group sizes are static python ints; strip them from the traced
    # pytree by rebuilding the param tree from the named leaves at call time.
    flat_leaves = [a for _, a in named]
    treedef_params = params

    def fn(tokens, *leaves):
        rebuilt = _rebuild(treedef_params, list(leaves))
        return (forward(rebuilt, tokens, cfg),)

    token_spec = jax.ShapeDtypeStruct((cfg.seq_len,), jnp.int32)
    leaf_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat_leaves]
    lowered = jax.jit(fn).lower(token_spec, *leaf_specs)
    hlo = to_hlo_text(lowered)

    fam_dir = os.path.join(out_dir, family_cfg.family)
    os.makedirs(fam_dir, exist_ok=True)
    hlo_rel = f"{family_cfg.family}/{role}.hlo.txt"
    bin_rel = f"{family_cfg.family}/{role}.params.bin"
    with open(os.path.join(out_dir, hlo_rel), "w") as f:
        f.write(hlo)

    args, offset = [], 0
    with open(os.path.join(out_dir, bin_rel), "wb") as f:
        for name, a in named:
            raw = np.ascontiguousarray(a).tobytes()
            args.append({
                "name": name,
                "dtype": _DTYPES[a.dtype],
                "shape": list(a.shape),
                "offset": offset,
                "nbytes": len(raw),
            })
            f.write(raw)
            offset += len(raw)

    flops = 2 * cfg.param_count() * cfg.seq_len
    return {
        "hlo": hlo_rel,
        "params_bin": bin_rel,
        "args": args,
        "config": {
            "name": cfg.name, "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
            "seq_len": cfg.seq_len, "seed": cfg.seed,
            "residual_gain": cfg.residual_gain,
        },
        "param_count": cfg.param_count(),
        "flops_per_forward": flops,
    }


def export_role_batched(family_cfg, role, out_dir, batch):
    """Batched entry point: lower f(tokens [B, S]) -> (logits [B, S, V],).

    This is the device-side half of the scheduler's cross-request batched
    verification (one ``SessionAppendBatch`` per chain member per tick).
    The rust engine currently serves batches by looping ``execute`` per
    prefix because the single-sequence HLO above has no batch dimension;
    this export produces the ``[B, S]`` module it would call instead.

    The lowering is a plain ``vmap`` over the full-prefix forward, so each
    batched call still recomputes every prefix from position 0 — this entry
    serves the *stateless* ``forward_batch`` path (sessions without cache
    slots).  Cached sessions go through the O(suffix) incremental pair from
    :func:`export_role_incremental` instead, where the batch dimension
    rides on cache pages rather than token prefixes.
    """
    cfg, params = build_role_params(family_cfg, role)
    named = [(n, a) for n, a in flatten_params(params)
             if isinstance(a, np.ndarray) and a.dtype != object and a.ndim > 0]
    flat_leaves = [a for _, a in named]
    treedef_params = params

    def fn(tokens, *leaves):
        rebuilt = _rebuild(treedef_params, list(leaves))
        # Weights are shared across the batch: vmap only the token axis.
        return (jax.vmap(lambda t: forward(rebuilt, t, cfg))(tokens),)

    token_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    leaf_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat_leaves]
    lowered = jax.jit(fn).lower(token_spec, *leaf_specs)

    fam_dir = os.path.join(out_dir, family_cfg.family)
    os.makedirs(fam_dir, exist_ok=True)
    hlo_rel = f"{family_cfg.family}/{role}.b{batch}.hlo.txt"
    with open(os.path.join(out_dir, hlo_rel), "w") as f:
        f.write(to_hlo_text(lowered))
    # Weights blob + args layout are identical to the unbatched export, so
    # the entry only references them; no second params.bin is written.
    return {"hlo": hlo_rel, "batch": batch,
            "params_bin": f"{family_cfg.family}/{role}.params.bin"}


def export_role_incremental(family_cfg, role, out_dir, batch, window):
    """KV-cached prefill / decode-step pair over a device cache pool.

    Lowers two executables against one shared pool layout
    ``[batch, L, S // BLOCK_SIZE, BLOCK_SIZE, H, dh]``:

      prefill:  f(tokens [S], slot [], k_pool, v_pool, *w)
                  -> (logits [S, V], k_pool', v_pool')
      decode:   f(suffixes [batch, window], prefix_lens [batch],
                  k_pool, v_pool, *w)
                  -> (logits [batch, window, V], k_pool', v_pool')

    The decode entry scores ``window`` suffix tokens per slot per call in
    O(window · S) — flat in prefix length; longer appends loop the window.
    Slots not participating in a call are fed dummy rows whose cache writes
    land past their ``prefix_len`` (the never-attended region), so idle
    slots survive every call unchanged.  Byte-identity with the full-prefix
    forward is pinned by python/tests/test_aot.py.
    """
    cfg, params = build_role_params(family_cfg, role)
    named = [(n, a) for n, a in flatten_params(params)
             if isinstance(a, np.ndarray) and a.dtype != object and a.ndim > 0]
    flat_leaves = [a for _, a in named]
    treedef_params = params
    leaf_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat_leaves]

    assert cfg.seq_len % BLOCK_SIZE == 0, (
        f"seq_len {cfg.seq_len} not a multiple of block size {BLOCK_SIZE}")
    blocks = cfg.seq_len // BLOCK_SIZE
    pool_shape = (batch, cfg.n_layers, blocks, BLOCK_SIZE,
                  cfg.n_heads, cfg.d_head)
    pool_spec = jax.ShapeDtypeStruct(pool_shape, jnp.float32)

    def prefill_fn(tokens, slot, k_pool, v_pool, *leaves):
        rebuilt = _rebuild(treedef_params, list(leaves))
        return forward_prefill_pool(rebuilt, tokens, slot, k_pool, v_pool, cfg)

    def decode_fn(suffixes, prefix_lens, k_pool, v_pool, *leaves):
        rebuilt = _rebuild(treedef_params, list(leaves))
        return forward_decode_pool(rebuilt, suffixes, prefix_lens,
                                   k_pool, v_pool, cfg)

    token_spec = jax.ShapeDtypeStruct((cfg.seq_len,), jnp.int32)
    slot_spec = jax.ShapeDtypeStruct((), jnp.int32)
    suffix_spec = jax.ShapeDtypeStruct((batch, window), jnp.int32)
    lens_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)

    prefill = jax.jit(prefill_fn).lower(
        token_spec, slot_spec, pool_spec, pool_spec, *leaf_specs)
    decode = jax.jit(decode_fn).lower(
        suffix_spec, lens_spec, pool_spec, pool_spec, *leaf_specs)

    fam_dir = os.path.join(out_dir, family_cfg.family)
    os.makedirs(fam_dir, exist_ok=True)
    prefill_rel = f"{family_cfg.family}/{role}.prefill.hlo.txt"
    decode_rel = f"{family_cfg.family}/{role}.decode.b{batch}.hlo.txt"
    with open(os.path.join(out_dir, prefill_rel), "w") as f:
        f.write(to_hlo_text(prefill))
    with open(os.path.join(out_dir, decode_rel), "w") as f:
        f.write(to_hlo_text(decode))

    return {
        "prefill_hlo": prefill_rel,
        "decode_hlo": decode_rel,
        "batch": batch,
        "window": window,
        "cache": {
            "block_size": BLOCK_SIZE, "blocks": blocks,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head,
        },
        # Same weights blob as the stateless entry; uploaded once.
        "params_bin": f"{family_cfg.family}/{role}.params.bin",
    }


def _rebuild(template, leaves):
    """Rebuild the params pytree from ``leaves`` in flatten order, keeping
    static entries (ints such as quant group sizes) from the template."""
    if isinstance(template, dict):
        # jax flattens dicts in sorted-key order; pops must match that order.
        return {k: _rebuild(template[k], leaves) for k in sorted(template)}
    if isinstance(template, list):
        return [_rebuild(t, leaves) for t in template]
    if isinstance(template, (int, float)) and not hasattr(template, "shape"):
        return template
    return leaves.pop(0)


def export_family(family, out_dir, roles=None, batched=0, window=BLOCK_SIZE):
    fam = configs.FAMILIES[family]
    entry = {"roles": {}}
    for role in (roles or fam.roles().keys()):
        print(f"[aot] lowering {family}/{role} ...", flush=True)
        entry["roles"][role] = export_role(fam, role, out_dir)
        if batched > 0:
            print(f"[aot] lowering {family}/{role} [B={batched}] ...", flush=True)
            entry["roles"][role]["batched"] = export_role_batched(
                fam, role, out_dir, batched)
            print(f"[aot] lowering {family}/{role} [prefill + decode "
                  f"B={batched} W={window}] ...", flush=True)
            entry["roles"][role]["incremental"] = export_role_incremental(
                fam, role, out_dir, batched, window)
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--families", default=",".join(configs.DEFAULT_SET),
                    help="comma list, or 'bench' / 'scale' / 'all'")
    ap.add_argument("--batched", type=int, default=0,
                    help="also export the batched triplet per role: legacy "
                         "[B, S] stacked entry + KV-cached prefill/decode "
                         "pair over a B-slot cache pool (0 = off)")
    ap.add_argument("--window", type=int, default=BLOCK_SIZE,
                    help="decode-step suffix window (tokens scored per slot "
                         "per decode call; longer appends loop the window)")
    args = ap.parse_args()

    sets = {"bench": configs.BENCH_SET, "scale": configs.SCALE_SET,
            "all": configs.ALL_SET}
    fams = sets.get(args.families, None) or args.families.split(",")

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "families": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    for fam in fams:
        manifest["families"][fam] = export_family(
            fam, out_dir, batched=args.batched, window=args.window)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {manifest_path} ({len(manifest['families'])} families)")


if __name__ == "__main__":
    main()
