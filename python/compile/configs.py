"""Model-family configurations for the polybasic speculative decoding stack.

The paper evaluates Vicuna-7B / LLaMA2-Chat-7B / LLaMA3-8B / Qwen2-7B (plus
13B/70B scaling tiers) on A800 GPUs.  We cannot host 7B-parameter models in
this environment, so each family is a *seeded synthetic* GPT config at laptop
scale (see DESIGN.md §3).  The quantities the paper's theory consumes — the
per-forward costs T_i and the pairwise acceptance lengths L_i — remain fully
real, measured quantities on these configs.

Chain derivation (per family):
  * target        — the full model (paper's M1).
  * intermediate  — the first ``intermediate_layers`` blocks with all
                    projection weights group-wise int4-quantized, run through
                    the Pallas dequant-matmul kernel (paper's M2, a W4A16
                    quantization of the target; layer truncation supplies the
                    real FLOP reduction that quantized CUDA kernels supply on
                    GPU).
  * draft         — a 1-block early-exit head (paper's M3; §3.4 of the paper
                    explicitly casts early-exit heads as polybasic drafters).
  * decoy         — an *uncorrelated* model (independent seed) used for the
                    Table-1 "non-compliant insertion" case.
"""

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of one transformer in a chain."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    seed: int
    # Residual-branch gain schedule: branch l is scaled by
    # ``residual_gain ** l`` (layer 0 gain 1.0).  Later blocks refine rather
    # than rewrite the stream — the property that makes early-exit drafting
    # (and hence layer-truncated chain members) work on real LLMs.
    residual_gain: float = 0.55
    # Group size for int4 weight quantization (only used by quantized roles).
    quant_group: int = 32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        return self.vocab * self.d_model + self.seq_len * self.d_model + self.n_layers * per_layer


@dataclass(frozen=True)
class FamilyConfig:
    """A model family = target config + how its chain members are derived."""

    family: str
    target: ModelConfig
    intermediate_layers: int
    draft_layers: int = 1
    # Decoy (non-compliant insertion experiment): an uncorrelated model.
    decoy_layers: Optional[int] = None
    decoy_seed: Optional[int] = None

    def roles(self) -> dict:
        """Role name -> (config, derivation) descriptors consumed by aot.py."""
        t = self.target
        out = {
            "target": {"cfg": t, "derive": "full"},
            "intermediate": {
                "cfg": replace(t, name=f"{t.name}-int", n_layers=self.intermediate_layers),
                "derive": "truncate_quantize",
            },
            "draft": {
                "cfg": replace(t, name=f"{t.name}-draft", n_layers=self.draft_layers),
                "derive": "truncate",
            },
        }
        if self.decoy_layers is not None:
            out["decoy"] = {
                "cfg": replace(
                    t,
                    name=f"{t.name}-decoy",
                    n_layers=self.decoy_layers,
                    seed=self.decoy_seed if self.decoy_seed is not None else t.seed + 9001,
                ),
                "derive": "independent",
            }
        return out


# ---------------------------------------------------------------------------
# The family zoo.  Sequence length / vocab are deliberately small so a full
# SpecBench sweep runs on CPU in minutes; relative T_i and all L_i are real.
# ---------------------------------------------------------------------------

S = 160  # max context (prompt + generation + pipeline headroom)
V = 256  # synthetic vocabulary


def _mk(name, n_layers, d_model, n_heads, d_ff, vocab, seq_len, seed, gain=0.55):
    return ModelConfig(
        name=name, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        d_ff=d_ff, vocab=vocab, seq_len=seq_len, seed=seed, residual_gain=gain,
    )


FAMILIES = {
    # 7B-class sims (Table 2 / Figures 2-3)
    "v7b": FamilyConfig(
        family="v7b",
        target=_mk("v7b", 10, 128, 4, 512, V, S, seed=17),
        intermediate_layers=3,
        decoy_layers=8,
    ),
    "l2-7b": FamilyConfig(
        family="l2-7b",
        target=_mk("l2-7b", 10, 128, 4, 512, V, S, seed=23, gain=0.53),
        intermediate_layers=3,
    ),
    "l3-8b": FamilyConfig(
        family="l3-8b",
        target=_mk("l3-8b", 11, 128, 4, 512, V, S, seed=31, gain=0.54),
        intermediate_layers=3,
    ),
    "q2-7b": FamilyConfig(
        family="q2-7b",
        target=_mk("q2-7b", 10, 96, 4, 384, V, S, seed=41, gain=0.54),
        intermediate_layers=3,
    ),
    # Scaling tier (Table 3)
    "v13b": FamilyConfig(
        family="v13b",
        target=_mk("v13b", 12, 144, 4, 576, V, S, seed=53, gain=0.58),
        intermediate_layers=4,
    ),
    "l2-70b": FamilyConfig(
        family="l2-70b",
        target=_mk("l2-70b", 16, 160, 4, 640, V, S, seed=61, gain=0.60),
        intermediate_layers=5,
    ),
}

# Families built by the default `make artifacts` (the rest via ARTIFACT_SET=full)
DEFAULT_SET = ["v7b"]
BENCH_SET = ["v7b", "l2-7b", "l3-8b", "q2-7b"]
SCALE_SET = ["v13b", "l2-70b"]
ALL_SET = BENCH_SET + SCALE_SET
