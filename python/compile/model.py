"""L2: GPT-style decoder-only transformer forward pass (JAX, functional).

One forward = a *full-context scorer*: ``tokens [S] int32 -> logits [S, V]
f32`` under causal masking.  Because attention is causal, ``logits[t]``
depends only on ``tokens[0..t]`` — the rust coordinator pads the suffix with
arbitrary ids and reads logits at whatever positions it needs (drafting reads
one row, verification reads a K-row window).  This keeps every AOT artifact a
single fixed-shape executable (see DESIGN.md §7 for the KV-cache discussion).

The hot spots route through the L1 Pallas kernels:
  * attention      -> kernels.attention.flash_attention
  * quantized GEMM -> kernels.quant_matmul.quant_matmul   (intermediate role)
Dense GEMMs stay as jnp.dot (XLA fuses them fine on every backend).
"""

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.quant_matmul import quant_matmul
from .kernels import ref as kref


def layer_norm(x, p, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def matmul(x, w, *, use_pallas=True):
    """Dense or quantized projection, dispatching on the weight's type."""
    if isinstance(w, dict):  # int4 group-quantized: {"q", "s", "group"}
        if use_pallas:
            return quant_matmul(x, w["q"], w["s"], group=w["group"])
        return kref.quant_matmul_ref(x, w["q"], w["s"], group=w["group"])
    return jnp.dot(x, w)


def attention_block(x, layer, cfg, *, use_pallas=True):
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = matmul(x, layer["wq"], use_pallas=use_pallas)
    k = matmul(x, layer["wk"], use_pallas=use_pallas)
    v = matmul(x, layer["wv"], use_pallas=use_pallas)
    # [S, D] -> [H, S, dh]
    q = q.reshape(s, h, dh).transpose(1, 0, 2)
    k = k.reshape(s, h, dh).transpose(1, 0, 2)
    v = v.reshape(s, h, dh).transpose(1, 0, 2)
    if use_pallas:
        o = flash_attention(q, k, v)
    else:
        o = kref.attention_ref(q, k, v)
    o = o.transpose(1, 0, 2).reshape(s, d)
    return matmul(o, layer["wo"], use_pallas=use_pallas)


def mlp_block(x, layer, *, use_pallas=True):
    h = matmul(x, layer["w1"], use_pallas=use_pallas)
    h = jax.nn.gelu(h)
    return matmul(h, layer["w2"], use_pallas=use_pallas)


def forward(params, tokens, cfg, *, use_pallas=True):
    """``tokens [S] int32 -> logits [S, V] f32`` (causal)."""
    s = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    # Residual-gain schedule: block l contributes gain**l — later blocks
    # refine rather than rewrite the stream, which is what makes early-exit
    # chain members (draft/intermediate) track the target (DESIGN.md §3).
    gain = 1.0
    for layer in params["layers"]:
        x = x + gain * attention_block(layer_norm(x, layer["ln1"]), layer, cfg,
                                       use_pallas=use_pallas)
        x = x + gain * mlp_block(layer_norm(x, layer["ln2"]), layer,
                                 use_pallas=use_pallas)
        gain *= cfg.residual_gain
    x = layer_norm(x, params["lnf"])
    return jnp.dot(x, params["tok_emb"].T)  # tied head: [S, V]


def forward_prob(params, tokens, cfg, *, temperature=1.0, use_pallas=True):
    """Softmax distribution per position (used by python-side diagnostics)."""
    logits = forward(params, tokens, cfg, use_pallas=use_pallas)
    return jax.nn.softmax(logits / temperature, axis=-1)


# ---------------------------------------------------------------------------
# KV-cached incremental execution (prefill / decode-step split)
#
# The serving runtime scores a session's *suffix* per append; a stateless
# full-context forward makes that O(prefix) per call, which breaks the
# per-token cost model T_i the paper's Lemma 3.1 prices chains by. The two
# entry points below split one role into:
#
#   forward_prefill : tokens [S] -> (logits [S, V], K/V cache)
#   forward_decode  : suffix [D] + prefix_len + cache -> (logits [D, V],
#                     updated cache)
#
# Cache layout is [L, NB, BS, H, dh] — per-layer K/V chunked into NB blocks
# of BS tokens, matching the coordinator's paged-KV block size, so a batch
# dimension stacked in front of it batches over *cache pages*, not token
# prefixes. Cache-validity contract (what makes rollback O(1)): rows
# < prefix_len are authoritative; rows >= prefix_len are garbage-but-finite
# (prefill computes them from padding, rollback simply lowers prefix_len).
# Garbage rows are never attended — decode masks position j for suffix row
# d unless j <= prefix_len + d — and every decode overwrites its window
# starting exactly at prefix_len, so staleness never escapes.
# ---------------------------------------------------------------------------


def _qkv(xn, layer, cfg, *, use_pallas=True):
    """Project one normed activation block to per-head q/k/v `[T, H, dh]`."""
    t = xn.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    q = matmul(xn, layer["wq"], use_pallas=use_pallas).reshape(t, h, dh)
    k = matmul(xn, layer["wk"], use_pallas=use_pallas).reshape(t, h, dh)
    v = matmul(xn, layer["wv"], use_pallas=use_pallas).reshape(t, h, dh)
    return q, k, v


def forward_prefill(params, tokens, cfg, *, use_pallas=True, block=16):
    """Full-context scorer that also materialises the per-layer K/V cache.

    ``tokens [S] int32 -> (logits [S, V], k_cache, v_cache)`` where each
    cache is ``[L, S // block, block, H, dh]`` f32. The logits computation
    is op-for-op the same as :func:`forward` (the caches are saved
    intermediates, not a different attention), so prefill logits match the
    stateless forward.
    """
    s = tokens.shape[0]
    assert s % block == 0, f"seq_len {s} not a multiple of block {block}"
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    gain = 1.0
    ks, vs = [], []
    for layer in params["layers"]:
        xn = layer_norm(x, layer["ln1"])
        q, k, v = _qkv(xn, layer, cfg, use_pallas=use_pallas)
        ks.append(k)
        vs.append(v)
        qh, kh, vh = (a.transpose(1, 0, 2) for a in (q, k, v))  # [H, S, dh]
        o = flash_attention(qh, kh, vh) if use_pallas else kref.attention_ref(qh, kh, vh)
        o = o.transpose(1, 0, 2).reshape(s, cfg.d_model)
        x = x + gain * matmul(o, layer["wo"], use_pallas=use_pallas)
        x = x + gain * mlp_block(layer_norm(x, layer["ln2"]), layer,
                                 use_pallas=use_pallas)
        gain *= cfg.residual_gain
    x = layer_norm(x, params["lnf"])
    logits = jnp.dot(x, params["tok_emb"].T)
    shape = (len(ks), s // block, block, cfg.n_heads, cfg.d_head)
    return logits, jnp.stack(ks).reshape(shape), jnp.stack(vs).reshape(shape)


def forward_decode(params, suffix, prefix_len, k_cache, v_cache, cfg, *,
                   use_pallas=True):
    """One decode step: score a fixed-width suffix window against the cache.

    ``suffix [D] int32`` are the tokens at positions ``prefix_len ..
    prefix_len + D``; their K/V rows are written into the cache at those
    positions (``dynamic_update_slice`` over the flattened block axis — the
    caller must keep ``prefix_len + D <= S``, XLA would clamp otherwise)
    and each suffix row ``d`` attends cache positions ``j <= prefix_len +
    d``. Returns ``(logits [D, V], k_cache', v_cache')``. Cost is
    O(D · S) attention instead of O(S²) — flat in prefix length.

    Attention here is plain jnp (the ref.py oracle idiom) rather than the
    Pallas flash kernel: the shape is a thin D×S rectangle with a
    dynamic diagonal offset, which the fixed-grid kernel does not serve.
    """
    d = suffix.shape[0]
    n_layers, nb, bs, h, dh = k_cache.shape
    s = nb * bs
    dm = cfg.d_model
    scale = 1.0 / (dh ** 0.5)
    prefix_len = jnp.asarray(prefix_len, jnp.int32)
    pos_emb = jax.lax.dynamic_slice(params["pos_emb"], (prefix_len, 0), (d, dm))
    x = params["tok_emb"][suffix] + pos_emb
    # Row d may attend cache position j iff j <= prefix_len + d (self and
    # earlier; rows beyond that are garbage or the causal future).
    mask = jnp.arange(s)[None, :] <= prefix_len + jnp.arange(d)[:, None]
    gain = 1.0
    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        xn = layer_norm(x, layer["ln1"])
        q, k, v = _qkv(xn, layer, cfg, use_pallas=use_pallas)
        kc = jax.lax.dynamic_update_slice(
            k_cache[li].reshape(s, h, dh), k, (prefix_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            v_cache[li].reshape(s, h, dh), v, (prefix_len, 0, 0))
        new_k.append(kc.reshape(nb, bs, h, dh))
        new_v.append(vc.reshape(nb, bs, h, dh))
        scores = jnp.einsum("dhe,she->hds", q, kc) * scale
        scores = jnp.where(mask[None], scores, -1e30)
        p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
        p = p / p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("hds,she->dhe", p, vc).reshape(d, dm)
        x = x + gain * matmul(o, layer["wo"], use_pallas=use_pallas)
        x = x + gain * mlp_block(layer_norm(x, layer["ln2"]), layer,
                                 use_pallas=use_pallas)
        gain *= cfg.residual_gain
    x = layer_norm(x, params["lnf"])
    logits = jnp.dot(x, params["tok_emb"].T)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def forward_prefill_pool(params, tokens, slot, k_pool, v_pool, cfg, *,
                         use_pallas=True):
    """Prefill one sequence and write its cache into pool slot ``slot``.

    Pools are ``[B, L, NB, BS, H, dh]`` — the device-resident cache arena
    the rust engine batches decode steps over. Returns ``(logits [S, V],
    k_pool', v_pool')``.
    """
    block = k_pool.shape[3]
    logits, kc, vc = forward_prefill(params, tokens, cfg,
                                     use_pallas=use_pallas, block=block)
    at = (jnp.asarray(slot, jnp.int32), 0, 0, 0, 0, 0)
    k_pool = jax.lax.dynamic_update_slice(k_pool, kc[None], at)
    v_pool = jax.lax.dynamic_update_slice(v_pool, vc[None], at)
    return logits, k_pool, v_pool


def forward_decode_pool(params, suffixes, prefix_lens, k_pool, v_pool, cfg, *,
                        use_pallas=True):
    """Batched decode step over every pool slot at once.

    ``suffixes [B, D]`` + ``prefix_lens [B]`` + pools ``[B, L, NB, BS, H,
    dh]`` -> ``(logits [B, D, V], k_pool', v_pool')``. vmap over the slot
    axis with shared weights: the batch dimension rides on cache pages.
    Slots with nothing to decode are fed dummy rows (zero tokens at their
    own ``prefix_len``); their writes land in the never-attended garbage
    region, so live-but-idle slots are unharmed.
    """
    f = lambda t, p, kc, vc: forward_decode(  # noqa: E731
        params, t, p, kc, vc, cfg, use_pallas=use_pallas)
    return jax.vmap(f)(suffixes, prefix_lens, k_pool, v_pool)
