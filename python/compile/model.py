"""L2: GPT-style decoder-only transformer forward pass (JAX, functional).

One forward = a *full-context scorer*: ``tokens [S] int32 -> logits [S, V]
f32`` under causal masking.  Because attention is causal, ``logits[t]``
depends only on ``tokens[0..t]`` — the rust coordinator pads the suffix with
arbitrary ids and reads logits at whatever positions it needs (drafting reads
one row, verification reads a K-row window).  This keeps every AOT artifact a
single fixed-shape executable (see DESIGN.md §7 for the KV-cache discussion).

The hot spots route through the L1 Pallas kernels:
  * attention      -> kernels.attention.flash_attention
  * quantized GEMM -> kernels.quant_matmul.quant_matmul   (intermediate role)
Dense GEMMs stay as jnp.dot (XLA fuses them fine on every backend).
"""

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.quant_matmul import quant_matmul
from .kernels import ref as kref


def layer_norm(x, p, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def matmul(x, w, *, use_pallas=True):
    """Dense or quantized projection, dispatching on the weight's type."""
    if isinstance(w, dict):  # int4 group-quantized: {"q", "s", "group"}
        if use_pallas:
            return quant_matmul(x, w["q"], w["s"], group=w["group"])
        return kref.quant_matmul_ref(x, w["q"], w["s"], group=w["group"])
    return jnp.dot(x, w)


def attention_block(x, layer, cfg, *, use_pallas=True):
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = matmul(x, layer["wq"], use_pallas=use_pallas)
    k = matmul(x, layer["wk"], use_pallas=use_pallas)
    v = matmul(x, layer["wv"], use_pallas=use_pallas)
    # [S, D] -> [H, S, dh]
    q = q.reshape(s, h, dh).transpose(1, 0, 2)
    k = k.reshape(s, h, dh).transpose(1, 0, 2)
    v = v.reshape(s, h, dh).transpose(1, 0, 2)
    if use_pallas:
        o = flash_attention(q, k, v)
    else:
        o = kref.attention_ref(q, k, v)
    o = o.transpose(1, 0, 2).reshape(s, d)
    return matmul(o, layer["wo"], use_pallas=use_pallas)


def mlp_block(x, layer, *, use_pallas=True):
    h = matmul(x, layer["w1"], use_pallas=use_pallas)
    h = jax.nn.gelu(h)
    return matmul(h, layer["w2"], use_pallas=use_pallas)


def forward(params, tokens, cfg, *, use_pallas=True):
    """``tokens [S] int32 -> logits [S, V] f32`` (causal)."""
    s = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    # Residual-gain schedule: block l contributes gain**l — later blocks
    # refine rather than rewrite the stream, which is what makes early-exit
    # chain members (draft/intermediate) track the target (DESIGN.md §3).
    gain = 1.0
    for layer in params["layers"]:
        x = x + gain * attention_block(layer_norm(x, layer["ln1"]), layer, cfg,
                                       use_pallas=use_pallas)
        x = x + gain * mlp_block(layer_norm(x, layer["ln2"]), layer,
                                 use_pallas=use_pallas)
        gain *= cfg.residual_gain
    x = layer_norm(x, params["lnf"])
    return jnp.dot(x, params["tok_emb"].T)  # tied head: [S, V]


def forward_prob(params, tokens, cfg, *, temperature=1.0, use_pallas=True):
    """Softmax distribution per position (used by python-side diagnostics)."""
    logits = forward(params, tokens, cfg, use_pallas=use_pallas)
    return jax.nn.softmax(logits / temperature, axis=-1)
