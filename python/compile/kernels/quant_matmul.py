"""L1 Pallas kernel: group-wise int4 dequant-matmul (W4A16-style).

The paper's intermediate model M2 is a 4-bit (group-size 128, AffineQuant)
quantization of the target; its GPU implementation fuses dequantization into
the GEMM.  TPU-shaped version (DESIGN.md §6): tile the output columns with
``BlockSpec`` so each program holds one ``[K, block_n]`` int4 (stored int8)
weight panel plus its per-group scale vector in VMEM, dequantize group-by-
group, and feed the MXU with ``[M, G] x [G, block_n]`` contractions — the
quant-group axis doubles as the K-tiling axis so exactly one scale row is
live per step.

Weights are *symmetric* 4-bit: values in [-8, 7] stored as int8, one f32
scale per (group, column).  ``interpret=True`` as for all kernels here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 64


def _qmm_kernel(x_ref, q_ref, s_ref, o_ref, *, group, n_groups):
    """One output-column panel: out[:, nb] = x @ dequant(q[:, nb])."""
    x = x_ref[...]  # [M, K]
    m = x.shape[0]
    bn = q_ref.shape[1]

    def body(g, acc):
        xg = pl.load(x_ref, (slice(None), pl.ds(g * group, group)))       # [M, G]
        qg = pl.load(q_ref, (pl.ds(g * group, group), slice(None)))       # [G, bn]
        sg = pl.load(s_ref, (pl.ds(g, 1), slice(None)))                   # [1, bn]
        w = qg.astype(jnp.float32) * sg                                   # dequant
        return acc + jnp.dot(xg, w, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, n_groups,
                            body, jnp.zeros((m, bn), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def quant_matmul(x, q, scales, *, group, block_n=DEFAULT_BLOCK_N, interpret=True):
    """``x [M,K] @ dequant(q [K,N] int8, scales [K//group, N]) -> [M,N]``."""
    m, k = x.shape
    kq, n = q.shape
    assert kq == k, f"inner dims {k} vs {kq}"
    assert k % group == 0, f"K={k} not a multiple of group={group}"
    n_groups = k // group
    assert scales.shape == (n_groups, n), scales.shape
    # Largest divisor of N that fits the requested panel width, so arbitrary
    # head/FFN widths tile cleanly.
    bn = next(b for b in range(min(block_n, n), 0, -1) if n % b == 0)

    kernel = functools.partial(_qmm_kernel, group=group, n_groups=n_groups)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bn), lambda j: (0, j)),
            pl.BlockSpec((n_groups, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, q, scales)


def quantize_weight(w, *, group):
    """Symmetric group-wise int4 quantization of ``w [K, N]``.

    Returns ``(q int8 in [-8,7], scales f32 [K//group, N])`` such that
    ``dequant = q * scales[group_of_row]`` approximates ``w``.
    """
    k, n = w.shape
    if k % group != 0:
        # Adapt to the largest divisor of K <= the requested group so any
        # projection width quantizes cleanly.
        group = next(g for g in range(min(group, k), 0, -1) if k % g == 0)
    wg = w.reshape(k // group, group, n)
    absmax = jnp.max(jnp.abs(wg), axis=1, keepdims=True)  # [K/G, 1, N]
    scales = (absmax / 7.0 + 1e-12)[:, 0, :]              # [K/G, N]
    q = jnp.clip(jnp.round(wg / scales[:, None, :]), -8, 7).astype(jnp.int8)
    return q.reshape(k, n), scales.astype(jnp.float32), group


def vmem_bytes(m, k, n_groups, group, block_n=DEFAULT_BLOCK_N):
    """Analytic VMEM per program for §Perf: x panel + weight panel + scales."""
    return (4 * m * k                 # x (f32)
            + 1 * k * block_n         # q panel (int8)
            + 4 * n_groups * block_n  # scales
            + 4 * m * block_n)        # acc
