"""L1 Pallas kernel: tiled causal flash-attention.

Hardware adaptation (DESIGN.md §6): the paper's serving stack spends its GPU
time in fused attention + GEMM CUDA kernels.  The TPU-shaped analogue tiles
the (q, k) iteration space for VMEM with ``BlockSpec`` and keeps the running
max / normalizer in registers/VMEM scratch, feeding the MXU with one
``[block_q, d_head] x [d_head, block_k]`` contraction per step — the flash
pattern expressed as an HBM->VMEM schedule instead of a threadblock schedule.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers the kernel to plain HLO so the same
artifact executes under the rust runtime (see /opt/xla-example/README.md).

Layout: inputs are ``[BH, S, dh]`` (batch*heads flattened into the leading
grid axis).  Grid is ``(BH, S // block_q)``; each program owns one q-block
and loops over its causal prefix of k-blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Set considerably below S so the kernel is genuinely multi-block at our
# sequence lengths; 32x32 f32 tiles also divide the 128x128 MXU cleanly when
# re-targeted to real TPU (4 tiles / MXU pass).
DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, scale):
    """One (bh, q-block) program of causal flash attention."""
    qi = pl.program_id(1)
    q = q_ref[0]  # [block_q, dh]

    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m0 = jnp.full((block_q, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)

    row_ids = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(kb, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (0, pl.ds(kb * block_k, block_k), slice(None)))  # [bk, dh]
        v = pl.load(v_ref, (0, pl.ds(kb * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]
        col_ids = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(row_ids >= col_ids, s, _NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    # Causal: q-block qi only attends to k-blocks 0..qi (block_q == block_k).
    acc, _, l = jax.lax.fori_loop(0, qi + 1, body, (acc0, m0, l0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                    interpret=True):
    """Causal multi-head attention over ``[BH, S, dh]`` tensors.

    Returns ``softmax(q k^T / sqrt(dh), causal) v`` with the same shape/dtype
    as ``q``.  ``block_q`` must equal ``block_k`` (causal block alignment) and
    divide S.
    """
    bh, s, dh = q.shape
    assert k.shape == (bh, s, dh) and v.shape == (bh, s, dh)
    assert block_q == block_k, "causal masking assumes aligned q/k blocks"
    if s % block_q != 0:
        # Fall back to the largest divisor of S <= the requested block, so
        # arbitrary context lengths tile cleanly.
        block_q = block_k = next(b for b in range(min(block_q, s), 0, -1) if s % b == 0)
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_attn_kernel, block_q=block_q, block_k=block_k,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(s, dh, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Analytic VMEM footprint of one program (for DESIGN/EXPERIMENTS §Perf).

    q-block + full k/v rows (this kernel streams k/v from the row block) +
    accumulators; f32 everywhere.
    """
    f = 4
    return f * (block_q * dh          # q block
                + 2 * s * dh          # k, v rows resident for the program
                + block_q * dh        # acc
                + 2 * block_q         # m, l
                + block_q * block_k)  # score tile
