"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its oracle to float tolerance under
pytest/hypothesis sweeps (python/tests/test_kernels_*.py).
"""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Causal softmax attention over ``[BH, S, dh]`` (numerically naive)."""
    bh, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v).astype(q.dtype)


def dequant_ref(q, scales, *, group):
    """Expand group-wise int4 weights back to f32: ``[K,N]``."""
    k, n = q.shape
    s_full = jnp.repeat(scales, group, axis=0)  # [K, N]
    return q.astype(jnp.float32) * s_full


def quant_matmul_ref(x, q, scales, *, group):
    """Oracle for quant_matmul: dense matmul against dequantized weights."""
    return (x @ dequant_ref(q, scales, group=group)).astype(x.dtype)
