"""AOT interchange tests: HLO text + weights blob + manifest round-trip.

Exports a deliberately tiny family to a temp dir, then re-executes the HLO
through jax's own XLA client and checks it reproduces the python forward —
the same contract the rust runtime consumes.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs
from compile.model import forward
from compile.params import build_role_params


@pytest.fixture(scope="module")
def tiny_family():
    target = dataclasses.replace(
        configs.FAMILIES["v7b"].target, n_layers=2, d_model=32, n_heads=2,
        d_ff=64, vocab=32, seq_len=32, name="tinyfam",
    )
    return configs.FamilyConfig(
        family="tinyfam", target=target, intermediate_layers=1, draft_layers=1,
    )


@pytest.fixture(scope="module")
def exported(tiny_family, tmp_path_factory, monkeypatch_module=None):
    out = tmp_path_factory.mktemp("artifacts")
    configs.FAMILIES["tinyfam"] = tiny_family
    try:
        entry = aot.export_family("tinyfam", str(out))
    finally:
        del configs.FAMILIES["tinyfam"]
    manifest = {"version": 1, "families": {"tinyfam": entry}}
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return out, entry


def test_manifest_structure(exported):
    out, entry = exported
    assert set(entry["roles"]) == {"target", "intermediate", "draft"}
    role = entry["roles"]["target"]
    assert os.path.exists(out / role["hlo"])
    assert os.path.exists(out / role["params_bin"])
    # Offsets are contiguous and cover the blob exactly.
    args = role["args"]
    expected = 0
    for a in args:
        assert a["offset"] == expected
        expected += a["nbytes"]
    assert os.path.getsize(out / role["params_bin"]) == expected


def test_intermediate_has_int8_args(exported):
    _, entry = exported
    dtypes = {a["dtype"] for a in entry["roles"]["intermediate"]["args"]}
    assert "s8" in dtypes, "quantized weights must export as int8"
    assert "f32" in dtypes


def test_hlo_text_parses_and_mentions_entry(exported):
    out, entry = exported
    text = open(out / entry["roles"]["target"]["hlo"]).read()
    assert "ENTRY" in text and "parameter(0)" in text
    assert "s32[32]" in text  # tokens arg


def test_hlo_reexecution_matches_python(exported, tiny_family):
    """Round-trip: run the exported HLO via jax's XLA client with weights
    read back from the blob; must equal the python forward bit-for-bit-ish."""
    out, entry = exported
    role = entry["roles"]["target"]
    from jax._src.lib import xla_client as xc

    cfg, params = build_role_params(tiny_family, "target")
    toks = (jnp.arange(cfg.seq_len, dtype=jnp.int32) * 5) % cfg.vocab
    want = forward(params, toks, cfg)

    blob = open(out / role["params_bin"], "rb").read()
    np_dtypes = {"f32": np.float32, "s8": np.int8, "s32": np.int32}
    arrays = [np.asarray(toks)]
    for a in role["args"]:
        raw = blob[a["offset"]:a["offset"] + a["nbytes"]]
        arrays.append(np.frombuffer(raw, dtype=np_dtypes[a["dtype"]]).reshape(a["shape"]))

    # Compile the HLO text through the same machinery the rust loader uses
    # (text -> HloModule -> PJRT compile).
    device = jax.devices("cpu")[0]
    backend = device.client
    hlo_text = open(out / role["hlo"]).read()
    proto = xc._xla.hlo_module_from_text(hlo_text).as_serialized_hlo_module_proto()
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(xc.XlaComputation(proto))
    exe = backend.compile_and_load(mlir, [device])
    bufs = [backend.buffer_from_pyval(a) for a in arrays]
    (result,) = exe.execute(bufs)
    got = np.asarray(result[0] if isinstance(result, (list, tuple)) else result)
    np.testing.assert_allclose(got, np.asarray(want), atol=1e-4, rtol=1e-4)


def test_repeat_export_is_stable(exported, tiny_family, tmp_path):
    """Re-exporting produces identical weights (determinism contract)."""
    configs.FAMILIES["tinyfam"] = tiny_family
    try:
        entry2 = aot.export_family("tinyfam", str(tmp_path), roles=["target"])
    finally:
        del configs.FAMILIES["tinyfam"]
    out, entry = exported
    a = open(out / entry["roles"]["target"]["params_bin"], "rb").read()
    b = open(tmp_path / entry2["roles"]["target"]["params_bin"], "rb").read()
    assert a == b
