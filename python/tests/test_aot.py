"""AOT interchange tests: HLO text + weights blob + manifest round-trip.

Exports a deliberately tiny family to a temp dir, then re-executes the HLO
through jax's own XLA client and checks it reproduces the python forward —
the same contract the rust runtime consumes.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs
from compile.model import (forward, forward_decode, forward_decode_pool,
                           forward_prefill, forward_prefill_pool)
from compile.params import build_role_params


@pytest.fixture(scope="module")
def tiny_family():
    target = dataclasses.replace(
        configs.FAMILIES["v7b"].target, n_layers=2, d_model=32, n_heads=2,
        d_ff=64, vocab=32, seq_len=32, name="tinyfam",
    )
    return configs.FamilyConfig(
        family="tinyfam", target=target, intermediate_layers=1, draft_layers=1,
    )


@pytest.fixture(scope="module")
def exported(tiny_family, tmp_path_factory, monkeypatch_module=None):
    out = tmp_path_factory.mktemp("artifacts")
    configs.FAMILIES["tinyfam"] = tiny_family
    try:
        entry = aot.export_family("tinyfam", str(out))
    finally:
        del configs.FAMILIES["tinyfam"]
    manifest = {"version": 1, "families": {"tinyfam": entry}}
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return out, entry


def test_manifest_structure(exported):
    out, entry = exported
    assert set(entry["roles"]) == {"target", "intermediate", "draft"}
    role = entry["roles"]["target"]
    assert os.path.exists(out / role["hlo"])
    assert os.path.exists(out / role["params_bin"])
    # Offsets are contiguous and cover the blob exactly.
    args = role["args"]
    expected = 0
    for a in args:
        assert a["offset"] == expected
        expected += a["nbytes"]
    assert os.path.getsize(out / role["params_bin"]) == expected


def test_intermediate_has_int8_args(exported):
    _, entry = exported
    dtypes = {a["dtype"] for a in entry["roles"]["intermediate"]["args"]}
    assert "s8" in dtypes, "quantized weights must export as int8"
    assert "f32" in dtypes


def test_hlo_text_parses_and_mentions_entry(exported):
    out, entry = exported
    text = open(out / entry["roles"]["target"]["hlo"]).read()
    assert "ENTRY" in text and "parameter(0)" in text
    assert "s32[32]" in text  # tokens arg


def test_hlo_reexecution_matches_python(exported, tiny_family):
    """Round-trip: run the exported HLO via jax's XLA client with weights
    read back from the blob; must equal the python forward bit-for-bit-ish."""
    out, entry = exported
    role = entry["roles"]["target"]
    from jax._src.lib import xla_client as xc

    cfg, params = build_role_params(tiny_family, "target")
    toks = (jnp.arange(cfg.seq_len, dtype=jnp.int32) * 5) % cfg.vocab
    want = forward(params, toks, cfg)

    blob = open(out / role["params_bin"], "rb").read()
    np_dtypes = {"f32": np.float32, "s8": np.int8, "s32": np.int32}
    arrays = [np.asarray(toks)]
    for a in role["args"]:
        raw = blob[a["offset"]:a["offset"] + a["nbytes"]]
        arrays.append(np.frombuffer(raw, dtype=np_dtypes[a["dtype"]]).reshape(a["shape"]))

    # Compile the HLO text through the same machinery the rust loader uses
    # (text -> HloModule -> PJRT compile).
    device = jax.devices("cpu")[0]
    backend = device.client
    hlo_text = open(out / role["hlo"]).read()
    proto = xc._xla.hlo_module_from_text(hlo_text).as_serialized_hlo_module_proto()
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(xc.XlaComputation(proto))
    exe = backend.compile_and_load(mlir, [device])
    bufs = [backend.buffer_from_pyval(a) for a in arrays]
    (result,) = exe.execute(bufs)
    got = np.asarray(result[0] if isinstance(result, (list, tuple)) else result)
    np.testing.assert_allclose(got, np.asarray(want), atol=1e-4, rtol=1e-4)


def test_repeat_export_is_stable(exported, tiny_family, tmp_path):
    """Re-exporting produces identical weights (determinism contract)."""
    configs.FAMILIES["tinyfam"] = tiny_family
    try:
        entry2 = aot.export_family("tinyfam", str(tmp_path), roles=["target"])
    finally:
        del configs.FAMILIES["tinyfam"]
    out, entry = exported
    a = open(out / entry["roles"]["target"]["params_bin"], "rb").read()
    b = open(tmp_path / entry2["roles"]["target"]["params_bin"], "rb").read()
    assert a == b


# ---------------------------------------------------------------------------
# KV-cached incremental path (prefill / decode-step split)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup(tiny_family):
    cfg, params = build_role_params(tiny_family, "target")
    toks = (jnp.arange(cfg.seq_len, dtype=jnp.int32) * 5) % cfg.vocab
    return cfg, params, toks


def test_prefill_logits_match_forward(tiny_setup):
    """Prefill is the same computation as forward plus saved K/V — exact."""
    cfg, params, toks = tiny_setup
    want = forward(params, toks, cfg)
    got, kc, vc = forward_prefill(params, toks, cfg)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert kc.shape == (cfg.n_layers, cfg.seq_len // aot.BLOCK_SIZE,
                        aot.BLOCK_SIZE, cfg.n_heads, cfg.d_head)
    assert vc.shape == kc.shape


def test_decode_rows_match_forward(tiny_setup):
    """Decode over a cache built from a *padded* prefill reproduces the
    full-context forward's suffix rows: garbage rows past prefix_len must
    not leak into attention."""
    cfg, params, toks = tiny_setup
    p, d = 12, 4
    # Prefill sees the true prefix but junk at positions >= p.
    padded = toks.at[p:].set(7 % cfg.vocab)
    _, kc, vc = forward_prefill(params, padded, cfg)
    got, kc2, vc2 = forward_decode(params, toks[p:p + d], p, kc, vc, cfg)
    want = forward(params, toks, cfg)[p:p + d]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert kc2.shape == kc.shape


def test_decode_after_rollback_overwrites_stale_rows(tiny_setup):
    """Rollback is a host-side length decrement: re-decoding a *different*
    suffix at the same prefix_len must overwrite the stale rows and match
    a fresh full-context forward on the new tokens."""
    cfg, params, toks = tiny_setup
    p, d = 12, 4
    _, kc, vc = forward_prefill(params, toks.at[p:].set(0), cfg)
    # First speculation: some draft suffix, later rejected.
    draft = (toks[p:p + d] + 3) % cfg.vocab
    _, kc, vc = forward_decode(params, draft, p, kc, vc, cfg)
    # After rollback to p, decode the real suffix over the same cache.
    got, _, _ = forward_decode(params, toks[p:p + d], p, kc, vc, cfg)
    want = forward(params, toks, cfg)[p:p + d]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_decode_chained_windows(tiny_setup):
    """Appending in several window-sized chunks equals one long forward."""
    cfg, params, toks = tiny_setup
    p, w = 8, 4
    _, kc, vc = forward_prefill(params, toks.at[p:].set(0), cfg)
    rows = []
    for start in range(p, p + 3 * w, w):
        out, kc, vc = forward_decode(params, toks[start:start + w],
                                     start, kc, vc, cfg)
        rows.append(np.asarray(out))
    want = forward(params, toks, cfg)[p:p + 3 * w]
    np.testing.assert_allclose(np.concatenate(rows), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_pool_batched_decode_matches_solo(tiny_setup):
    """One pooled decode over B slots == per-slot solo decodes, and dummy
    rows on one slot leave the other slot's result untouched."""
    cfg, params, toks = tiny_setup
    b, d = 2, 4
    toks2 = (toks * 3 + 1) % cfg.vocab
    p1, p2 = 12, 8
    nb = cfg.seq_len // aot.BLOCK_SIZE
    pool_shape = (b, cfg.n_layers, nb, aot.BLOCK_SIZE, cfg.n_heads, cfg.d_head)
    k_pool = jnp.zeros(pool_shape)
    v_pool = jnp.zeros(pool_shape)
    _, k_pool, v_pool = forward_prefill_pool(
        params, toks.at[p1:].set(0), 0, k_pool, v_pool, cfg)
    _, k_pool, v_pool = forward_prefill_pool(
        params, toks2.at[p2:].set(0), 1, k_pool, v_pool, cfg)

    suffixes = jnp.stack([toks[p1:p1 + d], toks2[p2:p2 + d]])
    lens = jnp.array([p1, p2], jnp.int32)
    got, k_pool, v_pool = forward_decode_pool(
        params, suffixes, lens, k_pool, v_pool, cfg)
    want1 = forward(params, toks, cfg)[p1:p1 + d]
    want2 = forward(params, toks2, cfg)[p2:p2 + d]
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want1),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want2),
                               atol=1e-4, rtol=1e-4)

    # Second call: slot 0 decodes for real, slot 1 rides along as a dummy —
    # zero tokens at its own current length, so the write lands entirely in
    # its never-attended garbage region.
    suffixes = jnp.stack([toks[p1 + d:p1 + 2 * d], jnp.zeros(d, jnp.int32)])
    lens = jnp.array([p1 + d, p2 + d], jnp.int32)
    got2, k_pool, v_pool = forward_decode_pool(
        params, suffixes, lens, k_pool, v_pool, cfg)
    want3 = forward(params, toks, cfg)[p1 + d:p1 + 2 * d]
    np.testing.assert_allclose(np.asarray(got2[0]), np.asarray(want3),
                               atol=1e-4, rtol=1e-4)
    # Slot 1's real rows survived the dummy write: decode its true suffix.
    got3, _, _ = forward_decode_pool(
        params, jnp.stack([jnp.zeros(d, jnp.int32), toks2[p2 + d:p2 + 2 * d]]),
        jnp.array([p1 + 2 * d, p2 + d], jnp.int32), k_pool, v_pool, cfg)
    want4 = forward(params, toks2, cfg)[p2 + d:p2 + 2 * d]
    np.testing.assert_allclose(np.asarray(got3[1]), np.asarray(want4),
                               atol=1e-4, rtol=1e-4)


@pytest.fixture(scope="module")
def exported_inc(tiny_family, tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_inc")
    configs.FAMILIES["tinyfam"] = tiny_family
    try:
        entry = aot.export_family("tinyfam", str(out), roles=["target"],
                                  batched=2, window=4)
    finally:
        del configs.FAMILIES["tinyfam"]
    return out, entry


def test_incremental_manifest_entry(exported_inc):
    out, entry = exported_inc
    role = entry["roles"]["target"]
    assert role["batched"]["batch"] == 2
    assert os.path.exists(out / role["batched"]["hlo"])
    inc = role["incremental"]
    assert inc["batch"] == 2 and inc["window"] == 4
    assert inc["cache"]["block_size"] == aot.BLOCK_SIZE
    assert inc["cache"]["blocks"] * aot.BLOCK_SIZE == role["config"]["seq_len"]
    assert inc["cache"]["n_layers"] == role["config"]["n_layers"]
    assert os.path.exists(out / inc["prefill_hlo"])
    assert os.path.exists(out / inc["decode_hlo"])
    assert inc["params_bin"] == role["params_bin"]


def test_incremental_hlo_signatures(exported_inc):
    """The lowered entry computations carry the pool/suffix shapes the rust
    loader will feed (3-output tuple, [B, W] suffixes, pool params)."""
    out, entry = exported_inc
    inc = entry["roles"]["target"]["incremental"]
    prefill = open(out / inc["prefill_hlo"]).read()
    decode = open(out / inc["decode_hlo"]).read()
    assert "ENTRY" in prefill and "ENTRY" in decode
    assert "s32[32]" in prefill        # full-context tokens
    assert "s32[2,4]" in decode        # [B, W] suffixes
    assert "s32[2]" in decode          # prefix_lens
    # Pool tensors appear as parameters in both.
    pool = "f32[2,2,2,16,2,16]"        # [B, L, NB, BS, H, dh]
    assert pool in prefill and pool in decode
