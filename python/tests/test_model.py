"""L2 correctness: model forward shapes, causality, Pallas-vs-ref parity,
and the chain-derivation properties the system depends on."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs
from compile.model import forward
from compile.params import (build_role_params, derive_draft, derive_intermediate,
                            init_params, quant_rel_error)


@pytest.fixture(scope="module")
def tiny_cfg():
    return dataclasses.replace(
        configs.FAMILIES["v7b"].target, n_layers=2, d_model=32, n_heads=2,
        d_ff=64, vocab=64, seq_len=64, name="tiny",
    )


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return init_params(tiny_cfg)


def test_forward_shape(tiny_cfg, tiny_params):
    toks = jnp.arange(64, dtype=jnp.int32) % tiny_cfg.vocab
    logits = forward(tiny_params, toks, tiny_cfg, use_pallas=False)
    assert logits.shape == (64, tiny_cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_pallas_path_matches_ref_path(tiny_cfg, tiny_params):
    # The lowered artifact uses the Pallas kernels; prove they don't change
    # the model's function.
    toks = (jnp.arange(64, dtype=jnp.int32) * 7) % tiny_cfg.vocab
    a = forward(tiny_params, toks, tiny_cfg, use_pallas=True)
    b = forward(tiny_params, toks, tiny_cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)


def test_pallas_path_matches_ref_quantized(tiny_cfg, tiny_params):
    qcfg = dataclasses.replace(tiny_cfg, n_layers=1)
    qparams = derive_intermediate(tiny_params, 1, 16)
    toks = (jnp.arange(64, dtype=jnp.int32) * 3) % tiny_cfg.vocab
    a = forward(qparams, toks, qcfg, use_pallas=True)
    b = forward(qparams, toks, qcfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)


def test_causality(tiny_cfg, tiny_params):
    t1 = jnp.zeros(32, jnp.int32).at[31].set(5)
    t2 = jnp.zeros(32, jnp.int32).at[31].set(9)
    a = forward(tiny_params, t1, tiny_cfg, use_pallas=False)
    b = forward(tiny_params, t2, tiny_cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a[:31]), np.asarray(b[:31]), atol=1e-5)
    assert not np.allclose(np.asarray(a[31]), np.asarray(b[31]))


def test_deterministic_in_seed(tiny_cfg):
    p1 = init_params(tiny_cfg)
    p2 = init_params(tiny_cfg)
    np.testing.assert_array_equal(np.asarray(p1["tok_emb"]), np.asarray(p2["tok_emb"]))


def test_derivations_share_weights(tiny_params):
    d = derive_draft(tiny_params, 1)
    assert d["tok_emb"] is tiny_params["tok_emb"]
    assert len(d["layers"]) == 1
    i = derive_intermediate(tiny_params, 1, 16)
    assert set(i["layers"][0]["wq"].keys()) == {"q", "s", "group"}


def test_chain_capability_ordering(tiny_cfg, tiny_params):
    """The derivation premise: intermediate tracks the target more closely
    than the draft does (acceptance ordering L(i->t) > L(d->t))."""
    toks = (jnp.arange(64, dtype=jnp.int32) * 11) % tiny_cfg.vocab
    lt = forward(tiny_params, toks, tiny_cfg, use_pallas=False)
    icfg = dataclasses.replace(tiny_cfg, n_layers=1)
    li = forward(derive_intermediate(tiny_params, 1, 16), toks, icfg, use_pallas=False)
    # A *separately seeded* model is the "uncorrelated decoy".
    decoy_cfg = dataclasses.replace(tiny_cfg, seed=tiny_cfg.seed + 999)
    ld = forward(init_params(decoy_cfg), toks, decoy_cfg, use_pallas=False)
    sm = lambda l: jax.nn.softmax(l, -1)
    overlap = lambda a, b: float(jnp.minimum(sm(a), sm(b)).sum(-1).mean())
    assert overlap(li, lt) > overlap(ld, lt) + 0.1


def test_quant_error_bounds():
    key_w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    assert quant_rel_error(key_w, 32) < 0.12
    assert quant_rel_error(key_w, 8) < quant_rel_error(key_w, 64) + 1e-6


@pytest.mark.parametrize("family", list(configs.FAMILIES))
def test_all_family_roles_materialize(family):
    fam = configs.FAMILIES[family]
    for role in fam.roles():
        cfg, params = build_role_params(fam, role)
        assert len(params["layers"]) == cfg.n_layers
        # Geometry constraints the kernels rely on.
        assert cfg.d_model % cfg.n_heads == 0
