"""L1 correctness: Pallas int4 dequant-matmul vs oracle + quantization error
bounds (the intermediate model's fidelity premise)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quant_matmul import quant_matmul, quantize_weight, vmem_bytes
from compile.kernels.ref import dequant_ref, quant_matmul_ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


@pytest.mark.parametrize("m,k,n,g", [(8, 64, 32, 16), (16, 128, 128, 32), (1, 96, 48, 32)])
def test_matches_ref(m, k, n, g):
    x = _rand(0, (m, k))
    w = _rand(1, (k, n))
    q, s, g_eff = quantize_weight(w, group=g)
    out = quant_matmul(x, q, s, group=g_eff)
    ref = quant_matmul_ref(x, q, s, group=g_eff)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 24),
    kg=st.integers(1, 6),
    n=st.sampled_from([16, 48, 64, 96]),
    g=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_hypothesis(m, kg, n, g, seed):
    k = g * kg
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    q, s, g_eff = quantize_weight(w, group=g)
    out = quant_matmul(x, q, s, group=g_eff)
    ref = quant_matmul_ref(x, q, s, group=g_eff)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


def test_quantized_values_are_int4():
    w = _rand(3, (64, 32))
    q, s, _ = quantize_weight(w, group=16)
    assert q.dtype == jnp.int8
    assert int(q.min()) >= -8 and int(q.max()) <= 7


def test_adaptive_group_for_odd_k():
    w = _rand(4, (144, 32))  # 144 % 32 != 0
    q, s, g = quantize_weight(w, group=32)
    assert 144 % g == 0 and g <= 32
    x = _rand(5, (4, 144))
    out = quant_matmul(x, q, s, group=g)
    ref = quant_matmul_ref(x, q, s, group=g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_roundtrip_error_small():
    # int4 with group 32 should reconstruct weights to within a few percent —
    # the premise that makes the intermediate model a high-acceptance M2.
    w = _rand(6, (128, 128))
    q, s, g = quantize_weight(w, group=32)
    wd = dequant_ref(q, s, group=g)
    rel = float(jnp.linalg.norm(wd - w) / jnp.linalg.norm(w))
    assert rel < 0.12, rel


def test_error_decreases_with_smaller_groups():
    w = _rand(7, (128, 64))
    errs = []
    for g in [64, 32, 8]:
        q, s, ge = quantize_weight(w, group=g)
        wd = dequant_ref(q, s, group=ge)
        errs.append(float(jnp.linalg.norm(wd - w)))
    assert errs[0] >= errs[1] >= errs[2], errs


def test_vmem_estimate_fits_budget():
    assert vmem_bytes(160, 128, 4, 32) < 1 << 20
