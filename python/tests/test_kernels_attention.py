"""L1 correctness: Pallas flash-attention vs the pure-jnp oracle.

Hypothesis sweeps shapes; assert_allclose against ref — the CORE
correctness signal for the attention kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, vmem_bytes
from compile.kernels.ref import attention_ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@pytest.mark.parametrize("bh,s,dh", [(1, 32, 8), (4, 64, 32), (2, 160, 32), (8, 96, 16)])
def test_matches_ref_basic(bh, s, dh):
    q, k, v = (_rand(i, (bh, s, dh)) for i in range(3))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    bh=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    dh=st.sampled_from([4, 8, 16, 24]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_hypothesis(bh, s_blocks, dh, seed):
    s = 32 * s_blocks
    q = _rand(seed, (bh, s, dh))
    k = _rand(seed + 1, (bh, s, dh))
    v = _rand(seed + 2, (bh, s, dh))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_non_multiple_block_falls_back():
    # 144 is not divisible by 32; the kernel must auto-pick a divisor block.
    q, k, v = (_rand(i, (2, 144, 16)) for i in range(3))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_causality():
    # Changing a later token must not affect earlier rows.
    q1, k1, v1 = (_rand(i, (1, 64, 8)) for i in range(3))
    q2 = q1.at[0, -1].set(99.0)
    k2 = k1.at[0, -1].set(99.0)
    v2 = v1.at[0, -1].set(99.0)
    a = flash_attention(q1, k1, v1)
    b = flash_attention(q2, k2, v2)
    np.testing.assert_allclose(np.asarray(a[0, :-1]), np.asarray(b[0, :-1]), atol=1e-6)


def test_first_row_attends_only_self():
    q, k, v = (_rand(i, (1, 32, 8)) for i in range(3))
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0]), atol=1e-5)


def test_scale_invariance_of_rows():
    # softmax rows sum to 1: uniform v => output equals v everywhere.
    q = _rand(0, (1, 64, 8))
    k = _rand(1, (1, 64, 8))
    v = jnp.ones((1, 64, 8))
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.ones((1, 64, 8)), atol=1e-5)


def test_vmem_estimate_fits_budget():
    # Structure-level perf check: one program's working set must fit VMEM
    # (16 MiB/core on modern TPUs) with ample headroom at our shapes.
    assert vmem_bytes(160, 32) < 1 << 20
