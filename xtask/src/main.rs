//! `cargo xtask check` — repo-specific invariant lints the generic tools
//! (clippy, rustc) cannot express. Pure-std lexical analysis over
//! `rust/src` (plus `examples/` for the counters rule); no syn, no
//! network. See `rust/docs/verification.md` for the full invariant list.
//!
//! Rules (each violation prints `error[<rule>] <file>:<line>: <msg>`):
//!
//! - `panic` — no `.unwrap()` / `.expect(` / `panic!` / `unreachable!`
//!   outside `#[cfg(test)]` regions. Escapes: the engine-boundary
//!   allowlist (`main.rs`, `runtime/engine.rs`), `unwrap_or*`
//!   combinators, the JSON scanner's own `self.expect(` method, and an
//!   `// xtask:allow(panic): <why>` annotation.
//! - `kv-pairing` — a module whose non-test code calls a KV `admit`
//!   method must also call `release`/`release_cached`/`suspend`, or carry
//!   an `// xtask:allow(kv-pairing): <why>` annotation on the first
//!   admit site (ownership-transfer modules like the router).
//! - `facade` — modules routed through the `crate::sync` facade must not
//!   name `std::sync`, `std::thread`, or `std::time::Instant` outside
//!   tests (loom model checking depends on it); escape with
//!   `// xtask:allow(facade): <why>`.
//! - `counters` — every `pub ...: AtomicU64` field of `Metrics` must be
//!   emitted by `snapshot()`, and the serve benchmark must write the
//!   snapshot into BENCH_serve.json (a counter nobody exports is a
//!   counter nobody will ever see regress).
//! - `no-debug` — no `todo!(` or `dbg!(` anywhere, tests included.
//!
//! Annotations bind to the same line or the contiguous `//` comment block
//! immediately above the flagged line.

#![forbid(unsafe_code)]

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files where panics are an accepted part of the contract: the CLI
/// binary (top-level error reporting) and the PJRT engine boundary
/// (feature-gated FFI shims whose failures are unrecoverable anyway).
const PANIC_ALLOWED_PATHS: &[&str] = &["rust/src/main.rs", "rust/src/runtime/engine.rs"];

/// Modules whose concurrency primitives must come from `crate::sync` so
/// the loom suite models the real code. Prefix match (covers
/// `coordinator/paged/*`).
const FACADE_ROUTED: &[&str] = &[
    "rust/src/coordinator/batcher.rs",
    "rust/src/coordinator/kv.rs",
    "rust/src/coordinator/scheduler.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/server.rs",
    "rust/src/coordinator/metrics.rs",
    "rust/src/coordinator/paged/",
    "rust/src/spec/types.rs",
    "rust/src/runtime/host.rs",
];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "error[{}] {}:{}: {}", self.rule, self.file, self.line, self.msg)
    }
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("check") | None => run_check(),
        Some(other) => {
            eprintln!("unknown xtask `{other}`; available: check");
            ExitCode::from(2)
        }
    }
}

fn run_check() -> ExitCode {
    // CARGO_MANIFEST_DIR is xtask/; the workspace root is its parent.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));

    let mut violations = Vec::new();
    let mut files = Vec::new();
    collect_rust_files(&root.join("rust/src"), &mut files);
    files.sort();

    for path in &files {
        let Ok(content) = std::fs::read_to_string(path) else {
            eprintln!("warning: cannot read {}", path.display());
            continue;
        };
        let label = rel_label(&root, path);
        violations.extend(check_panics(&label, &content));
        violations.extend(check_kv_pairing(&label, &content));
        violations.extend(check_facade(&label, &content));
        violations.extend(check_no_debug(&label, &content));
    }

    let metrics = root.join("rust/src/coordinator/metrics.rs");
    let bench = root.join("examples/serve_specbench.rs");
    let metrics_src = std::fs::read_to_string(&metrics).unwrap_or_default();
    let bench_src = std::fs::read_to_string(&bench).unwrap_or_default();
    violations.extend(check_counters(
        &rel_label(&root, &metrics),
        &metrics_src,
        &rel_label(&root, &bench),
        &bench_src,
    ));

    if violations.is_empty() {
        println!("xtask check: {} files, 0 violations", files.len());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            println!("{v}");
        }
        println!("xtask check: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn rel_label(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Lines before the first `#[cfg(test)]` / `#[cfg(all(test, ...))]` —
/// the convention in this repo is a single trailing test module.
fn non_test_region(content: &str) -> Vec<&str> {
    let mut out = Vec::new();
    for line in content.lines() {
        let t = line.trim_start();
        if t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test") {
            break;
        }
        out.push(line);
    }
    out
}

/// An `// xtask:allow(<rule>): why` annotation on the flagged line or in
/// the contiguous comment block immediately above it.
fn annotated(lines: &[&str], idx: usize, rule: &str) -> bool {
    let marker = format!("xtask:allow({rule})");
    if lines[idx].contains(&marker) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = lines[j].trim_start();
        if !t.starts_with("//") {
            return false;
        }
        if t.contains(&marker) {
            return true;
        }
    }
    false
}

fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

fn check_panics(label: &str, content: &str) -> Vec<Violation> {
    if PANIC_ALLOWED_PATHS.contains(&label) {
        return Vec::new();
    }
    let lines = non_test_region(content);
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        if is_comment(raw) {
            continue;
        }
        // `unwrap_or*` combinators are fallbacks, not panics, and the JSON
        // scanner's `self.expect(byte)` is its own (Result-returning)
        // parser method — neither is a panic site.
        let line = raw.replace(".unwrap_or", "").replace("self.expect(", "");
        let hit = [".unwrap()", ".expect(", "panic!(", "unreachable!("]
            .iter()
            .find(|pat| line.contains(*pat));
        let Some(pat) = hit else { continue };
        if annotated(&lines, i, "panic") {
            continue;
        }
        out.push(Violation {
            file: label.to_string(),
            line: i + 1,
            rule: "panic",
            msg: format!(
                "`{}` outside tests; return an error, or justify with \
                 `// xtask:allow(panic): <why>`",
                pat.trim_end_matches('(')
            ),
        });
    }
    out
}

fn check_kv_pairing(label: &str, content: &str) -> Vec<Violation> {
    const ADMITS: &[&str] =
        &[".admit(", ".admit_fresh(", ".admit_fresh_prefixed(", ".admit_resumed_prefixed("];
    const PAIRS: &[&str] = &[".release(", ".release_cached(", ".suspend("];
    let lines = non_test_region(content);
    let mut first_admit = None;
    let mut paired = false;
    for (i, raw) in lines.iter().enumerate() {
        if is_comment(raw) {
            continue;
        }
        if ADMITS.iter().any(|p| raw.contains(p)) && first_admit.is_none() {
            first_admit = Some(i);
        }
        if PAIRS.iter().any(|p| raw.contains(p)) {
            paired = true;
        }
    }
    match first_admit {
        Some(i) if !paired && !annotated(&lines, i, "kv-pairing") => vec![Violation {
            file: label.to_string(),
            line: i + 1,
            rule: "kv-pairing",
            msg: "module admits KV sequences but never releases or suspends any; \
                  pair the allocation or justify the ownership transfer with \
                  `// xtask:allow(kv-pairing): <why>`"
                .to_string(),
        }],
        _ => Vec::new(),
    }
}

fn check_facade(label: &str, content: &str) -> Vec<Violation> {
    if !FACADE_ROUTED.iter().any(|p| label == *p || label.starts_with(p)) {
        return Vec::new();
    }
    let lines = non_test_region(content);
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        if is_comment(raw) {
            continue;
        }
        let hit = ["std::sync", "std::thread", "std::time::Instant"]
            .iter()
            .find(|pat| raw.contains(*pat));
        let Some(pat) = hit else { continue };
        if annotated(&lines, i, "facade") {
            continue;
        }
        out.push(Violation {
            file: label.to_string(),
            line: i + 1,
            rule: "facade",
            msg: format!(
                "`{pat}` in a facade-routed module; use `crate::sync` so the \
                 loom models cover this code, or justify with \
                 `// xtask:allow(facade): <why>`"
            ),
        });
    }
    out
}

fn check_no_debug(label: &str, content: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    for (i, raw) in content.lines().enumerate() {
        if is_comment(raw) {
            continue;
        }
        let hit = ["todo!(", "dbg!("].iter().find(|pat| raw.contains(*pat));
        if let Some(pat) = hit {
            out.push(Violation {
                file: label.to_string(),
                line: i + 1,
                rule: "no-debug",
                msg: format!("`{}` must not ship, tests included", pat.trim_end_matches('(')),
            });
        }
    }
    out
}

/// Every `pub <name>: AtomicU64` field of `Metrics` must be named in the
/// `snapshot()` body, and the serve benchmark must export the snapshot.
fn check_counters(
    metrics_label: &str,
    metrics_src: &str,
    bench_label: &str,
    bench_src: &str,
) -> Vec<Violation> {
    let mut out = Vec::new();
    if metrics_src.is_empty() {
        out.push(Violation {
            file: metrics_label.to_string(),
            line: 1,
            rule: "counters",
            msg: "cannot read the metrics module".to_string(),
        });
        return out;
    }
    let fields = struct_pub_atomic_fields(metrics_src, "Metrics");
    let snapshot = fn_body(metrics_src, "fn snapshot");
    if snapshot.is_empty() {
        out.push(Violation {
            file: metrics_label.to_string(),
            line: 1,
            rule: "counters",
            msg: "Metrics has no snapshot() to export its counters".to_string(),
        });
        return out;
    }
    for (line, name) in fields {
        if !snapshot.contains(&name) {
            out.push(Violation {
                file: metrics_label.to_string(),
                line,
                rule: "counters",
                msg: format!(
                    "counter `{name}` is never emitted by snapshot(); \
                     a counter nobody exports cannot be watched for regressions"
                ),
            });
        }
    }
    if !bench_src.is_empty() && !bench_src.contains(".snapshot()") {
        out.push(Violation {
            file: bench_label.to_string(),
            line: 1,
            rule: "counters",
            msg: "serve benchmark must write the metrics snapshot into BENCH_serve.json"
                .to_string(),
        });
    }
    out
}

/// `(line, name)` of each `pub <name>: AtomicU64` field in `struct <name>`.
fn struct_pub_atomic_fields(src: &str, struct_name: &str) -> Vec<(usize, String)> {
    let header = format!("struct {struct_name} ");
    let header_brace = format!("struct {struct_name} {{");
    let mut out = Vec::new();
    let mut in_struct = false;
    let mut depth = 0i32;
    for (i, line) in src.lines().enumerate() {
        if !in_struct {
            let t = line.trim_start();
            if is_comment(line) {
                continue;
            }
            if t.contains(&header_brace) || t.ends_with(header.trim_end()) {
                in_struct = true;
                depth = brace_delta(line);
            }
            continue;
        }
        depth += brace_delta(line);
        if depth <= 0 {
            break;
        }
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("pub ") {
            if let Some((name, ty)) = rest.split_once(':') {
                if ty.trim().trim_end_matches(',') == "AtomicU64" {
                    out.push((i + 1, name.trim().to_string()));
                }
            }
        }
    }
    out
}

/// Body of the first function whose signature line contains `sig`,
/// delimited by brace counting from its opening `{`.
fn fn_body(src: &str, sig: &str) -> String {
    let mut body = String::new();
    let mut depth = 0i32;
    let mut started = false;
    for line in src.lines() {
        if !started {
            if line.contains(sig) && !is_comment(line) {
                started = true;
                depth = brace_delta(line);
            }
            continue;
        }
        depth += brace_delta(line);
        body.push_str(line);
        body.push('\n');
        if depth <= 0 {
            break;
        }
    }
    body
}

/// Net `{`/`}` count of a line. Lexically naive (braces in strings count),
/// which is fine for the struct/fn scopes this tool measures.
fn brace_delta(line: &str) -> i32 {
    let mut d = 0i32;
    for c in line.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance fixture: an unpaired admit AND a hot-path unwrap in
    /// one module — both must be reported, by the right rules.
    #[test]
    fn seeded_violations_are_both_reported() {
        let fixture = r#"
pub fn admit_only(kv: &mut KvManager) {
    kv.admit_fresh(1, 16).unwrap();
}
"#;
        let panics = check_panics("rust/src/coordinator/fixture.rs", fixture);
        assert_eq!(panics.len(), 1, "{panics:?}");
        assert_eq!(panics[0].rule, "panic");
        assert_eq!(panics[0].line, 3);

        let pairing = check_kv_pairing("rust/src/coordinator/fixture.rs", fixture);
        assert_eq!(pairing.len(), 1, "{pairing:?}");
        assert_eq!(pairing[0].rule, "kv-pairing");
        assert_eq!(pairing[0].line, 3);
    }

    #[test]
    fn annotations_suppress_with_reason() {
        let fixture = r#"
// xtask:allow(kv-pairing): ownership transfers to the scheduler.
kv.admit_fresh(1, 16)?;
// A longer justification that spans the contiguous comment block
// xtask:allow(panic): the branch above proves the key exists.
let v = map.get(&k).unwrap();
"#;
        assert!(check_kv_pairing("x.rs", fixture).is_empty());
        assert!(check_panics("x.rs", fixture).is_empty());
    }

    #[test]
    fn annotation_does_not_leak_past_code_lines() {
        let fixture = r#"
// xtask:allow(panic): only blesses the next statement.
let a = x.unwrap();
let b = y.unwrap();
"#;
        let v = check_panics("x.rs", fixture);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn test_regions_and_allowlisted_paths_are_skipped() {
        let fixture = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(check_panics("rust/src/spec/x.rs", fixture).is_empty());
        assert!(check_panics("rust/src/main.rs", "fn f() { x.unwrap(); }").is_empty());
        // ...but no-debug applies even inside tests.
        let t = "#[cfg(test)]\nmod tests {\n    fn f() { dbg!(1); }\n}\n";
        assert_eq!(check_no_debug("rust/src/spec/x.rs", t).len(), 1);
    }

    #[test]
    fn unwrap_or_and_scanner_expect_are_not_panics() {
        let fixture = r#"
let a = x.unwrap_or(0);
let b = x.unwrap_or_else(|| 0);
let c = x.unwrap_or_default();
self.expect(b'{')?;
"#;
        assert!(check_panics("x.rs", fixture).is_empty());
    }

    #[test]
    fn facade_rule_applies_only_to_routed_modules() {
        let src = "use std::sync::Mutex;\n";
        assert_eq!(check_facade("rust/src/coordinator/batcher.rs", src).len(), 1);
        assert_eq!(check_facade("rust/src/coordinator/paged/radix.rs", src).len(), 1);
        assert!(check_facade("rust/src/harness.rs", src).is_empty());
        let ann = "// xtask:allow(facade): monitoring-only atomics.\nuse std::sync::atomic::AtomicU64;\n";
        assert!(check_facade("rust/src/coordinator/metrics.rs", ann).is_empty());
    }

    #[test]
    fn paired_admit_release_passes() {
        let fixture = r#"
kv.admit_fresh(1, 16)?;
kv.release(1)?;
"#;
        assert!(check_kv_pairing("x.rs", fixture).is_empty());
        let suspends = r#"
kv.admit(1, 16)?;
kv.suspend(1, 16, 16)?;
"#;
        assert!(check_kv_pairing("x.rs", suspends).is_empty());
    }

    #[test]
    fn counters_rule_finds_unexported_field() {
        let metrics = r#"
pub struct Metrics {
    pub good_counter: AtomicU64,
    pub lost_counter: AtomicU64,
    private_counter: AtomicU64,
    pub histogram: LatencyHistogram,
}

impl Metrics {
    pub fn snapshot(&self) -> Json {
        put("good_counter", self.good_counter.load(Ordering::Relaxed));
    }
}
"#;
        let v = check_counters("m.rs", metrics, "b.rs", "metrics.snapshot()");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("lost_counter"));

        let missing_export = check_counters("m.rs", metrics, "b.rs", "no snapshot call");
        assert_eq!(missing_export.len(), 2);
        assert!(missing_export[1].msg.contains("BENCH_serve.json"));
    }

    #[test]
    fn fn_body_is_brace_delimited() {
        let src = "impl X {\n    pub fn snapshot(&self) -> J {\n        a();\n    }\n    pub fn other(&self) { b(); }\n}\n";
        let body = fn_body(src, "fn snapshot");
        assert!(body.contains("a()"));
        assert!(!body.contains("b()"));
    }
}
