//! Paged-KV subsystem micro-benchmarks: the accounting hot paths the
//! serving loop hits on every admission, growth step, and preemption.
//!   * block-table admit / grow / release cycles (allocator + refcounts)
//!   * prefixed admission on a warm radix cache (full-prefix hit)
//!   * cold-miss admission with register + on-demand LRU eviction
//!   * divergent-prompt admission (partial hit, copy-on-write tail)
//!   * suspend-to-swap / restore round-trip
//!
//!   cargo bench --bench kv_paged

use std::time::Instant;

use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::spec::rng::Pcg32;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "us")
    };
    println!("{name:<52} {val:>9.3} {unit}/iter  ({iters} iters)");
    per
}

fn cfg(total_blocks: usize, swap_blocks: usize) -> KvConfig {
    KvConfig { block_size: 16, total_blocks, bytes_per_token: 4, swap_blocks }
}

fn main() {
    let mut rng = Pcg32::seeded(7);
    let prompt = |n: usize, rng: &mut Pcg32| -> Vec<i32> {
        (0..n).map(|_| rng.next_below(50_000) as i32).collect()
    };

    println!("== kv_paged: block-table allocator ==");
    {
        let mut kv = KvManager::new(cfg(4096, 0));
        let mut id = 0u64;
        bench("admit + 4x grow + release (no cache traffic)", 20_000, || {
            id += 1;
            kv.admit(id, 100).unwrap();
            for g in 1..=4usize {
                kv.grow(id, 100 + g * 16).unwrap();
            }
            kv.release(id).unwrap();
        });
        assert_eq!(kv.active_seqs(), 0);
    }

    println!("\n== kv_paged: radix prefix cache (256-token prompts, 16-token blocks) ==");
    {
        // Warm path: one transcript seeds the cache, every admission after
        // that maps its 16 full blocks instead of allocating.
        let mut kv = KvManager::new(cfg(4096, 0));
        let transcript = prompt(256, &mut rng);
        kv.admit_fresh_prefixed(1, &transcript, transcript.len()).unwrap();
        kv.release_cached(1, &transcript).unwrap();
        let mut id = 1u64;
        bench("prefixed admission, warm full-prefix hit", 20_000, || {
            id += 1;
            let hits = kv
                .admit_fresh_prefixed(id, &transcript, transcript.len() + 32)
                .unwrap();
            std::hint::black_box(hits);
            kv.release(id).unwrap();
        });

        // Divergent path: shares the transcript's prefix but splits off
        // inside the cached run, exercising the copy-on-write machinery.
        let mut diverged = transcript[..250].to_vec();
        diverged.extend(prompt(6, &mut rng));
        bench("prefixed admission, divergent tail (partial hit)", 20_000, || {
            id += 1;
            let hits = kv.admit_fresh_prefixed(id, &diverged, diverged.len() + 32).unwrap();
            std::hint::black_box(hits);
            kv.release(id).unwrap();
        });
        println!(
            "  (cache: {} blocks resident, {} prefix-hit tokens, {} CoW splits)",
            kv.cached_blocks(),
            kv.prefix_hit_tokens(),
            kv.cow_splits()
        );
    }
    {
        // Cold path: every prompt is new, so each admission misses, registers
        // its blocks, and — once the pool fills with cached-but-unmapped
        // blocks — evicts an LRU subtree to make room. This is the
        // steady-state cost of serving non-repeating traffic with the cache
        // enabled.
        let mut kv = KvManager::new(cfg(4096, 0));
        let n = 2048usize;
        let prompts: Vec<Vec<i32>> = (0..=n).map(|_| prompt(256, &mut rng)).collect();
        let mut i = 0usize;
        bench("prefixed admission, cold miss + register + evict", n, || {
            let p = &prompts[i % prompts.len()];
            i += 1;
            kv.admit_fresh_prefixed(i as u64, p, p.len()).unwrap();
            kv.release(i as u64).unwrap();
        });
    }

    println!("\n== kv_paged: suspend-to-swap tier ==");
    {
        let mut kv = KvManager::new(cfg(1024, 1024));
        let mut id = 0u64;
        bench("suspend -> swap -> restore round-trip (256 tok)", 20_000, || {
            id += 1;
            kv.admit_fresh(id, 256).unwrap();
            let h = kv
                .suspend(id, 256, 256)
                .unwrap()
                .expect("tier sized for every victim");
            kv.restore(id, &h, 256).unwrap();
            kv.settle_resume_debt(256);
            kv.release(id).unwrap();
        });
        assert_eq!(kv.swapped_blocks(), 0, "tier must drain");
        assert_eq!(kv.resume_debt(), 0, "debt must settle");
        println!("  (restore credited {} tokens of avoided recompute)", kv.restore_tokens_saved());
    }
}
