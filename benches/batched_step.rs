//! Cross-request batched verification benchmark: engine calls per tick
//! and wall-clock for a batch of live same-chain requests, scheduler
//! coalescing on vs off, at 1 / 8 / 32 live requests.
//!
//! The chain is two mock members with a fixed per-call busy-wait, so the
//! wall-clock difference is dominated by how many engine calls the
//! scheduler issues — the quantity the coalescer (one `SessionAppendBatch`
//! per chain member per tick) exists to collapse. A perfect drafter
//! (same weights as the target) keeps every tick's drafter work a pure
//! append under greedy, the best case for coalescing; with one live
//! request the two modes should be indistinguishable.
//!
//!   cargo bench --bench batched_step

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use polyspec::coordinator::api::{Method, Request};
use polyspec::coordinator::batcher::QueueEntry;
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::scheduler::{self, SchedulerOpts};
use polyspec::spec::mock::MockModel;
use polyspec::spec::types::{LanguageModel, VerifyRule};

const MAX_NEW: usize = 24;
const CALL_COST: Duration = Duration::from_micros(200);

fn chain() -> Vec<Arc<dyn LanguageModel>> {
    let target = MockModel::new("bench-target", 2048, 32, 11, 0.0).with_cost(CALL_COST);
    let draft = MockModel::new("bench-draft", 2048, 32, 11, 0.0).with_cost(CALL_COST);
    vec![Arc::new(target), Arc::new(draft)]
}

struct Run {
    wall: f64,
    /// Forwards the chain members actually executed (batched = 1 per batch).
    model_calls: u64,
    /// Scheduler-coalesced submits ([`Metrics::engine_calls`]); 0 when off.
    coalesced: u64,
    outputs: Vec<(u64, Vec<i32>)>,
}

fn run(live: usize, coalesce: bool) -> Run {
    let chain = chain();
    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 16,
        total_blocks: 4096,
        bytes_per_token: 4,
        swap_blocks: 0,
    })));
    let metrics = Arc::new(Metrics::default());
    let now = Instant::now();
    let batch: Vec<QueueEntry> = (1..=live as u64)
        .map(|id| {
            let mut r = Request::new(id, vec![3, 1, 4], MAX_NEW);
            r.method = Method::Dualistic { draft_k: 1 };
            r.rule = VerifyRule::Greedy;
            r.sampling.temperature = 0.0;
            r.sampling.seed = 100 + id;
            kv.lock().unwrap().admit(id, 80).unwrap();
            QueueEntry::fresh(r, now)
        })
        .collect();

    let mut outputs = Vec::with_capacity(live);
    let start = Instant::now();
    scheduler::run_batch_opts(
        &chain,
        batch,
        None,
        live,
        &kv,
        &metrics,
        SchedulerOpts { coalesce },
        |ev| {
            if let scheduler::BatchEvent::Done { id, response } = ev {
                outputs.push((id, response.expect("bench workload must not fault").tokens));
            }
        },
    );
    let wall = start.elapsed().as_secs_f64();
    assert_eq!(outputs.len(), live, "every request must complete");
    assert_eq!(kv.lock().unwrap().active_seqs(), 0, "KV leaked");
    outputs.sort_by_key(|(id, _)| *id);
    Run {
        wall,
        model_calls: chain.iter().map(|m| m.calls()).sum(),
        coalesced: metrics.engine_calls.load(Ordering::Relaxed),
        outputs,
    }
}

fn main() {
    println!("== batched_step: cross-request batched verification ==");
    println!(
        "(2-member mock chain, {:?}/call busy-wait, dualistic draft_k=1, greedy, {MAX_NEW} new tokens)\n",
        CALL_COST
    );
    println!(
        "{:>5} {:>10} {:>11} {:>13} {:>11} {:>9}",
        "live", "mode", "wall", "model calls", "coalesced", "speedup"
    );
    for &live in &[1usize, 8, 32] {
        let off = run(live, false);
        let on = run(live, true);
        assert_eq!(
            on.outputs, off.outputs,
            "coalescing changed committed tokens at {live} live requests"
        );
        for (mode, r, speedup) in
            [("unbatched", &off, 1.0), ("coalesced", &on, off.wall / on.wall)]
        {
            println!(
                "{:>5} {:>10} {:>9.1}ms {:>13} {:>11} {:>8.2}x",
                live,
                mode,
                r.wall * 1e3,
                r.model_calls,
                r.coalesced,
                speedup
            );
        }
    }
    println!("\n(outputs byte-identical between modes at every batch size)");
}
