//! Cross-request batched verification benchmark, two parts:
//!
//! 1. Engine calls per tick and wall-clock for a batch of live same-chain
//!    requests, scheduler coalescing on vs off, at 1 / 8 / 32 live
//!    requests. The chain is two mock members with a fixed per-call
//!    busy-wait, so the wall-clock difference is dominated by how many
//!    engine calls the scheduler issues — the quantity the coalescer (one
//!    `SessionAppendBatch` per chain member per tick) exists to collapse.
//!
//! 2. A prefix-length sweep (128 / 1k / 8k) under the O(suffix) mock cost
//!    model (`with_token_cost`): the coalesced KV-cached tick pays
//!    `cost + per_token · suffix` — flat in prefix length — while the
//!    stateless full-recompute tick pays `cost + per_token · prefix` and
//!    grows linearly. This is the per-token cost contract (Lemma 3.1's
//!    `T_i` must not scale with context) the device cache pool implements.
//!
//!   cargo bench --bench batched_step

use std::sync::atomic::Ordering;
use std::sync::Arc;
use polyspec::sync::Mutex;
use std::time::{Duration, Instant};

use polyspec::coordinator::api::{Method, Request};
use polyspec::coordinator::batcher::QueueEntry;
use polyspec::coordinator::kv::{KvConfig, KvManager};
use polyspec::coordinator::metrics::Metrics;
use polyspec::coordinator::scheduler::{self, SchedulerOpts};
use polyspec::spec::mock::MockModel;
use polyspec::spec::types::{LanguageModel, ScoringSession, Token, VerifyRule};

const MAX_NEW: usize = 24;
const CALL_COST: Duration = Duration::from_micros(200);

fn chain() -> Vec<Arc<dyn LanguageModel>> {
    let target = MockModel::new("bench-target", 2048, 32, 11, 0.0).with_cost(CALL_COST);
    let draft = MockModel::new("bench-draft", 2048, 32, 11, 0.0).with_cost(CALL_COST);
    vec![Arc::new(target), Arc::new(draft)]
}

struct Run {
    wall: f64,
    /// Forwards the chain members actually executed (batched = 1 per batch).
    model_calls: u64,
    /// Scheduler-coalesced submits ([`Metrics::engine_calls`]); 0 when off.
    coalesced: u64,
    outputs: Vec<(u64, Vec<i32>)>,
}

fn run(live: usize, coalesce: bool) -> Run {
    let chain = chain();
    let kv = Arc::new(Mutex::new(KvManager::new(KvConfig {
        block_size: 16,
        total_blocks: 4096,
        bytes_per_token: 4,
        swap_blocks: 0,
    })));
    let metrics = Arc::new(Metrics::default());
    let now = Instant::now();
    let batch: Vec<QueueEntry> = (1..=live as u64)
        .map(|id| {
            let mut r = Request::new(id, vec![3, 1, 4], MAX_NEW);
            r.method = Method::Dualistic { draft_k: 1 };
            r.rule = VerifyRule::Greedy;
            r.sampling.temperature = 0.0;
            r.sampling.seed = 100 + id;
            kv.lock().admit(id, 80).unwrap();
            QueueEntry::fresh(r, now)
        })
        .collect();

    let mut outputs = Vec::with_capacity(live);
    let start = Instant::now();
    scheduler::run_batch_opts(
        &chain,
        batch,
        None,
        live,
        &kv,
        &metrics,
        SchedulerOpts { coalesce },
        |ev| {
            if let scheduler::BatchEvent::Done { id, response } = ev {
                outputs.push((id, response.expect("bench workload must not fault").tokens));
            }
        },
    );
    let wall = start.elapsed().as_secs_f64();
    assert_eq!(outputs.len(), live, "every request must complete");
    assert_eq!(kv.lock().active_seqs(), 0, "KV leaked");
    outputs.sort_by_key(|(id, _)| *id);
    Run {
        wall,
        model_calls: chain.iter().map(|m| m.calls()).sum(),
        coalesced: metrics.engine_calls.load(Ordering::Relaxed),
        outputs,
    }
}

/// One decode-tick timing at prefix length `p`: `live` sessions, each tick
/// one coalesced `append_batch` of a 2-token suffix per session followed by
/// a 1-token rollback (the draft/verify reject pattern that keeps caches
/// hot and exercised). Returns mean tick wall-clock over `ticks` ticks.
fn cached_tick_cost(model: &MockModel, p: usize, live: usize, ticks: usize) -> f64 {
    let prefix: Vec<Token> = (0..p).map(|i| (i % 32) as Token).collect();
    let mut sessions: Vec<_> = (0..live).map(|_| model.open_session().unwrap()).collect();
    for s in &mut sessions {
        // Install the prefix without paying the prefill (absorb recomputes
        // rows locally): the sweep times steady-state decode ticks only.
        s.absorb_batched(&prefix, None).unwrap();
    }
    let start = Instant::now();
    for t in 0..ticks {
        let suffix: Arc<[Token]> = Arc::from(&[(t % 32) as Token, ((t + 7) % 32) as Token][..]);
        let entries: Vec<(u64, Arc<[Token]>)> =
            sessions.iter().map(|s| (s.batch_handle().unwrap(), suffix.clone())).collect();
        let results = model.append_batch(&entries).expect("mock batches");
        for (s, r) in sessions.iter_mut().zip(results) {
            s.absorb_batched(&suffix, r.unwrap()).unwrap();
            let len = s.len();
            s.rollback(len - 1).unwrap(); // reject the second token
        }
    }
    start.elapsed().as_secs_f64() / ticks as f64
}

/// The stateless contrast: each tick re-scores prefix + suffix in full,
/// once per session (no cache, no coalescing across the prefix).
fn stateless_tick_cost(model: &MockModel, p: usize, live: usize, ticks: usize) -> f64 {
    let mut ctx: Vec<Token> = (0..p).map(|i| (i % 32) as Token).collect();
    let start = Instant::now();
    for t in 0..ticks {
        ctx.push((t % 32) as Token);
        for _ in 0..live {
            model.forward(&ctx).unwrap();
        }
        ctx.pop();
    }
    start.elapsed().as_secs_f64() / ticks as f64
}

fn prefix_sweep() {
    const LIVE: usize = 4;
    const TICKS: usize = 32;
    let per_token = Duration::from_micros(1);
    let model = MockModel::new("sweep", 16384, 32, 17, 0.0)
        .with_cost(CALL_COST)
        .with_token_cost(per_token);
    println!("\n== prefix sweep: per-tick cost under the O(suffix) cost model ==");
    println!(
        "({LIVE} sessions, {TICKS} ticks, 2-token suffix/tick, {:?} flat + {:?}/token)\n",
        CALL_COST, per_token
    );
    println!("{:>8} {:>14} {:>16} {:>7}", "prefix", "cached/tick", "stateless/tick", "ratio");
    let mut cached = Vec::new();
    for &p in &[128usize, 1024, 8192] {
        let c = cached_tick_cost(&model, p, LIVE, TICKS);
        let s = stateless_tick_cost(&model, p, LIVE, TICKS);
        println!("{:>8} {:>12.1}us {:>14.1}us {:>6.1}x", p, c * 1e6, s * 1e6, s / c);
        cached.push(c);
    }
    // The coalesced cached tick must be flat in prefix length (generous 3x
    // margin for timer noise); the stateless tick must visibly grow.
    assert!(
        cached[2] < cached[0] * 3.0,
        "cached tick cost grew with prefix length: {:.1}us @128 vs {:.1}us @8k",
        cached[0] * 1e6,
        cached[2] * 1e6
    );
    println!("\n(cached per-tick cost flat in prefix length; stateless grows linearly)");
}

fn main() {
    println!("== batched_step: cross-request batched verification ==");
    println!(
        "(2-member mock chain, {:?}/call busy-wait, dualistic draft_k=1, greedy, {MAX_NEW} new tokens)\n",
        CALL_COST
    );
    println!(
        "{:>5} {:>10} {:>11} {:>13} {:>11} {:>9}",
        "live", "mode", "wall", "model calls", "coalesced", "speedup"
    );
    for &live in &[1usize, 8, 32] {
        let off = run(live, false);
        let on = run(live, true);
        assert_eq!(
            on.outputs, off.outputs,
            "coalescing changed committed tokens at {live} live requests"
        );
        for (mode, r, speedup) in
            [("unbatched", &off, 1.0), ("coalesced", &on, off.wall / on.wall)]
        {
            println!(
                "{:>5} {:>10} {:>9.1}ms {:>13} {:>11} {:>8.2}x",
                live,
                mode,
                r.wall * 1e3,
                r.model_calls,
                r.coalesced,
                speedup
            );
        }
    }
    println!("\n(outputs byte-identical between modes at every batch size)");
    prefix_sweep();
}
