//! Hot-path micro-benchmarks (the §Perf profiling instrument):
//!   * sampler / verifier / softmax costs per decode event
//!   * incremental scoring sessions vs stateless full-context decode
//!   * per-forward engine cost per chain member (T_i) + dispatch overhead
//!   * RemoteModel channel round-trip tax
//!
//!   cargo bench --bench micro_hotpath

use std::sync::Arc;
use std::time::Instant;

use polyspec::harness::artifacts_dir;
use polyspec::runtime::EngineHost;
use polyspec::spec::mock::MockModel;
use polyspec::spec::rng::Pcg32;
use polyspec::spec::sampler;
use polyspec::spec::types::{
    softmax, softmax_into, ForceStateless, LanguageModel, ScoringSession, VerifyRule,
};
use polyspec::spec::verify;
use polyspec::spec::{polybasic, PolyConfig};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "us")
    };
    println!("{name:<44} {val:>9.3} {unit}/iter  ({iters} iters)");
    per
}

fn main() {
    println!("== micro: CPU-side decode-event costs ==");
    let vocab = 256;
    let mut rng = Pcg32::seeded(1);
    let logits: Vec<f32> = (0..vocab).map(|i| ((i * 37 % 97) as f32) / 17.0).collect();

    bench("softmax(256) + temperature", 20_000, || {
        let p = softmax(&logits, 0.8);
        std::hint::black_box(p);
    });
    let mut probs_buf: Vec<f32> = Vec::new();
    bench("softmax_into(256) reused buffer", 20_000, || {
        softmax_into(&logits, 0.8, &mut probs_buf);
        std::hint::black_box(&probs_buf);
    });
    let probs = softmax(&logits, 1.0);
    bench("categorical sample(256)", 20_000, || {
        std::hint::black_box(sampler::sample_categorical(&probs, &mut rng));
    });
    // The proposal distribution is built OUTSIDE the timed closure: this
    // bench measures the rejection path (residual + resample), and a
    // per-iteration reversed-Vec allocation used to dominate the number.
    let q_rev: Vec<f32> = probs.iter().rev().copied().collect();
    bench("residual + resample (rejection path)", 20_000, || {
        if let Some(r) = sampler::residual(&probs, &q_rev) {
            std::hint::black_box(sampler::sample_categorical(&r, &mut rng));
        }
    });
    let p_rows: Vec<Vec<f32>> = (0..8).map(|_| probs.clone()).collect();
    let q_rows = p_rows.clone();
    let toks: Vec<i32> = (0..8).collect();
    bench("verify_block(8 tokens, speculative)", 20_000, || {
        let v = verify::verify_block(&toks, &p_rows, &q_rows, VerifyRule::Speculative, &mut rng);
        std::hint::black_box(v);
    });

    // ---- incremental scoring sessions vs stateless decode -----------------
    // The tentpole measurement: a polybasic decode on the mock chain at
    // ctx 512, 64 new tokens. "stateless" forces the StatelessSession
    // fallback (every append re-scores the whole prefix — the pre-session
    // behaviour); "sessions" uses the mock's cached rolling-hash sessions.
    println!("\n== micro: incremental scoring sessions (mock chain, ctx 512) ==");
    let prompt: Vec<i32> = (0..512).map(|i| (i * 7 % 256) as i32).collect();
    let max_new = 64;
    let mk_chain = |stateless: bool| -> Vec<Arc<dyn LanguageModel>> {
        [("mock-target", 0.0f32), ("mock-mid", 0.35), ("mock-draft", 0.8)]
            .iter()
            .map(|&(name, noise)| -> Arc<dyn LanguageModel> {
                let m = MockModel::new(name, 1024, 256, 1, noise);
                if stateless {
                    Arc::new(ForceStateless(m))
                } else {
                    Arc::new(m)
                }
            })
            .collect()
    };
    let mut cfg = PolyConfig::for_chain(3, 6, 8, max_new);
    cfg.sampling.seed = 42;
    let session_chain = mk_chain(false);
    let stateless_chain = mk_chain(true);
    // Warmup + identity check: sessions must not change the output.
    let a = polybasic::generate(&session_chain, &prompt, &cfg).unwrap();
    let b = polybasic::generate(&stateless_chain, &prompt, &cfg).unwrap();
    assert_eq!(a.tokens, b.tokens, "session decode diverged from stateless");
    let iters = 3;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(polybasic::generate(&session_chain, &prompt, &cfg).unwrap());
    }
    let session_s = t0.elapsed().as_secs_f64() / iters as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(polybasic::generate(&stateless_chain, &prompt, &cfg).unwrap());
    }
    let stateless_s = t0.elapsed().as_secs_f64() / iters as f64;
    println!("drafting loop, {max_new} new tokens @ ctx {} (outputs identical):", prompt.len());
    println!("  stateless full-context: {:>10.1} tok/s", max_new as f64 / stateless_s);
    println!("  incremental sessions:   {:>10.1} tok/s", max_new as f64 / session_s);
    println!(
        "  speedup:                {:>10.2}x  (acceptance target: >= 5x)",
        stateless_s / session_s
    );

    println!("\n== micro: engine forward costs (requires artifacts) ==");
    let artifacts = artifacts_dir();
    let host = match EngineHost::load(&artifacts, "v7b", &["target", "intermediate", "draft"]) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("skipping engine micro-benches: {e:#}");
            return;
        }
    };
    for (i, role) in ["target", "intermediate", "draft"].iter().enumerate() {
        for ctx in [16usize, 64, 128] {
            let t = host.measure_cost_ms(i, ctx, 5).unwrap();
            println!("forward {role:<13} ctx={ctx:<4} {t:>9.3} ms (on engine thread)");
        }
    }
    // Channel tax: same forward via the RemoteModel proxy.
    let m = host.model(2);
    let ctx: Vec<i32> = (0..64).map(|i| i % 256).collect();
    let _ = m.forward(&ctx);
    let t0 = Instant::now();
    let iters = 20;
    for _ in 0..iters {
        let _ = m.forward(&ctx).unwrap();
    }
    let via_proxy = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let direct = host.measure_cost_ms(2, 64, iters).unwrap();
    println!(
        "\nRemoteModel channel tax: {:.3} ms (proxy {via_proxy:.3} - direct {direct:.3})",
        via_proxy - direct
    );
    // Session protocol vs stateless proxy forwards: decode 16 tokens with
    // the draft engine both ways (suffix-only payloads vs full-context).
    let mut sess = m.open_session().unwrap();
    sess.append(&ctx).unwrap();
    let t0 = Instant::now();
    for i in 0..16 {
        sess.append(&[(i % 256) as i32]).unwrap();
        std::hint::black_box(sess.row(sess.len() - 1));
    }
    let per_append = t0.elapsed().as_secs_f64() * 1e3 / 16.0;
    let mut full = ctx.clone();
    let t0 = Instant::now();
    for i in 0..16 {
        full.push((i % 256) as i32);
        let logits = m.forward(&full).unwrap();
        std::hint::black_box(logits.row(full.len() - 1));
    }
    let per_forward = t0.elapsed().as_secs_f64() * 1e3 / 16.0;
    println!(
        "session append vs stateless forward (draft, ctx 64+): {per_append:.3} ms vs {per_forward:.3} ms/token"
    );
}
