//! Hot-path micro-benchmarks (the §Perf profiling instrument):
//!   * per-forward engine cost per chain member (T_i) + dispatch overhead
//!   * RemoteModel channel round-trip tax
//!   * sampler / verifier / softmax costs per decode event
//!
//!   cargo bench --bench micro_hotpath

use std::time::Instant;

use polyspec::harness::artifacts_dir;
use polyspec::runtime::EngineHost;
use polyspec::spec::rng::Pcg32;
use polyspec::spec::sampler;
use polyspec::spec::types::{softmax, LanguageModel, VerifyRule};
use polyspec::spec::verify;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "us")
    };
    println!("{name:<44} {val:>9.3} {unit}/iter  ({iters} iters)");
    per
}

fn main() {
    println!("== micro: CPU-side decode-event costs ==");
    let vocab = 256;
    let mut rng = Pcg32::seeded(1);
    let logits: Vec<f32> = (0..vocab).map(|i| ((i * 37 % 97) as f32) / 17.0).collect();

    bench("softmax(256) + temperature", 20_000, || {
        let p = softmax(&logits, 0.8);
        std::hint::black_box(p);
    });
    let probs = softmax(&logits, 1.0);
    bench("categorical sample(256)", 20_000, || {
        std::hint::black_box(sampler::sample_categorical(&probs, &mut rng));
    });
    bench("residual + resample (rejection path)", 20_000, || {
        let r = sampler::residual(&probs, &probs.iter().rev().copied().collect::<Vec<_>>());
        std::hint::black_box(r);
    });
    let p_rows: Vec<Vec<f32>> = (0..8).map(|_| probs.clone()).collect();
    let q_rows = p_rows.clone();
    let toks: Vec<i32> = (0..8).collect();
    bench("verify_block(8 tokens, speculative)", 20_000, || {
        let v = verify::verify_block(&toks, &p_rows, &q_rows, VerifyRule::Speculative, &mut rng);
        std::hint::black_box(v);
    });

    println!("\n== micro: engine forward costs (requires artifacts) ==");
    let artifacts = artifacts_dir();
    let host = match EngineHost::load(&artifacts, "v7b", &["target", "intermediate", "draft"]) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("skipping engine micro-benches: {e:#}");
            return;
        }
    };
    for (i, role) in ["target", "intermediate", "draft"].iter().enumerate() {
        for ctx in [16usize, 64, 128] {
            let t = host.measure_cost_ms(i, ctx, 5).unwrap();
            println!("forward {role:<13} ctx={ctx:<4} {t:>9.3} ms (on engine thread)");
        }
    }
    // Channel tax: same forward via the RemoteModel proxy.
    let m = host.model(2);
    let ctx: Vec<i32> = (0..64).map(|i| i % 256).collect();
    let _ = m.forward(&ctx);
    let t0 = Instant::now();
    let iters = 20;
    for _ in 0..iters {
        let _ = m.forward(&ctx).unwrap();
    }
    let via_proxy = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let direct = host.measure_cost_ms(2, 64, iters).unwrap();
    println!(
        "\nRemoteModel channel tax: {:.3} ms (proxy {via_proxy:.3} - direct {direct:.3})",
        via_proxy - direct
    );
}
