//! Paper Figure 4 + Theorem 3.3: acceptance-length variance under
//! speculative vs greedy verification, over 50 queries on the three-model
//! system, with the closed-form variance overlaid.
//!
//!   cargo bench --bench fig4_variance

use polyspec::harness::{artifacts_dir, hr, load_chain, run_cell, DEFAULT_POLY};
use polyspec::spec::stats::IntHistogram;
use polyspec::spec::theory::{accept_len_mean, accept_len_variance, thm33_variance_paper};
use polyspec::spec::types::VerifyRule;
use polyspec::workload::tasks::make_query;

fn main() {
    let artifacts = artifacts_dir();
    let family = std::env::var("POLYSPEC_FAMILY").unwrap_or_else(|_| "v7b".into());
    let host = match load_chain(&artifacts, &family) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("artifacts missing for {family}: {e:#}");
            return;
        }
    };
    let chain = host.chain();
    let vocab = chain[0].vocab();
    let n_queries: usize = std::env::var("POLYSPEC_FIG4_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);

    // 50 queries mixed across tasks, exactly the paper's §4.5 protocol.
    let queries: Vec<_> = (0..n_queries)
        .map(|i| {
            let task = polyspec::workload::ALL_TASKS[i % 6];
            let mut q = make_query(task, (i / 6) as u64, vocab);
            q.max_new = q.max_new.min(32);
            q
        })
        .collect();

    println!("== Figure 4: acceptance-length distribution over {n_queries} queries ==\n");
    let mut rows = Vec::new();
    for (label, rule) in
        [("speculative", VerifyRule::Speculative), ("greedy", VerifyRule::Greedy)]
    {
        let cell = run_cell(&chain, &queries, DEFAULT_POLY, rule).expect("cell");
        let mut hist = IntHistogram::new(16);
        for &a in &cell.accept_samples {
            hist.push(a as usize);
        }
        let mean = cell.accept.mean();
        let var = cell.accept.variance();
        println!("--- {label} verification ---");
        println!("{}", hist.ascii(40));
        println!(
            "mean = {mean:.2}   variance = {var:.2}   cv = {:.3}\n",
            var.sqrt() / mean.max(1e-9)
        );
        rows.push((label, mean, var, var.sqrt() / mean.max(1e-9)));
    }

    let head = format!("{:<14} {:>8} {:>10} {:>8}", "verification", "mean", "variance", "cv");
    println!("{head}");
    println!("{}", hr(head.len()));
    for (label, mean, var, cv) in &rows {
        println!("{:<14} {:>8.2} {:>10.2} {:>8.3}", label, mean, var, cv);
    }
    let (_, _, v_spec, cv_spec) = ("", rows[0].1, rows[0].2, rows[0].3);
    let (_, _, v_greedy, cv_greedy) = ("", rows[1].1, rows[1].2, rows[1].3);
    println!(
        "\nspeculative is more stable: variance {:.2} vs {:.2}, cv {:.3} vs {:.3} -> {}",
        v_spec, v_greedy, cv_spec, cv_greedy,
        if cv_spec < cv_greedy { "matches the paper (Fig 4 / Thm 3.3)" } else { "UNEXPECTED" }
    );

    // ---- Theorem 3.3 overlay -----------------------------------------------
    // Estimate the per-token acceptance probability from the speculative run
    // and compare the closed-form (exact-pmf) moments against measurement.
    let mean_spec = rows[0].1;
    let n = 14usize; // pipeline block bound for DEFAULT_POLY (draft_k=6, mu=8)
    // Invert E[N] ~= p(1-p^n)/(1-p) numerically for p-hat. The committed
    // count per target forward includes the replacement/bonus token, so the
    // geometric "accept" count is mean-1.
    let observed = (mean_spec - 1.0).max(0.0);
    let mut p_hat = 0.5;
    for _ in 0..60 {
        let f = accept_len_mean(p_hat, n) - observed;
        if f.abs() < 1e-10 {
            break;
        }
        p_hat -= f * 0.02;
        p_hat = p_hat.clamp(0.001, 0.999);
    }
    println!("\n== Theorem 3.3 overlay (truncated-geometric model, n={n}) ==");
    println!("p-hat (from mean accept) = {p_hat:.3}");
    println!("exact-pmf variance       = {:.2}", accept_len_variance(p_hat, n));
    println!("paper printed formula    = {:.2}  (alpha = {:.3})",
             thm33_variance_paper(1.0 - p_hat, n), 1.0 - p_hat);
    println!("measured variance        = {:.2}", rows[0].2);
    println!("(see EXPERIMENTS.md §Theory for the printed-formula discrepancy)");
}
