//! Paper Table 1 / §4.2 "Theoretical Validation": Theorem 3.2's insertion
//! criterion checked against measured end-to-end speedups.
//!
//!   Case 1 (non-compliant): insert an *uncorrelated* model (the `decoy`
//!           role — our Vicuna-1B stand-in) between target and drafter.
//!           Criterion fails -> measured speedup must drop.
//!   Case 2 (compliant): insert the W4-quantized early-exit `intermediate`
//!           (the paper's quantized Vicuna-7B). Criterion holds -> speedup
//!           must improve.
//!   Case 3 (CS Drafting): same check on a CS-Drafting cascade whose lowest
//!           tier is the statistical bigram drafter.
//!
//!   cargo bench --bench table1_insertion

use std::sync::Arc;

use polyspec::harness::{artifacts_dir, hr, queries_per_task, run_cell, BenchMethod};
use polyspec::runtime::EngineHost;
use polyspec::spec::csdraft::{self, CsDraftConfig};
use polyspec::spec::ngram::BigramModel;
use polyspec::spec::planner::measure_pair_acceptance;
use polyspec::spec::theory::InsertionCheck;
use polyspec::spec::types::{LanguageModel, SamplingParams, VerifyRule};
use polyspec::spec::{polybasic, PolyConfig};
use polyspec::workload::tasks::{make_query, TaskKind};

fn main() {
    let artifacts = artifacts_dir();
    let family = std::env::var("POLYSPEC_FAMILY").unwrap_or_else(|_| "v7b".into());
    let host = match EngineHost::load(&artifacts, &family, &["target", "intermediate", "draft", "decoy"])
    {
        Ok(h) => h,
        Err(e) => {
            eprintln!("need target/intermediate/draft/decoy artifacts for {family}: {e:#}");
            return;
        }
    };
    let target = host.model(0) as Arc<dyn LanguageModel>;
    let inter = host.model(1) as Arc<dyn LanguageModel>;
    let draft = host.model(2) as Arc<dyn LanguageModel>;
    let decoy = host.model(3) as Arc<dyn LanguageModel>;

    // ---- measured per-forward costs (T_i, ms) -----------------------------
    let t_target = host.measure_cost_ms(0, 100, 5).unwrap();
    let t_inter = host.measure_cost_ms(1, 100, 5).unwrap();
    let t_draft = host.measure_cost_ms(2, 100, 5).unwrap();
    let t_decoy = host.measure_cost_ms(3, 100, 5).unwrap();
    println!("== measured per-forward costs (ms) ==");
    println!(
        "T_target={t_target:.2}  T_int={t_inter:.2}  T_draft={t_draft:.2}  T_decoy={t_decoy:.2}\n"
    );

    // ---- pairwise acceptance lengths (L) ----------------------------------
    let vocab = target.vocab();
    let probes: Vec<Vec<i32>> = (0..3)
        .map(|i| make_query(TaskKind::Qa, i, vocab).prompt)
        .collect();
    let sampling = SamplingParams::default();
    let l =
        |ver: &Arc<dyn LanguageModel>, prop: &Arc<dyn LanguageModel>| -> f64 {
            // draft_k must exceed the expected acceptance length or the
            // probe saturates at k+1 and understates L for strong pairs.
            measure_pair_acceptance(ver.clone(), prop.clone(), &probes, 10, 40, sampling)
                .expect("acceptance probe")
        };
    let l_target_draft = l(&target, &draft); // L_i (current pair)
    let l_target_inter = l(&target, &inter); // L_{i-new}, compliant
    let l_inter_draft = l(&inter, &draft); // L_new, compliant
    let l_target_decoy = l(&target, &decoy); // L_{i-new}, non-compliant
    let l_decoy_draft = l(&decoy, &draft); // L_new, non-compliant

    // ---- measured end-to-end speedups -------------------------------------
    let qpt = queries_per_task().max(2);
    let queries: Vec<_> = (0..qpt).map(|i| make_query(TaskKind::MultiTurn, i as u64, vocab)).collect();
    let two_chain = vec![target.clone(), draft.clone()];
    let dec_chain = vec![target.clone(), decoy.clone(), draft.clone()];
    let int_chain = vec![target.clone(), inter.clone(), draft.clone()];

    let vanilla = run_cell(&two_chain, &queries, BenchMethod::Vanilla, VerifyRule::Speculative)
        .unwrap();
    let base = run_cell(&two_chain, &queries, BenchMethod::Eagle { draft_k: 4 },
                        VerifyRule::Speculative).unwrap();
    let poly = |chain: &[Arc<dyn LanguageModel>]| {
        let mut total = 0.0;
        let mut n = 0u64;
        for (i, q) in queries.iter().enumerate() {
            let mut cfg = PolyConfig::for_chain(3, 6, 8, q.max_new);
            cfg.sampling =
                SamplingParams { temperature: q.temperature, seed: 2000 + i as u64, ..Default::default() };
            let t0 = std::time::Instant::now();
            let out = polybasic::generate(chain, &q.prompt, &cfg).unwrap();
            total += t0.elapsed().as_secs_f64();
            n += out.tokens.len() as u64;
        }
        (total, n)
    };
    let (decoy_wall, _) = poly(&dec_chain);
    let (int_wall, _) = poly(&int_chain);

    let c_base = vanilla.wall_s / base.wall_s;
    let c_decoy = vanilla.wall_s / decoy_wall;
    let c_int = vanilla.wall_s / int_wall;

    // ---- Theorem 3.2 verdicts ---------------------------------------------
    let beta = 1.0;
    let noncompliant = InsertionCheck {
        t_i: t_target, t_new: t_decoy, t_next: t_draft,
        l_i: l_target_draft, l_i_new: l_target_decoy, l_new: l_decoy_draft, beta,
    }
    .evaluate();
    let compliant = InsertionCheck {
        t_i: t_target, t_new: t_inter, t_next: t_draft,
        l_i: l_target_draft, l_i_new: l_target_inter, l_new: l_inter_draft, beta,
    }
    .evaluate();

    println!("== Table 1: Theoretical Validation via Model Insertion ==");
    let head = format!(
        "{:<14} {:>7} {:>8} {:>8} {:>7} {:>8} {:>6} | {:>18} | {:>9} {:>9}",
        "Case", "T_i", "L_i-new", "T_new", "L_new", "T_i+1", "L_i", "Speedup", "Thm3.2", "Agrees?"
    );
    println!("{head}");
    println!("{}", hr(head.len()));
    let row = |case: &str, t_new: f64, l_i_new: f64, l_new: f64, c_to: f64,
               verdict: &polyspec::spec::theory::InsertionVerdict| {
        let predicted = verdict.predicts_improvement();
        let actual = c_to > c_base;
        println!(
            "{:<14} {:>7.2} {:>8.2} {:>8.2} {:>7.2} {:>8.2} {:>6.2} | {:>7.2}x -> {:>6.2}x | {:>9} {:>9}",
            case, t_target, l_i_new, t_new, l_new, t_draft, l_target_draft,
            c_base, c_to,
            if predicted { "improves" } else { "degrades" },
            if predicted == actual { "YES" } else { "NO" },
        );
        println!(
            "{:<14}   cond1: {:.3} < {:.3} ? {}   cond2: {:.3} < {:.3} ? {}",
            "", verdict.cond1_lhs, verdict.cond1_rhs, verdict.cond1,
            verdict.cond2_lhs, verdict.cond2_rhs, verdict.cond2
        );
    };
    row("Non-compliant", t_decoy, l_target_decoy, l_decoy_draft, c_decoy, &noncompliant);
    row("Compliant", t_inter, l_target_inter, l_inter_draft, c_int, &compliant);

    // ---- Case 3: CS Drafting cascade ---------------------------------------
    let bigram: Arc<dyn LanguageModel> = Arc::new(BigramModel::new(target.seq_len(), vocab));
    let cs_base_models = vec![target.clone(), draft.clone(), bigram.clone()];
    let cs_ins_models = vec![target.clone(), inter.clone(), draft.clone(), bigram.clone()];
    let run_cs = |models: &[Arc<dyn LanguageModel>], lens: Vec<usize>| -> f64 {
        let mut wall = 0.0;
        for (i, q) in queries.iter().enumerate() {
            let cfg = CsDraftConfig {
                lens: lens.clone(),
                rule: VerifyRule::Speculative,
                sampling: SamplingParams {
                    temperature: q.temperature, seed: 3000 + i as u64, ..Default::default()
                },
                max_new: q.max_new,
            };
            let t0 = std::time::Instant::now();
            csdraft::generate(models, &q.prompt, &cfg).unwrap();
            wall += t0.elapsed().as_secs_f64();
        }
        wall
    };
    let cs_base_wall = run_cs(&cs_base_models, vec![4, 2]);
    let cs_ins_wall = run_cs(&cs_ins_models, vec![2, 3, 2]);
    let c_cs_base = vanilla.wall_s / cs_base_wall;
    let c_cs_ins = vanilla.wall_s / cs_ins_wall;
    let cs_check = InsertionCheck {
        t_i: t_target, t_new: t_inter, t_next: t_draft,
        l_i: l_target_draft, l_i_new: l_target_inter, l_new: l_inter_draft, beta,
    }
    .evaluate();
    println!(
        "{:<14} {:>7.2} {:>8.2} {:>8.2} {:>7.2} {:>8.2} {:>6.2} | {:>7.2}x -> {:>6.2}x | {:>9} {:>9}",
        "CS Drafting", t_target, l_target_inter, t_inter, l_inter_draft, t_draft,
        l_target_draft, c_cs_base, c_cs_ins,
        if cs_check.predicts_improvement() { "improves" } else { "degrades" },
        if cs_check.predicts_improvement() == (c_cs_ins > c_cs_base) { "YES" } else { "NO" },
    );
    println!("\n(paper shape: non-compliant insertion degrades, compliant and CS");
    println!(" insertions improve, and Thm 3.2's verdict agrees with measurement)");
}
