//! Paper Table 3: scaling to larger models (Vicuna-13B / LLaMA-2-70B tiers).
//! Needs the scale-tier artifacts: `make artifacts ARTIFACT_SET=all`.
//!
//!   cargo bench --bench table3_scaling

use polyspec::harness::{
    artifacts_dir, bench_families, hr, load_chain, queries_per_task, run_cell, BenchMethod,
    DEFAULT_EAGLE, DEFAULT_POLY,
};
use polyspec::spec::types::VerifyRule;
use polyspec::workload::specbench_suite;

fn main() {
    let families = bench_families(&["v13b", "l2-70b"]);
    if families.is_empty() {
        eprintln!("scale-tier artifacts missing; run `make artifacts ARTIFACT_SET=all`");
        return;
    }
    let qpt = queries_per_task();
    let artifacts = artifacts_dir();

    println!("== Table 3: speedup ratios and acceptance lengths on larger models ==\n");
    let head = format!("{:<8} {:<10} {:>7} {:>7}", "Method", "Model", "c", "mu");
    println!("{head}");
    println!("{}", hr(head.len()));

    for family in &families {
        let host = match load_chain(&artifacts, family) {
            Ok(h) => h,
            Err(e) => {
                eprintln!("skipping {family}: {e:#}");
                continue;
            }
        };
        let chain = host.chain();
        let queries = specbench_suite(qpt, chain[0].vocab());
        let vanilla =
            run_cell(&chain, &queries, BenchMethod::Vanilla, VerifyRule::Speculative).unwrap();
        for (label, method) in [("Our", DEFAULT_POLY), ("EAGLE*", DEFAULT_EAGLE)] {
            let cell = run_cell(&chain, &queries, method, VerifyRule::Speculative).unwrap();
            println!(
                "{:<8} {:<10} {:>6.2}x {:>7.2}",
                label,
                family,
                vanilla.wall_s / cell.wall_s.max(1e-12),
                cell.mu()
            );
        }
    }
    println!("\n(paper shape: speedups persist at larger scale with slightly");
    println!(" lower c than the 7B tier; Our mu stays ~2x EAGLE's)");
}
