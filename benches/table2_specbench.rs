//! Paper Table 2 + Figures 2 & 3: per-task speedup `c` and acceptance
//! length `μ` for each model family, ours vs the EAGLE2-like dualistic
//! baseline (vanilla autoregressive is the speedup denominator).
//!
//!   cargo bench --bench table2_specbench
//!
//! Env knobs: POLYSPEC_FAMILIES=v7b,l2-7b,...  POLYSPEC_QPT=<queries/task>
//! (table-2 families need `make artifacts ARTIFACT_SET=bench`).

use polyspec::harness::{
    artifacts_dir, bench_families, hr, load_chain, queries_per_task, run_cell, BenchMethod,
    Cell, DEFAULT_EAGLE, DEFAULT_POLY,
};
use polyspec::spec::types::VerifyRule;
use polyspec::workload::tasks::ALL_TASKS;
use polyspec::workload::task_queries;

fn main() {
    let families = bench_families(&["v7b", "l2-7b", "l3-8b", "q2-7b"]);
    if families.is_empty() {
        eprintln!("no families available; run `make artifacts ARTIFACT_SET=bench`");
        return;
    }
    let qpt = queries_per_task();
    let artifacts = artifacts_dir();
    println!("== Table 2: average acceptance length (mu) and speedup (c) per task ==");
    println!("   ({} queries/task; vanilla autoregressive = 1.00x)\n", qpt);

    let methods: [(&str, Option<BenchMethod>); 3] =
        [("Our", Some(DEFAULT_POLY)), ("EAGLE2*", Some(DEFAULT_EAGLE)), ("vanilla", None)];

    let mut header = format!("{:<8} {:<8}", "Method", "Model");
    for t in ALL_TASKS {
        header.push_str(&format!(" | {:>5}c {:>5}mu", t.label(), ""));
    }
    header.push_str(" | Overall c  mu");
    println!("{header}");
    println!("{}", hr(header.len()));

    for family in &families {
        let host = match load_chain(&artifacts, family) {
            Ok(h) => h,
            Err(e) => {
                eprintln!("skipping {family}: {e:#}");
                continue;
            }
        };
        let chain = host.chain();
        let vocab = chain[0].vocab();

        // Vanilla walls per task are the speedup denominators.
        let mut vanilla: Vec<Cell> = Vec::new();
        for task in ALL_TASKS {
            let queries = task_queries(task, qpt, vocab);
            vanilla.push(
                run_cell(&chain, &queries, BenchMethod::Vanilla, VerifyRule::Speculative)
                    .expect("vanilla cell"),
            );
        }

        for (label, method) in &methods {
            let mut row = format!("{:<8} {:<8}", label, family);
            let mut total_wall = 0.0;
            let mut total_vanilla = 0.0;
            let mut mu_acc = polyspec::spec::stats::Welford::default();
            for (ti, task) in ALL_TASKS.iter().enumerate() {
                let queries = task_queries(*task, qpt, vocab);
                let cell = match method {
                    Some(m) => {
                        run_cell(&chain, &queries, *m, VerifyRule::Speculative).expect("cell")
                    }
                    None => vanilla[ti].clone(),
                };
                let c = vanilla[ti].wall_s / cell.wall_s.max(1e-12);
                row.push_str(&format!(" | {:>5.2}x {:>5.2}", c, cell.mu()));
                total_wall += cell.wall_s;
                total_vanilla += vanilla[ti].wall_s;
                mu_acc.merge(&cell.accept);
            }
            row.push_str(&format!(
                " | {:>7.2}x {:>5.2}",
                total_vanilla / total_wall.max(1e-12),
                mu_acc.mean()
            ));
            println!("{row}");
        }
        println!("{}", hr(header.len()));
    }

    println!("\n== Figure 2 (overall speedup bars) and Figure 3 (per-task) ==");
    println!("   are the Overall column / per-task columns of the rows above.");
    println!("   Expected shape: Our > EAGLE2* > vanilla on every family; math");
    println!("   and multi-turn highest, summarization/RAG lowest (paper §4.3).");
}
