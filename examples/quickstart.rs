//! Quickstart: load the v7b chain, decode a prompt with polybasic
//! speculative decoding, and compare against vanilla autoregressive.
//!
//!   make artifacts && cargo run --release --example quickstart

use polyspec::runtime::EngineHost;
use polyspec::spec::types::{SamplingParams, VerifyRule};
use polyspec::spec::{autoregressive, polybasic, PolyConfig};
use polyspec::workload::tokenizer;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT-compiled chain: target / W4 intermediate / draft.
    //    Python never runs here — artifacts/ were built once by `make`.
    let host = EngineHost::load("artifacts", "v7b", &["target", "intermediate", "draft"])?;
    let chain = host.chain();
    println!("chain loaded:");
    for m in host.metas() {
        println!(
            "  {:<12} layers={:<2} d_model={:<4} params={}",
            m.name, m.n_layers, m.d_model, m.param_count
        );
    }

    // 2. Encode a prompt (byte-level tokenizer over the synthetic vocab).
    let prompt = tokenizer::encode("Q: what makes polybasic decoding fast? A:", chain[0].vocab());
    let max_new = 48;

    // 3. Vanilla decode (the baseline).
    let sampling = SamplingParams { temperature: 0.8, seed: 7, ..Default::default() };
    let ar = autoregressive::generate(chain[0].as_ref(), &prompt, max_new, &sampling)?;
    println!(
        "\nvanilla:   {:>7.1} ms  ({} target forwards)",
        ar.wall.as_secs_f64() * 1e3,
        ar.forward_passes[0]
    );

    // 4. Polybasic decode: M3 drafts, M2 filters, M1 verifies blocks.
    let mut cfg = PolyConfig::for_chain(chain.len(), 6, 8, max_new);
    cfg.rule = VerifyRule::Speculative;
    cfg.sampling = sampling;
    let out = polybasic::generate(&chain, &prompt, &cfg)?;
    println!(
        "polybasic: {:>7.1} ms  ({} target forwards, mu = {:.2})",
        out.wall.as_secs_f64() * 1e3,
        out.forward_passes[0],
        out.mean_accept()
    );
    println!(
        "speedup:   {:>7.2}x",
        ar.wall.as_secs_f64() / out.wall.as_secs_f64()
    );
    println!("\noutput tokens ({}): {:?}", out.tokens.len(), &out.tokens[..12.min(out.tokens.len())]);
    println!("as text: {:?}", tokenizer::decode(&out.tokens));
    Ok(())
}
