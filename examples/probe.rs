//! Dev probe: per-model costs + (draft_k, mu) parameter sweep for the
//! polybasic chain. Used during the perf pass (EXPERIMENTS.md §Perf).
use polyspec::runtime::EngineHost;
use polyspec::spec::{polybasic, PolyConfig, autoregressive, dualistic};
use polyspec::spec::types::SamplingParams;

fn main() {
    let fam = std::env::var("POLYSPEC_FAMILY").unwrap_or_else(|_| "v7b".into());
    let host = EngineHost::load("artifacts", &fam, &["target", "intermediate", "draft"]).unwrap();
    for (i, name) in ["target", "int", "draft"].iter().enumerate() {
        println!("{name}: {:.3} ms/fwd", host.measure_cost_ms(i, 100, 8).unwrap());
    }
    let chain = host.chain();
    let prompt: Vec<i32> = (0..24).collect();
    let n = 64;
    let sampling = SamplingParams { temperature: 0.8, seed: 3, ..Default::default() };
    let mut ar_wall = 0.0;
    for s in 0..2 {
        let sp = SamplingParams { seed: s, ..sampling };
        ar_wall += autoregressive::generate(chain[0].as_ref(), &prompt, n, &sp).unwrap().wall.as_secs_f64();
    }
    println!("AR: {:.0} ms/run", ar_wall / 2.0 * 1e3);
    for k in [4usize, 6, 8] {
        let cfg = dualistic::DualisticConfig { draft_k: k, rule: polyspec::spec::VerifyRule::Speculative, sampling, max_new: n };
        let mut w = 0.0; let mut mu = 0.0;
        for s in 0..2 {
            let mut c = cfg; c.sampling.seed = s;
            let out = dualistic::generate(chain[0].as_ref(), chain[2].as_ref(), &prompt, &c).unwrap();
            w += out.wall.as_secs_f64(); mu += out.mean_accept();
        }
        println!("dual k={k}: {:.2}x mu={:.2}", ar_wall / w, mu / 2.0);
    }
    for k in [4usize, 6, 8, 10] {
        for mu in [4usize, 6, 8, 10, 12] {
            let mut w = 0.0; let mut mu_m = 0.0; let mut fwds = vec![0u64; 3];
            for s in 0..2 {
                let mut cfg = PolyConfig::for_chain(3, k, mu, n);
                cfg.sampling = SamplingParams { seed: s, ..sampling };
                let out = polybasic::generate(&chain, &prompt, &cfg).unwrap();
                w += out.wall.as_secs_f64(); mu_m += out.mean_accept();
                for i in 0..3 { fwds[i] += out.forward_passes[i]; }
            }
            println!("poly k={k:<2} mu={mu:<2}: {:.2}x mu={:.2} fwds={:?}", ar_wall / w, mu_m / 2.0, fwds);
        }
    }
}
