//! End-to-end serving driver (the repository's E2E validation run):
//! start the coordinator, replay a Poisson arrival stream of SpecBench
//! queries against the polybasic chain, and report latency/throughput —
//! the full L3 -> runtime -> AOT-kernel stack under load.
//!
//!   make artifacts && cargo run --release --example serve_specbench
//!
//! Env: POLYSPEC_RATE (req/s, default 2), POLYSPEC_REQUESTS (default 24),
//!      POLYSPEC_METHOD (poly|dual|vanilla), POLYSPEC_WORKERS (default 1).

use std::time::{Duration, Instant};

use polyspec::coordinator::{Method, Server, ServerConfig};
use polyspec::spec::stats::Welford;
use polyspec::workload::ArrivalStream;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let rate: f64 = env_or("POLYSPEC_RATE", 2.0);
    let n_requests: usize = env_or("POLYSPEC_REQUESTS", 24);
    let workers: usize = env_or("POLYSPEC_WORKERS", 1);
    let method = match std::env::var("POLYSPEC_METHOD").as_deref() {
        Ok("vanilla") => Method::Autoregressive,
        Ok("dual") => Method::Dualistic { draft_k: 4 },
        _ => Method::Polybasic { draft_k: 6, mu: 8 },
    };

    println!("starting server: family=v7b workers={workers} method={}", method.label());
    let mut cfg = ServerConfig::new("artifacts", "v7b");
    cfg.workers = workers;
    let server = Server::start(cfg)?;
    println!("server up (context window {})", server.seq_len());

    let vocab = 256;
    let arrivals: Vec<_> = ArrivalStream::new(rate, vocab, 42).take(n_requests).collect();
    let start = Instant::now();
    let mut receivers = Vec::new();
    let mut rejected = 0usize;

    for a in arrivals {
        // Open-loop load generation: honor the arrival timestamps.
        if let Some(wait) = a.at.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        match server.submit(a.query.prompt.clone(), a.query.max_new, method, Some(a.query.task)) {
            Ok(rx) => receivers.push((a.query.task, rx)),
            Err(e) => {
                rejected += 1;
                eprintln!("rejected: {e}");
            }
        }
    }

    let mut e2e = Welford::default();
    let mut tokens = 0usize;
    let mut mu = Welford::default();
    let mut failed = 0usize;
    let mut completed = 0usize;
    for (_, rx) in &receivers {
        // The final channel carries Result<Response, DecodeError>: a decode
        // failure arrives as a typed value (timeout / engine lost /
        // saturated / internal), not a channel close.
        let resp = match rx.recv_timeout(Duration::from_secs(600))? {
            Ok(resp) => resp,
            Err(e) => {
                failed += 1;
                eprintln!("failed: {e}");
                continue;
            }
        };
        completed += 1;
        e2e.push((resp.queue_time + resp.service_time).as_secs_f64() * 1e3);
        tokens += resp.tokens.len();
        if resp.mean_accept > 0.0 {
            mu.push(resp.mean_accept);
        }
    }
    let wall = start.elapsed();

    println!("\n== serve_specbench report ==");
    println!("requests: {completed} completed, {failed} failed, {rejected} rejected");
    println!("wall time: {:.2}s  offered rate: {rate}/s", wall.as_secs_f64());
    println!("throughput: {:.1} tok/s  ({tokens} tokens)", tokens as f64 / wall.as_secs_f64());
    println!("e2e latency: mean {:.0} ms (n={})", e2e.mean(), e2e.count());
    println!("mean acceptance length: {:.2}", mu.mean());
    println!("KV pool utilization now: {:.1}%", server.kv_utilization() * 100.0);

    let metrics = server.shutdown();
    println!("\nmetrics snapshot:\n{}", metrics.snapshot());
    Ok(())
}
