//! End-to-end serving driver (the repository's E2E validation run):
//! start the coordinator, replay a Poisson arrival stream of SpecBench
//! queries — or a multi-turn conversation stream whose nested prompts
//! exercise the paged-KV radix prefix cache — against the polybasic chain,
//! and report latency/throughput. Writes a machine-readable
//! `BENCH_serve.json` (throughput, TTFT, prefix-hit rate, restore cost,
//! coalesced engine calls per committed token, and the KV-cache
//! recompute-avoided ratio) next to the working directory for CI trend
//! tracking.
//!
//!   make artifacts && cargo run --release --example serve_specbench
//!
//! Env: POLYSPEC_RATE (req/s, default 2), POLYSPEC_REQUESTS (default 24),
//!      POLYSPEC_METHOD (poly|dual|vanilla), POLYSPEC_WORKERS (default 1),
//!      POLYSPEC_MULTITURN (1 = conversation stream with shared prefixes).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use polyspec::coordinator::{Method, Server, ServerConfig};
use polyspec::runtime::json::Json;
use polyspec::spec::stats::Welford;
use polyspec::workload::{ArrivalStream, ConversationStream, Query};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let rate: f64 = env_or("POLYSPEC_RATE", 2.0);
    let n_requests: usize = env_or("POLYSPEC_REQUESTS", 24);
    let workers: usize = env_or("POLYSPEC_WORKERS", 1);
    let multiturn: usize = env_or("POLYSPEC_MULTITURN", 0);
    let method = match std::env::var("POLYSPEC_METHOD").as_deref() {
        Ok("vanilla") => Method::Autoregressive,
        Ok("dual") => Method::Dualistic { draft_k: 4 },
        _ => Method::Polybasic { draft_k: 6, mu: 8 },
    };

    println!("starting server: family=v7b workers={workers} method={}", method.label());
    let mut cfg = ServerConfig::new("artifacts", "v7b");
    cfg.workers = workers;
    let server = Server::start(cfg)?;
    println!("server up (context window {})", server.seq_len());

    let vocab = 256;
    // Either independent SpecBench queries (default) or multi-turn
    // conversations, where each follow-up's prompt extends the previous
    // turn's transcript — the workload the radix prefix cache serves from
    // shared blocks instead of fresh allocations.
    let arrivals: Vec<(Duration, Query)> = if multiturn != 0 {
        // Size transcript caps to the serving window: a follow-up prompt can
        // reach max_prompt + 24 chunk tokens and still needs output budget
        // plus speculative headroom inside seq_len to clear admission.
        let max_prompt = server.seq_len().saturating_sub(96).max(48);
        ConversationStream::new(rate, vocab, 42)
            .with_caps(max_prompt, 4)
            .take(n_requests)
            .map(|a| (a.at, a.query))
            .collect()
    } else {
        ArrivalStream::new(rate, vocab, 42).take(n_requests).map(|a| (a.at, a.query)).collect()
    };
    let prompt_tokens: usize = arrivals.iter().map(|(_, q)| q.prompt.len()).sum();
    let start = Instant::now();
    let mut receivers = Vec::new();
    let mut rejected = 0usize;

    for (at, query) in arrivals {
        // Open-loop load generation: honor the arrival timestamps.
        if let Some(wait) = at.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        match server.submit(query.prompt.clone(), query.max_new, method, Some(query.task)) {
            Ok(rx) => receivers.push(rx),
            Err(e) => {
                rejected += 1;
                eprintln!("rejected: {e}");
            }
        }
    }

    let mut e2e = Welford::default();
    let mut ttft = Welford::default();
    let mut tokens = 0usize;
    let mut mu = Welford::default();
    let mut failed = 0usize;
    let mut completed = 0usize;
    for rx in &receivers {
        // The final channel carries Result<Response, DecodeError>: a decode
        // failure arrives as a typed value (timeout / engine lost /
        // saturated / internal), not a channel close.
        let resp = match rx.recv_timeout(Duration::from_secs(600))? {
            Ok(resp) => resp,
            Err(e) => {
                failed += 1;
                eprintln!("failed: {e}");
                continue;
            }
        };
        completed += 1;
        e2e.push((resp.queue_time + resp.service_time).as_secs_f64() * 1e3);
        if let Some(t) = resp.ttft {
            ttft.push(t.as_secs_f64() * 1e3);
        }
        tokens += resp.tokens.len();
        if resp.mean_accept > 0.0 {
            mu.push(resp.mean_accept);
        }
    }
    let wall = start.elapsed();
    let throughput = tokens as f64 / wall.as_secs_f64();

    println!("\n== serve_specbench report ==");
    println!("requests: {completed} completed, {failed} failed, {rejected} rejected");
    println!("wall time: {:.2}s  offered rate: {rate}/s", wall.as_secs_f64());
    println!("throughput: {throughput:.1} tok/s  ({tokens} tokens)");
    println!("e2e latency: mean {:.0} ms (n={})", e2e.mean(), e2e.count());
    println!("ttft: mean {:.0} ms (n={})", ttft.mean(), ttft.count());
    println!("mean acceptance length: {:.2}", mu.mean());
    println!("KV pool utilization now: {:.1}%", server.kv_utilization() * 100.0);

    let metrics = server.shutdown();
    let snapshot = metrics.snapshot();
    println!("\nmetrics snapshot:\n{snapshot}");

    // Machine-readable summary for CI trend tracking. Prefix-hit rate is
    // the fraction of offered prompt tokens the radix cache served from
    // already-resident blocks; restore cost contrasts the swap tier's
    // avoided recompute against what discard-path resumes re-scored.
    let ord = std::sync::atomic::Ordering::Relaxed;
    let hit_tokens = metrics.prefix_hit_tokens.load(ord) as f64;
    let hit_rate = if prompt_tokens > 0 { hit_tokens / prompt_tokens as f64 } else { 0.0 };
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    let mut put = |k: &str, v: Json| {
        report.insert(k.to_string(), v);
    };
    put("method", Json::Str(method.label().to_string()));
    put("multiturn", Json::Bool(multiturn != 0));
    put("offered_rate_per_s", Json::Num(rate));
    put("requests_completed", Json::Num(completed as f64));
    put("requests_failed", Json::Num(failed as f64));
    put("requests_rejected", Json::Num(rejected as f64));
    put("wall_s", Json::Num(wall.as_secs_f64()));
    put("throughput_tok_s", Json::Num(throughput));
    put("e2e_ms_mean", Json::Num(e2e.mean()));
    put("ttft_ms_mean", Json::Num(ttft.mean()));
    put("mean_accept", Json::Num(mu.mean()));
    put("prompt_tokens_offered", Json::Num(prompt_tokens as f64));
    put("prefix_hit_tokens", Json::Num(hit_tokens));
    put("prefix_hit_rate", Json::Num(hit_rate));
    // Cross-request batching: how many scheduler-coalesced engine calls
    // served the run, how many actually carried ≥ 2 sessions, and the
    // headline efficiency ratio — coalesced engine calls per committed
    // token (lower is better; 0 when nothing coalesced).
    let engine_calls = metrics.engine_calls.load(ord) as f64;
    put("engine_calls", Json::Num(engine_calls));
    put("batched_calls", Json::Num(metrics.batched_calls.load(ord) as f64));
    put("batch_mean_sessions", Json::Num(metrics.batch_occupancy.mean()));
    put(
        "engine_calls_per_token",
        Json::Num(engine_calls / (tokens.max(1) as f64)),
    );
    put("cow_splits", Json::Num(metrics.cow_splits.load(ord) as f64));
    put("swapped_blocks", Json::Num(metrics.swapped_blocks.load(ord) as f64));
    put(
        "restore_tokens_saved",
        Json::Num(metrics.restore_tokens_saved.load(ord) as f64),
    );
    put(
        "wasted_recompute_tokens",
        Json::Num(metrics.wasted_recompute_tokens.load(ord) as f64),
    );
    // KV-cached incremental scoring: suffix rows actually computed vs the
    // prefix rows the session caches spared from re-scoring. The ratio
    // `avoided / (avoided + computed)` is the headline O(suffix) win — a
    // stateless engine sits at 0, a warm cache near 1.
    let suffix_computed = metrics.suffix_tokens_computed.load(ord) as f64;
    let prefix_avoided = metrics.prefix_tokens_avoided.load(ord) as f64;
    put("suffix_tokens_computed", Json::Num(suffix_computed));
    put("prefix_tokens_avoided", Json::Num(prefix_avoided));
    put("recompute_avoided_ratio", Json::Num(metrics.recompute_avoided_ratio()));
    put(
        "cache_resident_tokens",
        Json::Num(metrics.cache_resident_tokens.load(ord) as f64),
    );
    put("metrics", snapshot);
    println!(
        "coalescing: {engine_calls:.0} engine calls ({:.0} batched, mean {:.2} sessions) \
         -> {:.3} calls/token",
        metrics.batched_calls.load(ord) as f64,
        metrics.batch_occupancy.mean(),
        engine_calls / (tokens.max(1) as f64),
    );
    println!(
        "kv cache: {suffix_computed:.0} suffix tokens computed, \
         {prefix_avoided:.0} prefix tokens avoided \
         -> {:.3} recompute avoided",
        metrics.recompute_avoided_ratio(),
    );
    let json = Json::Obj(report);
    std::fs::write("BENCH_serve.json", format!("{json}\n"))?;
    println!("\nwrote BENCH_serve.json");
    Ok(())
}
