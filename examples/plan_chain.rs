//! Theory-driven chain planning (paper §3.2 workflow): measure T_i and
//! pairwise acceptance lengths for every candidate, evaluate Theorem 3.2,
//! and print the chain the planner selects — including the decoy model it
//! must reject.
//!
//!   make artifacts && cargo run --release --example plan_chain

use std::sync::Arc;

use polyspec::runtime::EngineHost;
use polyspec::spec::planner::{plan_chain, ModelProfile};
use polyspec::spec::types::{LanguageModel, SamplingParams};
use polyspec::workload::tasks::make_query;

fn main() -> anyhow::Result<()> {
    let roles = ["target", "intermediate", "decoy", "draft"];
    let host = EngineHost::load("artifacts", "v7b", &roles)?;
    let models: Vec<Arc<dyn LanguageModel>> =
        (0..roles.len()).map(|i| host.model(i) as Arc<dyn LanguageModel>).collect();

    println!("measuring per-forward costs (T_i)...");
    let profiles: Vec<ModelProfile> = roles
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let t_ms = host.measure_cost_ms(i, 100, 5).unwrap();
            println!("  {r:<13} {t_ms:>7.2} ms/forward");
            ModelProfile { name: r.to_string(), t_ms }
        })
        .collect();

    let vocab = models[0].vocab();
    let prompts: Vec<Vec<i32>> =
        (0..3).map(|i| make_query(polyspec::workload::TaskKind::MultiTurn, i, vocab).prompt).collect();

    println!("\nevaluating insertions (Theorem 3.2)...");
    let plan = plan_chain(
        &models,
        &profiles,
        &prompts,
        10,
        40,
        SamplingParams::default(),
        1.0,
    )?;

    for r in &plan.reports {
        println!("\ncandidate {:?}:", r.candidate);
        println!(
            "  cond1: T_new/T_i = {:.3}  vs  L_new(1/L_i - 1/L_i-new) = {:.3}  -> {}",
            r.verdict.cond1_lhs, r.verdict.cond1_rhs, r.verdict.cond1
        );
        println!(
            "  cond2: T_new/T_next = {:.3}  vs  beta(L_new/L_i - 1) = {:.3}  -> {}",
            r.verdict.cond2_lhs, r.verdict.cond2_rhs, r.verdict.cond2
        );
        println!(
            "  Lemma 3.1 prediction per 100 tokens: {:.0} ms -> {:.0} ms ({})",
            r.predicted_ms_without,
            r.predicted_ms_with,
            if r.verdict.predicts_improvement() { "INSERT" } else { "SKIP" }
        );
    }

    println!("\nplanned chain: {:?}", plan.names);
    println!("(expected: target / intermediate / draft, decoy rejected)");
    Ok(())
}
