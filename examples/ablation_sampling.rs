//! Ablation (paper §4.5): verification-rule comparison on the three-model
//! system — speculative vs greedy vs typical acceptance. Reports speedup,
//! acceptance stability, and whether the output distribution is preserved.
//!
//!   make artifacts && cargo run --release --example ablation_sampling

use polyspec::harness::{load_chain, run_cell, BenchMethod, DEFAULT_POLY};
use polyspec::spec::types::{SamplingParams, VerifyRule};
use polyspec::spec::{autoregressive, polybasic, PolyConfig};
use polyspec::workload::tasks::{make_query, TaskKind};

fn main() -> anyhow::Result<()> {
    let host = load_chain("artifacts", "v7b")?;
    let chain = host.chain();
    let vocab = chain[0].vocab();
    let queries: Vec<_> = (0..6)
        .map(|i| {
            let mut q = make_query(polyspec::workload::ALL_TASKS[i % 6], i as u64, vocab);
            q.max_new = q.max_new.min(32);
            q
        })
        .collect();

    let vanilla = run_cell(&chain, &queries, BenchMethod::Vanilla, VerifyRule::Speculative)?;

    println!("== verification-rule ablation (three-model system) ==\n");
    println!(
        "{:<22} {:>8} {:>8} {:>10} {:>8} {:>10}",
        "rule", "c", "mu", "var(mu)", "cv", "lossless"
    );
    for (label, rule, lossless) in [
        ("speculative", VerifyRule::Speculative, "yes (exact)"),
        ("greedy", VerifyRule::Greedy, "yes (=argmax)"),
        ("typical(eps=0.25)", VerifyRule::Typical { eps: 0.25 }, "NO"),
        ("typical(eps=0.05)", VerifyRule::Typical { eps: 0.05 }, "NO"),
    ] {
        let cell = run_cell(&chain, &queries, DEFAULT_POLY, rule)?;
        let mean = cell.accept.mean();
        println!(
            "{:<22} {:>7.2}x {:>8.2} {:>10.2} {:>8.3} {:>10}",
            label,
            vanilla.wall_s / cell.wall_s.max(1e-12),
            mean,
            cell.accept.variance(),
            cell.accept.variance().sqrt() / mean.max(1e-9),
            lossless
        );
    }

    // Exactness spot-check: greedy polybasic == target greedy decode.
    let prompt = make_query(TaskKind::Qa, 99, vocab).prompt;
    let mut cfg = PolyConfig::for_chain(chain.len(), 6, 8, 24);
    cfg.rule = VerifyRule::Greedy;
    cfg.sampling = SamplingParams { temperature: 0.0, ..Default::default() };
    let poly = polybasic::generate(&chain, &prompt, &cfg)?;
    let ar = autoregressive::generate(chain[0].as_ref(), &prompt, 24, &cfg.sampling)?;
    println!(
        "\ngreedy exactness check: polybasic == target greedy ? {}",
        if poly.tokens == ar.tokens { "YES" } else { "NO (BUG)" }
    );
    Ok(())
}
